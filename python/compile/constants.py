"""Fixed artifact shapes shared by the L1/L2 compile path and the rust runtime.

AOT-lowered HLO has static shapes; the rust coordinator pads/masks its job
queue and feedback batches to these sizes. Keep in sync with
``rust/src/runtime/artifacts.rs`` (checked at load time via manifest.json).
"""

# Job-queue scoring batch (padded, masked).
MAX_JOBS = 256
# Feature variables per (job, node) pair: 4 job features (avg cpu, mem, io,
# net usage declared at submit, 1-10) + 4 node features (cpu usage, idle mem,
# io load, net load from the last heartbeat, 1-10) + 2 failure-history
# features (per-job failed attempts, per-node decayed kill score, 1-10;
# ATLAS-style failure awareness).
N_FEATURES = 10
# The paper's 1-10 discretization -> bins 0..9.
N_BINS = 10
# good / bad (class 0 = good, class 1 = bad).
N_CLASSES = 2
# Feedback-update batch (padded, masked).
MAX_BATCH = 128

# MXU-friendly row tile for the scoring matmul.
TILE_N = 128

FEATURE_DIM = N_FEATURES * N_BINS  # flattened one-hot width (100)
