"""L1 Pallas kernel: batch Naive-Bayes joint log-probability scoring.

Hardware adaptation (DESIGN.md §2.2): the natural GPU formulation is a
gather per (job, feature) — poor on TPU. We one-hot encode the discretized
features (done in L2, cheap VPU work) so the whole batch score becomes a
single ``[N, F*B] @ [F*B, C]`` matmul plus a broadcast prior add — the exact
shape the MXU systolic array wants. The grid streams row tiles of N; the
flattened table (F*B x C = 80x2 f32 = 640 B) and a 128-row activation tile
(40 KiB) are both VMEM-resident, so no K-tiling or double buffering is
needed.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both jax-CPU (tests)
and the rust xla/PJRT runtime can run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(onehot_ref, loglik_t_ref, prior_ref, out_ref):
    """One row-tile: out = onehot @ loglik_t + prior.

    onehot_ref:   f32[TILE_N, F*B]  one-hot encoded features for this tile
    loglik_t_ref: f32[F*B, C]       transposed flattened log-likelihood table
    prior_ref:    f32[1, C]         log class priors (broadcast over rows)
    out_ref:      f32[TILE_N, C]    joint log-probability per (job, class)
    """
    oh = onehot_ref[...]
    llt = loglik_t_ref[...]
    pr = prior_ref[...]
    out_ref[...] = jnp.dot(oh, llt, preferred_element_type=jnp.float32) + pr


def _score_kernel_bf16(onehot_ref, loglik_t_ref, prior_ref, out_ref):
    """bf16-input variant: the MXU's native matmul dtype. The one-hot
    activations are exact in bf16 (values 0/1); only the log-likelihood
    table is rounded (8-bit mantissa -> ~3 decimal digits), and the
    accumulation stays f32 (`preferred_element_type`), mirroring TPU MXU
    semantics. Error bound per output: F * |log_lik| * 2^-8.
    """
    oh = onehot_ref[...].astype(jnp.bfloat16)
    llt = loglik_t_ref[...].astype(jnp.bfloat16)
    pr = prior_ref[...]
    out_ref[...] = jnp.dot(oh, llt, preferred_element_type=jnp.float32) + pr


@functools.partial(jax.jit, static_argnames=("tile_n", "use_bf16"))
def score_onehot(onehot, log_lik, log_prior, *, tile_n=128, use_bf16=False):
    """Joint log-probability of each row under each class.

    Args:
      onehot:    f32[N, F*B] one-hot encoded feature rows.
      log_lik:   f32[C, F*B] flattened log-likelihood table.
      log_prior: f32[C] log class priors.
      tile_n:    row tile; N must be a multiple (callers pad).
      use_bf16:  cast matmul inputs to bfloat16 with f32 accumulation
                 (MXU-native mode; ~3-digit table precision).

    Returns:
      f32[N, C] joint log-probabilities.
    """
    n, fb = onehot.shape
    c = log_prior.shape[0]
    if n % tile_n != 0:
        raise ValueError(f"N={n} must be a multiple of tile_n={tile_n}")
    loglik_t = log_lik.T  # [F*B, C]
    prior2d = log_prior.reshape(1, c)
    grid = (n // tile_n,)
    kernel = _score_kernel_bf16 if use_bf16 else _score_kernel
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, fb), lambda i: (i, 0)),
            pl.BlockSpec((fb, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=True,
    )(onehot, loglik_t, prior2d)
