"""L1 Pallas kernel: Naive-Bayes count accumulation from a feedback batch.

The scatter-add ``counts[label[m], flat[m, j]] += mask[m]`` is reformulated
as a matmul (DESIGN.md §2.2): with L (masked label one-hots, ``[M, C]``) and
X (feature one-hots, ``[M, F*B]``), the count delta is ``Lᵀ @ X`` — an MXU
contraction over the batch dimension M. The kernel computes one (C, F*B)
output block per grid step, accumulating over M tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(lab_t_ref, onehot_ref, out_ref):
    """Accumulate one M-tile: out += lab_t @ onehot.

    lab_t_ref:  f32[C, TILE_M] masked label one-hots, transposed
    onehot_ref: f32[TILE_M, F*B] feature one-hots
    out_ref:    f32[C, F*B] count delta (accumulated across the grid)
    """
    m_idx = pl.program_id(0)

    @pl.when(m_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        lab_t_ref[...], onehot_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_m",))
def count_delta(labels_onehot, onehot, *, tile_m=128):
    """Count-table delta from a masked feedback batch.

    Args:
      labels_onehot: f32[M, C] label one-hots, already multiplied by the
        sample mask (padding rows are all-zero).
      onehot:        f32[M, F*B] feature one-hots.
      tile_m:        batch tile; M must be a multiple (callers pad).

    Returns:
      f32[C, F*B] delta such that new_counts = counts + delta.
    """
    m, c = labels_onehot.shape
    _, fb = onehot.shape
    if m % tile_m != 0:
        raise ValueError(f"M={m} must be a multiple of tile_m={tile_m}")
    lab_t = labels_onehot.T  # [C, M]
    grid = (m // tile_m,)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, tile_m), lambda i: (0, i)),
            pl.BlockSpec((tile_m, fb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((c, fb), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, fb), jnp.float32),
        interpret=True,
    )(lab_t, onehot)
