"""Pure-jnp correctness oracle for the Bayes kernels.

Deliberately uses the *gather* formulation (index into the log-likelihood
table per feature) rather than the one-hot matmul the Pallas kernels use, so
the two paths are independent implementations of the same math.
"""

import jax.numpy as jnp


def score_ref(log_prior, log_lik, feats):
    """Joint log-probability of each job under each class.

    Args:
      log_prior: f32[C] log class priors.
      log_lik:   f32[C, F*B] flattened log P(feature j = bin v | class).
      feats:     i32[N, F] bin indices in [0, B).

    Returns:
      f32[N, C] where out[n, c] = log_prior[c] + sum_j log_lik[c, j*B + feats[n, j]].
    """
    n, f = feats.shape
    b = log_lik.shape[1] // f
    # flat index j*B + v per (job, feature)
    flat = feats + jnp.arange(f, dtype=feats.dtype)[None, :] * b  # [N, F]
    gathered = log_lik[:, flat]  # [C, N, F]
    return log_prior[None, :] + jnp.transpose(gathered.sum(axis=-1))  # [N, C]


def posterior_good_ref(log_prior, log_lik, feats):
    """P(class 0 | feats) per job, numerically stable two-class softmax."""
    s = score_ref(log_prior, log_lik, feats)  # [N, C] with C == 2
    m = jnp.max(s, axis=1, keepdims=True)
    e = jnp.exp(s - m)
    return e[:, 0] / jnp.sum(e, axis=1)


def classify_ref(log_prior, log_lik, feats, utility, mask):
    """Full reference classify: posterior, expected-utility score, argmax."""
    p_good = posterior_good_ref(log_prior, log_lik, feats)
    score = jnp.where(mask > 0, p_good * utility, -1e30)
    best = jnp.argmax(score).astype(jnp.int32).reshape(1)
    return p_good, score, best


def update_counts_ref(counts, class_counts, feats, labels, mask):
    """Accumulate masked feedback samples into the NB count tables.

    Args:
      counts:       f32[C, F*B] per-(class, feature, bin) counts.
      class_counts: f32[C].
      feats:        i32[M, F] bin indices.
      labels:       i32[M] class ids in [0, C).
      mask:         f32[M] 1.0 = real sample, 0.0 = padding.
    """
    c_dim, fb = counts.shape
    m, f = feats.shape
    b = fb // f
    flat = feats + jnp.arange(f, dtype=feats.dtype)[None, :] * b  # [M, F]
    cls_onehot = (labels[:, None] == jnp.arange(c_dim)[None, :]).astype(counts.dtype)
    cls_onehot = cls_onehot * mask[:, None]  # [M, C]
    pos_onehot = (flat[:, :, None] == jnp.arange(fb)[None, None, :]).astype(counts.dtype)
    pos_onehot = pos_onehot.sum(axis=1)  # [M, F*B], one 1 per feature slot
    delta = jnp.einsum("mc,mk->ck", cls_onehot, pos_onehot)
    new_counts = counts + delta
    new_class_counts = class_counts + cls_onehot.sum(axis=0)
    return new_counts, new_class_counts


def smoothed_tables_ref(counts, class_counts, alpha, n_bins):
    """Laplace-smoothed log tables from counts.

    P(J_j = v | c) = (counts[c, j*B+v] + alpha) / (class_counts[c] + alpha*B)
    P(c)           = (class_counts[c] + alpha) / (sum + alpha*C)
    """
    c_dim = class_counts.shape[0]
    log_lik = jnp.log(counts + alpha) - jnp.log(
        class_counts[:, None] + alpha * n_bins
    )
    log_prior = jnp.log(class_counts + alpha) - jnp.log(
        class_counts.sum() + alpha * c_dim
    )
    return log_prior, log_lik


def update_ref(counts, class_counts, feats, labels, mask, alpha, n_bins):
    """Full reference update: new counts + smoothed log tables."""
    nc, ncc = update_counts_ref(counts, class_counts, feats, labels, mask)
    lp, ll = smoothed_tables_ref(nc, ncc, alpha, n_bins)
    return nc, ncc, lp, ll
