"""AOT-lower the L2 entry points to HLO *text* for the rust runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla`` 0.1.6
crate links) rejects (``proto.id() <= INT_MAX``). The HLO text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import constants as C
from . import model


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_classify():
    """Lower classify_jobs at the fixed artifact shapes (DESIGN.md §2.1)."""
    fn = functools.partial(model.classify_jobs, n_bins=C.N_BINS, tile_n=C.TILE_N)
    specs = (
        jax.ShapeDtypeStruct((C.N_CLASSES,), jnp.float32),            # log_prior
        jax.ShapeDtypeStruct((C.N_CLASSES, C.FEATURE_DIM), jnp.float32),  # log_lik
        jax.ShapeDtypeStruct((C.MAX_JOBS, C.N_FEATURES), jnp.int32),  # feats
        jax.ShapeDtypeStruct((C.MAX_JOBS,), jnp.float32),             # utility
        jax.ShapeDtypeStruct((C.MAX_JOBS,), jnp.float32),             # mask
    )
    return jax.jit(fn).lower(*specs)


def lower_update():
    """Lower update_model at the fixed artifact shapes (DESIGN.md §2.1)."""
    fn = functools.partial(model.update_model, n_bins=C.N_BINS, tile_m=C.MAX_BATCH)
    specs = (
        jax.ShapeDtypeStruct((C.N_CLASSES, C.FEATURE_DIM), jnp.float32),  # counts
        jax.ShapeDtypeStruct((C.N_CLASSES,), jnp.float32),            # class_counts
        jax.ShapeDtypeStruct((C.MAX_BATCH, C.N_FEATURES), jnp.int32),  # feats
        jax.ShapeDtypeStruct((C.MAX_BATCH,), jnp.int32),              # labels
        jax.ShapeDtypeStruct((C.MAX_BATCH,), jnp.float32),            # mask
        jax.ShapeDtypeStruct((), jnp.float32),                        # alpha
    )
    return jax.jit(fn).lower(*specs)


MANIFEST_SHAPES = {
    "classify": {
        "inputs": [
            ["log_prior", "f32", [C.N_CLASSES]],
            ["log_lik", "f32", [C.N_CLASSES, C.FEATURE_DIM]],
            ["feats", "i32", [C.MAX_JOBS, C.N_FEATURES]],
            ["utility", "f32", [C.MAX_JOBS]],
            ["mask", "f32", [C.MAX_JOBS]],
        ],
        "outputs": [
            ["p_good", "f32", [C.MAX_JOBS]],
            ["score", "f32", [C.MAX_JOBS]],
            ["best", "i32", [1]],
        ],
    },
    "update": {
        "inputs": [
            ["counts", "f32", [C.N_CLASSES, C.FEATURE_DIM]],
            ["class_counts", "f32", [C.N_CLASSES]],
            ["feats", "i32", [C.MAX_BATCH, C.N_FEATURES]],
            ["labels", "i32", [C.MAX_BATCH]],
            ["mask", "f32", [C.MAX_BATCH]],
            ["alpha", "f32", []],
        ],
        "outputs": [
            ["new_counts", "f32", [C.N_CLASSES, C.FEATURE_DIM]],
            ["new_class_counts", "f32", [C.N_CLASSES]],
            ["log_prior", "f32", [C.N_CLASSES]],
            ["log_lik", "f32", [C.N_CLASSES, C.FEATURE_DIM]],
        ],
    },
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Back-compat with the scaffold Makefile target name.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    entries = {}
    for name, lower in (("classify", lower_classify), ("update", lower_update)):
        text = to_hlo_text(lower())
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            **MANIFEST_SHAPES[name],
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "constants": {
            "max_jobs": C.MAX_JOBS,
            "n_features": C.N_FEATURES,
            "n_bins": C.N_BINS,
            "n_classes": C.N_CLASSES,
            "max_batch": C.MAX_BATCH,
            "feature_dim": C.FEATURE_DIM,
        },
        "entries": entries,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
