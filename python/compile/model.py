"""L2: the Bayes-scheduler compute graph in JAX, calling the L1 kernels.

Two entry points, each AOT-lowered by ``aot.py`` to one HLO module the rust
coordinator executes through PJRT:

  * ``classify_jobs`` — score every queued job against a node's features and
    pick the expected-utility argmax (paper §4.2 selection step).
  * ``update_model``  — fold a batch of overload-rule feedback samples into
    the classifier's count tables and re-derive the smoothed log tables
    (paper §4.2 feedback step).

Everything around the kernels (one-hot encoding, softmax, argmax, Laplace
smoothing) is plain jnp so XLA fuses it into the same module.
"""

import jax
import jax.numpy as jnp

from .kernels.bayes_score import score_onehot
from .kernels.bayes_update import count_delta


def encode_onehot(feats, n_bins):
    """f32 one-hot encoding of discretized features.

    Args:
      feats:  i32[N, F] bin indices in [0, n_bins).
      n_bins: static bin count B.

    Returns:
      f32[N, F*B] flattened one-hot rows (exactly F ones per row).
    """
    n, f = feats.shape
    oh = jax.nn.one_hot(feats, n_bins, dtype=jnp.float32)  # [N, F, B]
    return oh.reshape(n, f * n_bins)


def classify_jobs(log_prior, log_lik, feats, utility, mask, *, n_bins, tile_n=128):
    """Classify the padded job queue against one node and select the best job.

    Args:
      log_prior: f32[2] log priors (class 0 = good, 1 = bad).
      log_lik:   f32[2, F*B] flattened log-likelihood table.
      feats:     i32[N, F] per-job feature bins (job features + node features).
      utility:   f32[N] utility U(i) per job.
      mask:      f32[N] 1.0 = real job, 0.0 = queue padding.

    Returns:
      p_good: f32[N] posterior P(good | J).
      score:  f32[N] masked expected utility P(good|J) * U(i); padding -> -1e30.
      best:   i32[1] argmax index into the padded queue.
    """
    onehot = encode_onehot(feats, n_bins)
    joint = score_onehot(onehot, log_lik, log_prior, tile_n=tile_n)  # [N, 2]
    # Stable two-class softmax -> P(good).
    m = jnp.max(joint, axis=1, keepdims=True)
    e = jnp.exp(joint - m)
    p_good = e[:, 0] / jnp.sum(e, axis=1)
    score = jnp.where(mask > 0, p_good * utility, -1e30)
    best = jnp.argmax(score).astype(jnp.int32).reshape(1)
    return p_good, score, best


def update_model(
    counts, class_counts, feats, labels, mask, alpha, *, n_bins, tile_m=128
):
    """Fold a masked feedback batch into the classifier state.

    Args:
      counts:       f32[2, F*B] per-(class, feature, bin) counts.
      class_counts: f32[2] per-class sample counts.
      feats:        i32[M, F] feature bins of the feedback samples.
      labels:       i32[M] observed class (0 = good, 1 = bad).
      mask:         f32[M] 1.0 = real sample, 0.0 = batch padding.
      alpha:        f32[] Laplace smoothing strength.

    Returns:
      new_counts:       f32[2, F*B]
      new_class_counts: f32[2]
      log_prior:        f32[2]   smoothed, ready for ``classify_jobs``
      log_lik:          f32[2, F*B]
    """
    c_dim = class_counts.shape[0]
    onehot = encode_onehot(feats, n_bins)  # [M, F*B]
    lab_oh = jax.nn.one_hot(labels, c_dim, dtype=jnp.float32) * mask[:, None]
    delta = count_delta(lab_oh, onehot, tile_m=tile_m)  # [2, F*B]
    new_counts = counts + delta
    new_class_counts = class_counts + jnp.sum(lab_oh, axis=0)
    # Laplace smoothing: each feature slot contributes one of B bins per
    # sample, so the per-feature denominator is class_count + alpha*B.
    log_lik = jnp.log(new_counts + alpha) - jnp.log(
        new_class_counts[:, None] + alpha * n_bins
    )
    log_prior = jnp.log(new_class_counts + alpha) - jnp.log(
        jnp.sum(new_class_counts) + alpha * c_dim
    )
    return new_counts, new_class_counts, log_prior, log_lik
