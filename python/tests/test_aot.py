"""AOT path: lowering produces parseable HLO text with the manifest's
entry signature, and the lowered computation (run through jax CPU) matches
the eager L2 functions — i.e. what rust will execute is what we tested."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import constants as C
from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def classify_text():
    return aot.to_hlo_text(aot.lower_classify())


@pytest.fixture(scope="module")
def update_text():
    return aot.to_hlo_text(aot.lower_update())


class TestHloText:
    def test_classify_is_hlo_module(self, classify_text):
        assert classify_text.startswith("HloModule")
        assert "ENTRY" in classify_text

    def test_update_is_hlo_module(self, update_text):
        assert update_text.startswith("HloModule")

    def test_classify_signature(self, classify_text):
        # 5 params with the manifest shapes, tuple of 3 results (HLO text
        # carries layout annotations like f32[256]{0}).
        assert f"f32[{C.N_CLASSES}]" in classify_text
        assert f"f32[{C.N_CLASSES},{C.FEATURE_DIM}]" in classify_text
        assert f"s32[{C.MAX_JOBS},{C.N_FEATURES}]" in classify_text
        assert (
            f"(f32[{C.MAX_JOBS}]{{0}}, f32[{C.MAX_JOBS}]{{0}}, s32[1]{{0}}) tuple"
            in classify_text
        )

    def test_update_signature(self, update_text):
        assert f"s32[{C.MAX_BATCH},{C.N_FEATURES}]" in update_text
        assert (
            f"(f32[{C.N_CLASSES},{C.FEATURE_DIM}]{{1,0}}, f32[{C.N_CLASSES}]{{0}}, "
            f"f32[{C.N_CLASSES}]{{0}}, f32[{C.N_CLASSES},{C.FEATURE_DIM}]{{1,0}}) tuple"
        ) in update_text

    def test_no_custom_calls(self, classify_text, update_text):
        # interpret=True must have eliminated all Mosaic custom-calls; the
        # rust CPU PJRT client cannot execute them.
        assert "custom-call" not in classify_text
        assert "custom-call" not in update_text


class TestLoweredSemantics:
    def test_compiled_classify_matches_eager(self):
        compiled = aot.lower_classify().compile()
        rng = np.random.default_rng(0)
        lp = jnp.log(jnp.asarray([0.6, 0.4], jnp.float32))
        ll = jnp.log(
            jnp.asarray(
                rng.dirichlet(np.ones(C.N_BINS), size=(2, C.N_FEATURES))
                .reshape(2, C.FEATURE_DIM),
                jnp.float32,
            )
        )
        feats = jnp.asarray(
            rng.integers(0, C.N_BINS, size=(C.MAX_JOBS, C.N_FEATURES)), jnp.int32
        )
        utility = jnp.asarray(rng.random(C.MAX_JOBS), jnp.float32)
        mask = jnp.ones(C.MAX_JOBS, jnp.float32)
        got = compiled(lp, ll, feats, utility, mask)
        want = model.classify_jobs(lp, ll, feats, utility, mask, n_bins=C.N_BINS)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6)

    def test_compiled_update_matches_eager(self):
        compiled = aot.lower_update().compile()
        rng = np.random.default_rng(1)
        counts = jnp.asarray(rng.gamma(2.0, 5.0, (2, C.FEATURE_DIM)), jnp.float32)
        class_counts = jnp.asarray([30.0, 20.0], jnp.float32)
        feats = jnp.asarray(
            rng.integers(0, C.N_BINS, size=(C.MAX_BATCH, C.N_FEATURES)), jnp.int32
        )
        labels = jnp.asarray(rng.integers(0, 2, C.MAX_BATCH), jnp.int32)
        mask = jnp.asarray((rng.random(C.MAX_BATCH) < 0.5), jnp.float32)
        alpha = jnp.float32(1.0)
        got = compiled(counts, class_counts, feats, labels, mask, alpha)
        want = model.update_model(
            counts, class_counts, feats, labels, mask, alpha, n_bins=C.N_BINS
        )
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5)


class TestAotCli:
    def test_writes_artifacts_and_manifest(self, tmp_path):
        env = dict(os.environ, JAX_PLATFORMS="cpu")
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["constants"]["max_jobs"] == C.MAX_JOBS
        for name in ("classify", "update"):
            text = (tmp_path / f"{name}.hlo.txt").read_text()
            assert text.startswith("HloModule")
            assert manifest["entries"][name]["file"] == f"{name}.hlo.txt"
