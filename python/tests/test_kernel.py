"""L1 correctness: Pallas kernels vs the pure-jnp gather oracle (ref.py).

This is the CORE correctness signal for the compiled artifacts — the same
kernel code lowers into the HLO the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import constants as C
from compile.kernels import ref
from compile.kernels.bayes_score import score_onehot
from compile.kernels.bayes_update import count_delta
from compile.model import encode_onehot

jax.config.update("jax_platform_name", "cpu")


def make_tables(rng, f, b):
    """Random but valid smoothed NB tables."""
    counts = rng.gamma(2.0, 10.0, size=(2, f * b)).astype(np.float32)
    class_counts = counts.reshape(2, f, b).sum(axis=2).mean(axis=1).astype(np.float32)
    lp, ll = ref.smoothed_tables_ref(
        jnp.asarray(counts), jnp.asarray(class_counts), 1.0, b
    )
    return np.asarray(lp), np.asarray(ll)


# ---------------------------------------------------------------- score ---


class TestScoreKernel:
    def _check(self, seed, n, f, b, tile_n):
        rng = np.random.default_rng(seed)
        lp, ll = make_tables(rng, f, b)
        feats = rng.integers(0, b, size=(n, f), dtype=np.int32)
        onehot = encode_onehot(jnp.asarray(feats), b)
        got = score_onehot(onehot, jnp.asarray(ll), jnp.asarray(lp), tile_n=tile_n)
        want = ref.score_ref(jnp.asarray(lp), jnp.asarray(ll), jnp.asarray(feats))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_artifact_shape(self):
        self._check(0, C.MAX_JOBS, C.N_FEATURES, C.N_BINS, C.TILE_N)

    def test_single_tile(self):
        self._check(1, 128, 8, 10, 128)

    def test_many_tiles(self):
        self._check(2, 512, 8, 10, 128)

    def test_tiny_tile(self):
        self._check(3, 32, 4, 5, 8)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        tiles=st.integers(1, 4),
        tile_n=st.sampled_from([8, 16, 32, 64, 128]),
        f=st.integers(1, 8),
        b=st.integers(2, 12),
    )
    def test_hypothesis_sweep(self, seed, tiles, tile_n, f, b):
        self._check(seed, tiles * tile_n, f, b, tile_n)

    def test_rejects_unaligned_n(self):
        with pytest.raises(ValueError, match="multiple"):
            score_onehot(
                jnp.zeros((100, 80)), jnp.zeros((2, 80)), jnp.zeros((2,)), tile_n=128
            )

    def test_extreme_loglik_values(self):
        # Very negative log-liks (near-zero probabilities) must not produce
        # NaN/Inf in the joint scores.
        n, f, b = 128, 8, 10
        rng = np.random.default_rng(7)
        feats = rng.integers(0, b, size=(n, f), dtype=np.int32)
        ll = np.full((2, f * b), -50.0, dtype=np.float32)
        lp = np.log(np.array([0.5, 0.5], dtype=np.float32))
        onehot = encode_onehot(jnp.asarray(feats), b)
        got = np.asarray(score_onehot(onehot, jnp.asarray(ll), jnp.asarray(lp)))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, -50.0 * f + np.log(0.5), rtol=1e-5)


# --------------------------------------------------------------- update ---


class TestUpdateKernel:
    def _check(self, seed, m, f, b, tile_m, mask_frac=0.7):
        rng = np.random.default_rng(seed)
        feats = rng.integers(0, b, size=(m, f), dtype=np.int32)
        labels = rng.integers(0, 2, size=(m,), dtype=np.int32)
        mask = (rng.random(m) < mask_frac).astype(np.float32)
        lab_oh = jax.nn.one_hot(jnp.asarray(labels), 2, dtype=jnp.float32)
        lab_oh = lab_oh * jnp.asarray(mask)[:, None]
        onehot = encode_onehot(jnp.asarray(feats), b)
        got = count_delta(lab_oh, onehot, tile_m=tile_m)
        want, _ = ref.update_counts_ref(
            jnp.zeros((2, f * b)),
            jnp.zeros((2,)),
            jnp.asarray(feats),
            jnp.asarray(labels),
            jnp.asarray(mask),
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)

    def test_artifact_shape(self):
        self._check(0, C.MAX_BATCH, C.N_FEATURES, C.N_BINS, C.MAX_BATCH)

    def test_multi_tile_accumulation(self):
        self._check(1, 256, 8, 10, 64)

    def test_all_masked(self):
        self._check(2, 128, 8, 10, 128, mask_frac=0.0)

    def test_none_masked(self):
        self._check(3, 128, 8, 10, 128, mask_frac=1.0)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        tiles=st.integers(1, 4),
        tile_m=st.sampled_from([8, 32, 64, 128]),
        f=st.integers(1, 8),
        b=st.integers(2, 12),
        mask_frac=st.floats(0.0, 1.0),
    )
    def test_hypothesis_sweep(self, seed, tiles, tile_m, f, b, mask_frac):
        self._check(seed, tiles * tile_m, f, b, tile_m, mask_frac)

    def test_rejects_unaligned_m(self):
        with pytest.raises(ValueError, match="multiple"):
            count_delta(jnp.zeros((100, 2)), jnp.zeros((100, 80)), tile_m=128)

    def test_delta_total_equals_masked_samples_times_features(self):
        # Each real sample contributes exactly F ones to the count table.
        m, f, b = 128, 8, 10
        rng = np.random.default_rng(11)
        feats = rng.integers(0, b, size=(m, f), dtype=np.int32)
        labels = rng.integers(0, 2, size=(m,), dtype=np.int32)
        mask = (rng.random(m) < 0.5).astype(np.float32)
        lab_oh = jax.nn.one_hot(jnp.asarray(labels), 2) * jnp.asarray(mask)[:, None]
        delta = count_delta(lab_oh, encode_onehot(jnp.asarray(feats), b))
        assert float(jnp.sum(delta)) == pytest.approx(float(mask.sum()) * f)


# --------------------------------------------------------------- onehot ---


class TestEncodeOnehot:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(1, 64),
        f=st.integers(1, 8),
        b=st.integers(2, 12),
    )
    def test_row_structure(self, seed, n, f, b):
        rng = np.random.default_rng(seed)
        feats = rng.integers(0, b, size=(n, f), dtype=np.int32)
        oh = np.asarray(encode_onehot(jnp.asarray(feats), b))
        assert oh.shape == (n, f * b)
        # exactly one 1 per feature slot
        np.testing.assert_array_equal(oh.reshape(n, f, b).sum(axis=2), 1.0)
        # and it's at the right bin
        recon = oh.reshape(n, f, b).argmax(axis=2)
        np.testing.assert_array_equal(recon, feats)


# ---------------------------------------------------------------- bf16 ----


class TestBf16Variant:
    """The MXU-native bf16 kernel must match f32 within the rounding bound
    F * max|log_lik| * 2^-8 and must never flip a confident good/bad call."""

    def _pair(self, seed, n=128, f=8, b=10):
        rng = np.random.default_rng(seed)
        lp, ll = make_tables(rng, f, b)
        feats = rng.integers(0, b, size=(n, f), dtype=np.int32)
        onehot = encode_onehot(jnp.asarray(feats), b)
        f32 = score_onehot(onehot, jnp.asarray(ll), jnp.asarray(lp))
        bf16 = score_onehot(
            onehot, jnp.asarray(ll), jnp.asarray(lp), use_bf16=True
        )
        bound = f * np.abs(ll).max() * 2.0**-8 + 1e-5
        return np.asarray(f32), np.asarray(bf16), bound

    def test_within_rounding_bound(self):
        f32, bf16, bound = self._pair(0)
        assert np.abs(f32 - bf16).max() <= bound

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_bound(self, seed):
        f32, bf16, bound = self._pair(seed)
        assert np.abs(f32 - bf16).max() <= bound

    def test_confident_decisions_stable(self):
        # margins larger than 2x the bound cannot flip sign
        f32, bf16, bound = self._pair(7)
        margin_f32 = f32[:, 0] - f32[:, 1]
        margin_bf16 = bf16[:, 0] - bf16[:, 1]
        confident = np.abs(margin_f32) > 2 * bound
        assert (np.sign(margin_f32[confident]) == np.sign(margin_bf16[confident])).all()
