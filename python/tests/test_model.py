"""L2 correctness: the full classify/update entry points vs ref.py, plus the
semantic properties the rust coordinator depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import constants as C
from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def random_state(rng, f=C.N_FEATURES, b=C.N_BINS):
    counts = rng.gamma(2.0, 10.0, size=(2, f * b)).astype(np.float32)
    class_counts = np.array(
        [counts[0].sum() / f, counts[1].sum() / f], dtype=np.float32
    )
    lp, ll = ref.smoothed_tables_ref(
        jnp.asarray(counts), jnp.asarray(class_counts), 1.0, b
    )
    return counts, class_counts, np.asarray(lp), np.asarray(ll)


def random_queue(rng, n=C.MAX_JOBS, f=C.N_FEATURES, b=C.N_BINS, fill=0.6):
    feats = rng.integers(0, b, size=(n, f), dtype=np.int32)
    utility = rng.random(n).astype(np.float32) * 10.0
    mask = np.zeros(n, dtype=np.float32)
    k = max(1, int(n * fill))
    mask[:k] = 1.0
    return feats, utility, mask


class TestClassifyJobs:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        _, _, lp, ll = random_state(rng)
        feats, utility, mask = random_queue(rng)
        p, s, best = model.classify_jobs(
            jnp.asarray(lp), jnp.asarray(ll), jnp.asarray(feats),
            jnp.asarray(utility), jnp.asarray(mask), n_bins=C.N_BINS,
        )
        pr, sr, br = ref.classify_ref(
            jnp.asarray(lp), jnp.asarray(ll), jnp.asarray(feats),
            jnp.asarray(utility), jnp.asarray(mask),
        )
        np.testing.assert_allclose(np.asarray(p), np.asarray(pr), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5, atol=1e-5)
        assert int(best[0]) == int(br[0])

    def test_posterior_in_unit_interval(self):
        rng = np.random.default_rng(1)
        _, _, lp, ll = random_state(rng)
        feats, utility, mask = random_queue(rng)
        p, _, _ = model.classify_jobs(
            jnp.asarray(lp), jnp.asarray(ll), jnp.asarray(feats),
            jnp.asarray(utility), jnp.asarray(mask), n_bins=C.N_BINS,
        )
        p = np.asarray(p)
        assert ((p >= 0) & (p <= 1)).all()

    def test_best_never_padding(self):
        rng = np.random.default_rng(2)
        _, _, lp, ll = random_state(rng)
        for fill in (0.01, 0.25, 1.0):
            feats, utility, mask = random_queue(rng, fill=fill)
            _, _, best = model.classify_jobs(
                jnp.asarray(lp), jnp.asarray(ll), jnp.asarray(feats),
                jnp.asarray(utility), jnp.asarray(mask), n_bins=C.N_BINS,
            )
            assert mask[int(best[0])] == 1.0

    def test_utility_breaks_ties(self):
        # Identical features => selection driven purely by utility.
        rng = np.random.default_rng(3)
        _, _, lp, ll = random_state(rng)
        n = C.MAX_JOBS
        feats = np.full((n, C.N_FEATURES), 4, dtype=np.int32)
        utility = np.ones(n, dtype=np.float32)
        utility[17] = 5.0
        mask = np.ones(n, dtype=np.float32)
        _, _, best = model.classify_jobs(
            jnp.asarray(lp), jnp.asarray(ll), jnp.asarray(feats),
            jnp.asarray(utility), jnp.asarray(mask), n_bins=C.N_BINS,
        )
        assert int(best[0]) == 17

    def test_deterministic(self):
        rng = np.random.default_rng(4)
        _, _, lp, ll = random_state(rng)
        feats, utility, mask = random_queue(rng)
        args = (
            jnp.asarray(lp), jnp.asarray(ll), jnp.asarray(feats),
            jnp.asarray(utility), jnp.asarray(mask),
        )
        a = model.classify_jobs(*args, n_bins=C.N_BINS)
        b = model.classify_jobs(*args, n_bins=C.N_BINS)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestUpdateModel:
    def _batch(self, rng, m=C.MAX_BATCH, f=C.N_FEATURES, b=C.N_BINS, fill=0.5):
        feats = rng.integers(0, b, size=(m, f), dtype=np.int32)
        labels = rng.integers(0, 2, size=(m,), dtype=np.int32)
        mask = (rng.random(m) < fill).astype(np.float32)
        return feats, labels, mask

    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        counts, class_counts, _, _ = random_state(rng)
        feats, labels, mask = self._batch(rng)
        got = model.update_model(
            jnp.asarray(counts), jnp.asarray(class_counts), jnp.asarray(feats),
            jnp.asarray(labels), jnp.asarray(mask), jnp.float32(1.0),
            n_bins=C.N_BINS,
        )
        want = ref.update_ref(
            jnp.asarray(counts), jnp.asarray(class_counts), jnp.asarray(feats),
            jnp.asarray(labels), jnp.asarray(mask), 1.0, C.N_BINS,
        )
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        alpha=st.sampled_from([0.1, 0.5, 1.0, 10.0]),
        fill=st.floats(0.0, 1.0),
    )
    def test_hypothesis_matches_ref(self, seed, alpha, fill):
        rng = np.random.default_rng(seed)
        counts, class_counts, _, _ = random_state(rng)
        feats, labels, mask = self._batch(rng, fill=fill)
        got = model.update_model(
            jnp.asarray(counts), jnp.asarray(class_counts), jnp.asarray(feats),
            jnp.asarray(labels), jnp.asarray(mask), jnp.float32(alpha),
            n_bins=C.N_BINS,
        )
        want = ref.update_ref(
            jnp.asarray(counts), jnp.asarray(class_counts), jnp.asarray(feats),
            jnp.asarray(labels), jnp.asarray(mask), alpha, C.N_BINS,
        )
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5)

    def test_counts_monotone(self):
        rng = np.random.default_rng(5)
        counts, class_counts, _, _ = random_state(rng)
        feats, labels, mask = self._batch(rng)
        nc, ncc, _, _ = model.update_model(
            jnp.asarray(counts), jnp.asarray(class_counts), jnp.asarray(feats),
            jnp.asarray(labels), jnp.asarray(mask), jnp.float32(1.0),
            n_bins=C.N_BINS,
        )
        assert (np.asarray(nc) >= counts - 1e-6).all()
        assert (np.asarray(ncc) >= class_counts - 1e-6).all()

    def test_tables_are_log_probabilities(self):
        # Start from the empty state (as the coordinator does) so the NB
        # invariant counts[c, j*B:(j+1)*B].sum() == class_counts[c] holds.
        rng = np.random.default_rng(6)
        counts = jnp.zeros((2, C.FEATURE_DIM), jnp.float32)
        class_counts = jnp.zeros((2,), jnp.float32)
        feats, labels, mask = self._batch(rng)
        _, _, lp, ll = model.update_model(
            counts, class_counts, jnp.asarray(feats),
            jnp.asarray(labels), jnp.asarray(mask), jnp.float32(1.0),
            n_bins=C.N_BINS,
        )
        # priors sum to 1
        assert float(jnp.sum(jnp.exp(lp))) == pytest.approx(1.0, rel=1e-5)
        # each per-feature likelihood block sums to 1 per class
        blocks = np.exp(np.asarray(ll)).reshape(2, C.N_FEATURES, C.N_BINS)
        np.testing.assert_allclose(blocks.sum(axis=2), 1.0, rtol=1e-4)

    def test_learning_separates_classes(self):
        # Feed the classifier overload feedback that is perfectly predictable
        # from feature 0 and check classify flips accordingly: the paper's
        # feedback loop in miniature.
        f, b = C.N_FEATURES, C.N_BINS
        counts = jnp.zeros((2, f * b), jnp.float32)
        class_counts = jnp.zeros((2,), jnp.float32)
        m = C.MAX_BATCH
        rng = np.random.default_rng(7)
        feats = rng.integers(0, b, size=(m, f), dtype=np.int32)
        feats[: m // 2, 0] = 9  # high cpu -> bad
        feats[m // 2 :, 0] = 0  # low cpu -> good
        labels = np.r_[np.ones(m // 2, np.int32), np.zeros(m // 2, np.int32)]
        mask = np.ones(m, np.float32)
        _, _, lp, ll = model.update_model(
            counts, class_counts, jnp.asarray(feats), jnp.asarray(labels),
            jnp.asarray(mask), jnp.float32(1.0), n_bins=C.N_BINS,
        )
        n = C.MAX_JOBS
        qf = rng.integers(0, b, size=(n, f), dtype=np.int32)
        qf[0, 0] = 0   # should classify good
        qf[1, 0] = 9   # should classify bad
        p, _, _ = model.classify_jobs(
            lp, ll, jnp.asarray(qf), jnp.ones(n, jnp.float32),
            jnp.ones(n, jnp.float32), n_bins=C.N_BINS,
        )
        assert float(p[0]) > 0.5 > float(p[1])
