//! Trace workflow: generate a workload trace, persist it as JSON, reload
//! it, and replay the identical job stream under several schedulers —
//! the apples-to-apples comparison methodology the experiments use.
//!
//!     cargo run --release --example trace_explorer

use std::collections::BTreeMap;

use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::builder::{build_tracker_with, RunConfig};
use bayes_sched::report::table::{fnum, Table};
use bayes_sched::workload::generator::{generate, WorkloadConfig};
use bayes_sched::workload::trace;

fn main() -> bayes_sched::errors::Result<()> {
    // 1. generate + save
    let workload = WorkloadConfig { n_jobs: 80, arrival_rate: 0.8, seed: 5, ..Default::default() };
    let specs = generate(&workload);
    let path = std::env::temp_dir().join("bayes_sched_demo_trace.json");
    trace::save(&specs, &path)?;
    println!("wrote {} jobs to {}", specs.len(), path.display());

    // 2. inspect the trace composition
    let mut by_class: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    for s in &specs {
        let e = by_class.entry(s.class.name()).or_default();
        e.0 += 1;
        e.1 += s.map_works.len() + s.reduce_works.len();
    }
    let mut comp = Table::new("trace composition", &["class", "jobs", "tasks"]);
    for (class, (jobs, tasks)) in by_class {
        comp.row(vec![class.into(), jobs.to_string(), tasks.to_string()]);
    }
    println!("{}", comp.render());

    // 3. reload + replay under every scheduler
    let loaded = trace::load(&path)?;
    assert_eq!(loaded.len(), specs.len());
    let mut table = Table::new(
        "identical trace replayed per scheduler",
        &["scheduler", "makespan_s", "throughput", "overload_rate"],
    );
    for sched in ["fifo", "fair", "capacity", "bayes", "random"] {
        let cfg = RunConfig {
            scheduler: sched.into(),
            n_nodes: 16,
            n_racks: 4,
            workload: workload.clone(),
            ..Default::default()
        };
        let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
        let mut jt = build_tracker_with(&cfg, cluster, loaded.clone())?;
        jt.run();
        table.row(vec![
            sched.into(),
            fnum(jt.metrics.makespan),
            fnum(jt.metrics.throughput()),
            fnum(jt.metrics.overload_rate()),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
