//! Quickstart: the smallest complete use of the public API — build a
//! cluster, generate a workload, run the Bayes scheduler, read the metrics.
//!
//!     cargo run --release --example quickstart

use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
use bayes_sched::metrics::stats;
use bayes_sched::scheduler;
use bayes_sched::workload::generator::{generate, WorkloadConfig};

fn main() {
    // 1. a 10-node, 2-rack cluster of standard TaskTrackers
    let cluster = Cluster::homogeneous(10, 2);

    // 2. 50 mixed jobs arriving as a Poisson process (0.5 jobs/s)
    let workload = WorkloadConfig {
        n_jobs: 50,
        arrival_rate: 0.5,
        seed: 42,
        ..Default::default()
    };
    let specs = generate(&workload);

    // 3. the paper's scheduler: online Naive Bayes with overload feedback
    let sched = scheduler::by_name("bayes", workload.seed).unwrap();

    // 4. run the JobTracker to completion
    let mut jt = JobTracker::new(cluster, sched, specs, workload.seed, TrackerConfig::default());
    let makespan = jt.run();

    // 5. read the results
    let m = &jt.metrics;
    let lat = m.latencies();
    println!("scheduler        : bayes");
    println!("jobs completed   : {}", m.completed_jobs());
    println!("makespan         : {makespan:.1} s (virtual)");
    println!("throughput       : {:.3} jobs/s", m.throughput());
    println!("mean job latency : {:.1} s", stats::mean(&lat));
    println!("p95 job latency  : {:.1} s", stats::percentile(&lat, 95.0));
    println!("overload rate    : {:.3}", m.overload_rate());
    println!("node-local maps  : {:.1} %", 100.0 * m.locality_fraction("node_local"));
    println!("feedback samples : good={} bad={}", m.feedback[0], m.feedback[1]);
    assert!(jt.jobs.all_complete());
}
