//! YARN-mode scenario (paper §2 + E10): the Bayes policy inside the
//! ResourceManager, against YARN-FIFO and YARN-Fair, under the
//! declared-vs-actual container demand mismatch that defeats pure fit
//! checking.
//!
//!     cargo run --release --example yarn_mode

use bayes_sched::cluster::Cluster;
use bayes_sched::metrics::stats;
use bayes_sched::report::table::{fnum, Table};
use bayes_sched::workload::generator::{generate, Mix, WorkloadConfig};
use bayes_sched::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

fn main() {
    let workload = WorkloadConfig {
        n_jobs: 120,
        arrival_rate: 0.6,
        mix: Mix::cpu_fraction(0.4),
        seed: 10,
        ..Default::default()
    };
    let mut table = Table::new(
        "YARN mode: RM policies under misdeclared container demands",
        &[
            "policy",
            "makespan_s",
            "mean_latency_s",
            "overload_rate",
            "overload_seconds",
            "oom_kills",
            "failed_jobs",
        ],
    );
    for policy in ["yarn-fifo", "yarn-fair", "yarn-bayes"] {
        let mut rm = ResourceManager::new(
            Cluster::homogeneous(24, 4),
            yarn_policy_by_name(policy, 1.0).expect("policy"),
            generate(&workload),
            workload.seed,
            YarnConfig::default(),
        );
        rm.run();
        let m = &rm.metrics;
        let lat = m.latencies();
        table.row(vec![
            policy.into(),
            fnum(m.makespan),
            fnum(stats::mean(&lat)),
            fnum(m.overload_rate()),
            fnum(m.overload_seconds),
            format!("{}", m.oom_kills),
            format!("{}", m.failed_jobs),
        ]);
        assert!(rm.jobs.all_complete());
    }
    println!("{}", table.render());
    println!(
        "the RM fit-checks DECLARED demands; ACTUAL usage diverges (users\n\
         misdeclare), so fit-only policies still overload. the bayes policy\n\
         learns the gap from overload feedback — the paper's algorithm\n\
         transplanted into the architecture its §2 motivates."
    );
}
