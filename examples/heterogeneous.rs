//! Heterogeneous-cluster scenario (paper §4.1 motivation): administrators
//! cannot hand-tune per-node task limits. A mixed fast/standard/slow
//! cluster runs with MIS-tuned slot counts (every node gets the default 4
//! map slots); the Bayes scheduler has to learn which (job, node) pairs
//! melt the slow machines, while FIFO happily overloads them.
//!
//!     cargo run --release --example heterogeneous

use bayes_sched::cluster::node::NodeSpec;
use bayes_sched::cluster::resources::Resources;
use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::builder::{build_tracker_with, RunConfig};
use bayes_sched::metrics::stats;
use bayes_sched::report::table::{fnum, Table};
use bayes_sched::workload::generator::{generate, WorkloadConfig};

fn mistuned_cluster(n: u32, seed: u64) -> Cluster {
    let fast = NodeSpec {
        capacity: Resources::splat(2.0),
        speed: 2.0,
        map_slots: 4,
        reduce_slots: 2,
    };
    let standard = NodeSpec { map_slots: 4, reduce_slots: 2, ..Default::default() };
    // the mis-tuning: slow, small nodes get the same 4 map slots
    let slow = NodeSpec {
        capacity: Resources::splat(0.5),
        speed: 0.5,
        map_slots: 4,
        reduce_slots: 2,
    };
    Cluster::heterogeneous(
        n,
        4,
        &[(fast, 0.25), (standard, 0.5), (slow, 0.25)],
        seed,
    )
}

fn main() {
    let workload = WorkloadConfig {
        n_jobs: 150,
        arrival_rate: 0.6,
        seed: 9,
        ..Default::default()
    };
    let mut table = Table::new(
        "mis-tuned heterogeneous cluster (25% fast / 50% std / 25% slow)",
        &[
            "scheduler",
            "makespan_s",
            "p95_latency_s",
            "overload_rate",
            "overload_seconds",
            "oom_kills",
        ],
    );
    for sched in ["fifo", "fair", "threshold-fifo", "bayes"] {
        let cfg = RunConfig {
            scheduler: sched.into(),
            n_nodes: 32,
            n_racks: 4,
            workload: workload.clone(),
            ..Default::default()
        };
        let cluster = mistuned_cluster(cfg.n_nodes, 99);
        let specs = generate(&cfg.workload);
        let mut jt = build_tracker_with(&cfg, cluster, specs).expect("build");
        jt.run();
        let lat = jt.metrics.latencies();
        table.row(vec![
            sched.into(),
            fnum(jt.metrics.makespan),
            fnum(stats::percentile(&lat, 95.0)),
            fnum(jt.metrics.overload_rate()),
            fnum(jt.metrics.overload_seconds),
            format!("{}", jt.metrics.oom_kills),
        ]);
    }
    println!("{}", table.render());
    println!(
        "the static threshold baseline helps, but only the learner adapts to\n\
         per-node capacity differences it was never told about (paper §4.3)."
    );
}
