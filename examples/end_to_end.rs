//! End-to-end driver (DESIGN.md §4, EXPERIMENTS.md): exercises the FULL
//! three-layer stack on a real workload — the AOT-compiled Pallas/JAX
//! classifier artifacts executed through rust PJRT inside the scheduling
//! hot path — and prints the paper's headline comparison.
//!
//! Requires `make artifacts` (falls back to the pure-rust classifier with a
//! warning if they are missing, so the example always runs).
//!
//!     cargo run --release --example end_to_end

use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::builder::{build_tracker_with, RunConfig};
use bayes_sched::metrics::stats;
use bayes_sched::report::table::{fnum, Table};
use bayes_sched::runtime::artifacts;
use bayes_sched::workload::generator::{generate, Mix, WorkloadConfig};

fn main() {
    let artifacts_ok = cfg!(feature = "xla-runtime")
        && artifacts::Manifest::load(&artifacts::default_dir()).is_ok();
    let bayes_variant = if artifacts_ok {
        println!("artifacts found: running the XLA/PJRT classifier on the hot path\n");
        "bayes-xla"
    } else {
        eprintln!(
            "WARNING: XLA path unavailable (artifacts/ missing or built \
             without `xla-runtime`)."
        );
        eprintln!("falling back to the pure-rust classifier\n");
        "bayes"
    };

    let workload = WorkloadConfig {
        n_jobs: 120,
        arrival_rate: 0.6,
        mix: Mix::cpu_fraction(0.5), // contention-prone half-cpu-heavy mix
        n_users: 6,
        seed: 7,
    };

    let mut table = Table::new(
        "end-to-end: 120 jobs, 20 nodes, cpu-heavy mix (full stack)",
        &[
            "scheduler",
            "makespan_s",
            "mean_latency_s",
            "p95_latency_s",
            "overload_rate",
            "oom_kills",
            "decision_us",
        ],
    );

    for sched in ["fifo", "fair", "capacity", bayes_variant] {
        let cfg = RunConfig {
            scheduler: sched.into(),
            n_nodes: 20,
            n_racks: 4,
            workload: workload.clone(),
            ..Default::default()
        };
        let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
        let specs = generate(&cfg.workload);
        let mut jt = build_tracker_with(&cfg, cluster, specs).expect("build");
        let wall = std::time::Instant::now();
        jt.run();
        let wall = wall.elapsed();
        let lat = jt.metrics.latencies();
        table.row(vec![
            sched.into(),
            fnum(jt.metrics.makespan),
            fnum(stats::mean(&lat)),
            fnum(stats::percentile(&lat, 95.0)),
            fnum(jt.metrics.overload_rate()),
            format!("{}", jt.metrics.oom_kills),
            fnum(jt.metrics.mean_decision_micros()),
        ]);
        println!(
            "{sched:>10}: {} events, {} heartbeats, {:.2}s wall",
            jt.engine.processed(),
            jt.metrics.heartbeats,
            wall.as_secs_f64()
        );
        assert!(jt.jobs.all_complete());
    }
    println!("\n{}", table.render());
    println!(
        "expected shape (paper §4.3): bayes lowest overload rate and fewest \
         OOM kills,\ncompetitive-or-best makespan, at microsecond-scale \
         decision cost."
    );
}
