//! The RM-side scheduler adapter. The old `YarnPolicy` trait hierarchy
//! (YarnFifo / YarnFair / YarnBayes) duplicated the MRv1 scheduler
//! abstraction behind a second interface; it is gone. [`SchedulerPolicy`]
//! adapts any [`Scheduler`] to the ResourceManager driver instead, so the
//! exact same policy code — including the paper's Bayes contribution — runs
//! under both execution modes and can be compared apples-to-apples.
//!
//! The adapter is thin by design: the RM owns the YARN-specific mechanics
//! (declared-resource fit filtering, the per-node container cap, the
//! misdeclaration model) and presents the scheduler with the same
//! `SchedView`/`SlotBudget`/`SchedEvent` contract the JobTracker uses.

use crate::bayes::classifier::NaiveBayes;
use crate::cluster::node::Node;
use crate::errors::{anyhow, Result};
use crate::scheduler::api::{Assignment, SchedEvent, SchedView, Scheduler, SlotBudget};
use crate::scheduler::{self, BayesScheduler, Capacity, Fair, Fifo};

/// Any [`Scheduler`] running under the ResourceManager driver.
pub struct SchedulerPolicy {
    inner: Box<dyn Scheduler>,
}

impl SchedulerPolicy {
    pub fn new(inner: Box<dyn Scheduler>) -> SchedulerPolicy {
        SchedulerPolicy { inner }
    }

    /// Build a policy by name. The legacy `yarn-*` aliases map onto the
    /// unified schedulers; every `scheduler::by_name` name works too.
    /// Note: seed-dependent baselines (`random`) get a fixed RNG stream
    /// here — use the MRv1 driver when a seeded baseline comparison
    /// matters.
    pub fn by_name(name: &str, alpha: f32) -> Result<SchedulerPolicy> {
        let inner: Box<dyn Scheduler> = match name {
            "yarn-fifo" => Box::new(Fifo::new()),
            "yarn-fair" => Box::new(Fair::new()),
            "yarn-capacity" => Box::new(Capacity::new()),
            "yarn-bayes" | "bayes" => {
                Box::new(BayesScheduler::new(NaiveBayes::new(alpha)))
            }
            other => scheduler::by_name(other, 0)
                .ok_or_else(|| anyhow!("unknown yarn policy '{other}'"))?,
        };
        Ok(SchedulerPolicy::new(inner))
    }

    pub fn name(&self) -> &'static str {
        self.inner.name()
    }

    pub fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        self.inner.assign(view, node, budget)
    }

    pub fn observe(&mut self, ev: &SchedEvent) {
        self.inner.observe(ev);
    }

    /// Forward obs registration to the wrapped scheduler, so the policy's
    /// assign timings land under the same `sched_<name>_*` metrics as in
    /// MRv1 mode.
    pub fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.inner.install_obs(registry);
    }

    pub fn export_model(&self) -> Option<crate::config::json::Json> {
        self.inner.export_model()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yarn_aliases_resolve() {
        for (alias, inner) in [
            ("yarn-fifo", "fifo"),
            ("yarn-fair", "fair"),
            ("yarn-capacity", "capacity"),
            ("yarn-bayes", "bayes"),
        ] {
            let p = SchedulerPolicy::by_name(alias, 1.0).unwrap();
            assert_eq!(p.name(), inner, "{alias}");
        }
    }

    #[test]
    fn plain_scheduler_names_work_too() {
        for name in scheduler::ALL_NAMES {
            assert!(SchedulerPolicy::by_name(name, 1.0).is_ok(), "{name}");
        }
    }

    #[test]
    fn unknown_name_rejected() {
        assert!(SchedulerPolicy::by_name("nope", 1.0).is_err());
    }
}
