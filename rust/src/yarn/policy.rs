//! RM scheduling policies: which application's pending container request
//! wins a node's free resources.

use crate::bayes::classifier::{Classifier, NaiveBayes};
use crate::bayes::features::{feature_vec, FeatureVec, NodeFeatures};
use crate::bayes::utility::UtilityFn;
use crate::bayes::Label;
use crate::cluster::resources::Resources;
use crate::job::job::Job;
use crate::job::JobId;
use crate::sim::engine::Time;

/// A pending container request summary handed to the policy.
pub struct AppRequest<'a> {
    pub app: JobId,
    pub job: &'a Job,
    /// Declared per-container demand (what the RM fit-checks).
    pub declared: Resources,
    /// Containers currently running for this app.
    pub running: u32,
}

/// RM scheduling policy.
pub trait YarnPolicy {
    fn name(&self) -> &'static str;

    /// Choose which request (index into `reqs`) gets a container on a node
    /// with `free` resources and `node_feats` load, or None to hold back.
    /// Every entry in `reqs` already passed the declared-fit check.
    fn choose(
        &mut self,
        reqs: &[AppRequest],
        free: Resources,
        node_feats: &NodeFeatures,
        now: Time,
    ) -> Option<usize>;

    /// Overload feedback for an earlier allocation (bayes only).
    fn feedback(&mut self, _feats: FeatureVec, _label: Label) {}
}

/// FIFO: oldest app first.
#[derive(Debug, Default)]
pub struct YarnFifo;

impl YarnPolicy for YarnFifo {
    fn name(&self) -> &'static str {
        "yarn-fifo"
    }

    fn choose(
        &mut self,
        reqs: &[AppRequest],
        _free: Resources,
        _node_feats: &NodeFeatures,
        _now: Time,
    ) -> Option<usize> {
        (!reqs.is_empty()).then_some(0)
    }
}

/// Fair: the app with the fewest running containers wins (instantaneous
/// max-min fairness in container count).
#[derive(Debug, Default)]
pub struct YarnFair;

impl YarnPolicy for YarnFair {
    fn name(&self) -> &'static str {
        "yarn-fair"
    }

    fn choose(
        &mut self,
        reqs: &[AppRequest],
        _free: Resources,
        _node_feats: &NodeFeatures,
        _now: Time,
    ) -> Option<usize> {
        reqs.iter()
            .enumerate()
            .min_by_key(|(i, r)| (r.running, *i))
            .map(|(i, _)| i)
    }
}

/// The paper's Bayes policy at the RM: classify (app declared profile ×
/// node load), pick the best good app by expected utility.
pub struct YarnBayes {
    classifier: NaiveBayes,
    utility: UtilityFn,
}

impl YarnBayes {
    pub fn new(alpha: f32) -> YarnBayes {
        YarnBayes { classifier: NaiveBayes::new(alpha), utility: UtilityFn::default() }
    }
}

impl YarnPolicy for YarnBayes {
    fn name(&self) -> &'static str {
        "yarn-bayes"
    }

    fn choose(
        &mut self,
        reqs: &[AppRequest],
        _free: Resources,
        node_feats: &NodeFeatures,
        now: Time,
    ) -> Option<usize> {
        if reqs.is_empty() {
            return None;
        }
        let window = reqs.len().min(crate::bayes::classifier::MAX_JOBS);
        let feats: Vec<FeatureVec> = reqs[..window]
            .iter()
            .map(|r| feature_vec(&r.job.spec.profile, node_feats))
            .collect();
        let utility: Vec<f32> = reqs[..window]
            .iter()
            .map(|r| {
                self.utility
                    .eval(r.job.spec.priority, now - r.job.spec.submit_time)
                    as f32
            })
            .collect();
        let res = self.classifier.classify(&feats, &utility);
        let good = (0..window)
            .filter(|&i| res.is_good(i))
            .max_by(|&a, &b| res.score[a].total_cmp(&res.score[b]));
        // Same wait-unless-idle gate as the MRv1 scheduler (deviation D3),
        // softened for YARN's resource-vector allocation: when everything
        // classifies bad, hold back only while the node's bottleneck
        // dimension is already past 75% — otherwise accept the least-bad
        // app so the cluster cannot sit idle under a pessimistic prior.
        good.or_else(|| {
            let bottleneck = node_feats
                .cpu_used
                .max(node_feats.mem_used)
                .max(node_feats.io_load)
                .max(node_feats.net_load);
            if bottleneck < 0.75 {
                (0..window).max_by(|&a, &b| res.p_good[a].total_cmp(&res.p_good[b]))
            } else {
                None
            }
        })
    }

    fn feedback(&mut self, feats: FeatureVec, label: Label) {
        self.classifier.observe(feats, label);
    }
}
