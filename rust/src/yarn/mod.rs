//! YARN-mode extension (paper §2): ResourceManager / NodeManager /
//! ApplicationMaster / Container simulation driven by the **same unified
//! [`crate::scheduler::Scheduler`] trait as the MRv1 JobTracker** — the
//! paper's Bayes contribution and every baseline run under both execution
//! modes without a parallel policy hierarchy, so results compare
//! apples-to-apples across modes.
//!
//! ## Migration note (old → new)
//!
//! The former `YarnPolicy` trait and its `YarnFifo` / `YarnFair` /
//! `YarnBayes` implementations are gone. [`SchedulerPolicy`] is the thin
//! adapter that runs any scheduler under the RM driver:
//!
//! | old                                   | new                                        |
//! |---------------------------------------|--------------------------------------------|
//! | `YarnPolicy::choose(reqs, free, ...)` | `Scheduler::assign(view, node, budget)`    |
//! | `YarnPolicy::feedback(feats, label)`  | `Scheduler::observe(SchedEvent::Feedback)` |
//! | `YarnFifo` / `YarnFair` / `YarnBayes` | `Fifo` / `Fair` / `BayesScheduler` via `yarn_policy_by_name` aliases |
//!
//! The key YARN-specific failure mode modeled here: containers are
//! allocated against **declared** resource demands, but jobs' **actual**
//! usage differs (users misdeclare). The RM's fit check can therefore be
//! satisfied while the node still melts — exactly the gap an overload-
//! feedback learner can close and a static fit check cannot.
//!
//! Simplifications vs real YARN (documented deviations):
//! * The AM itself does not occupy a container (it is control-plane only
//!   here); container allocation happens on NM heartbeats, as the real
//!   CapacityScheduler does.
//! * One container = one map/reduce task attempt.

pub mod policy;
pub mod rm;

pub use policy::SchedulerPolicy;
pub use rm::{yarn_policy_by_name, FailureConfig, ResourceManager, YarnConfig};
