//! YARN-mode extension (paper §2): ResourceManager / NodeManager /
//! ApplicationMaster / Container simulation, with the Bayes policy plugged
//! into the RM scheduler — showing the paper's algorithm generalizes from
//! MRv1 slots to YARN's resource-vector containers.
//!
//! The key YARN-specific failure mode modeled here: containers are
//! allocated against **declared** resource demands, but jobs' **actual**
//! usage differs (users misdeclare). The RM's fit check can therefore be
//! satisfied while the node still melts — exactly the gap an overload-
//! feedback learner can close and a static fit check cannot.
//!
//! Simplifications vs real YARN (documented deviations):
//! * The AM itself does not occupy a container (it is control-plane only
//!   here); container allocation happens on NM heartbeats, as the real
//!   CapacityScheduler does.
//! * One container = one map/reduce task attempt.

pub mod policy;
pub mod rm;

pub use policy::{YarnBayes, YarnFair, YarnFifo, YarnPolicy};
pub use rm::{yarn_policy_by_name, ResourceManager, YarnConfig};
