//! The ResourceManager driver: NM heartbeats, declared-fit container
//! allocation via the unified [`crate::scheduler::Scheduler`] trait
//! (through [`SchedulerPolicy`]), actual-demand contention on nodes,
//! overload feedback, AM lifecycle (register on job arrival, unregister on
//! completion — paper §2.3's application flow).
//!
//! Like the MRv1 JobTracker, the RM calls `assign` once per heartbeat with
//! the node's full free-container budget and feeds everything back through
//! `observe` — including the rich failure lifecycle (`TaskFailed`,
//! `NodeFailed`/`NodeRecovered`) and speculative backup launches, so every
//! scheduler behaves identically under both drivers. The YARN-specific
//! mechanics stay in the driver: requests are pre-filtered by the
//! **declared** fit, each proposed assignment is re-validated against the
//! running declared tally before launch, and the per-node container cap
//! truncates oversized batches. NodeManager failure injection mirrors the
//! JobTracker's (exponential MTBF/MTTR).

use crate::analysis::protocol::{AuditEvent, AuditSink};
use crate::bayes::classifier::Label;
use crate::bayes::features::{feature_vec, FailureHistory};
use crate::bayes::overload::OverloadRule;
use crate::cluster::heartbeat::HeartbeatConfig;
use crate::cluster::node::NodeId;
use crate::cluster::Cluster;
use crate::errors::Result;
use crate::hdfs::locality::{locality_multiplier, locality_net_demand};
use crate::hdfs::Namespace;
use crate::job::job::JobSpec;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef, TaskState};
use crate::job::JobId;
use crate::metrics::Metrics;
use crate::obs::{DriverObs, ObsOptions, Stopwatch};
use crate::scheduler::api::{
    Assignment, FailReason, OBS_EVENT_NAMES, SchedEvent, SchedView, SlotBudget,
};
use crate::sim::engine::{Engine, Time};
use crate::sim::event::Event;

pub use crate::coordinator::jobtracker::FailureConfig;

use super::policy::SchedulerPolicy;

/// YARN-mode knobs.
#[derive(Debug, Clone)]
pub struct YarnConfig {
    pub heartbeat: HeartbeatConfig,
    pub overload_rule: OverloadRule,
    /// NodeManager failure injection (exponential MTBF/MTTR), same model
    /// as the MRv1 tracker.
    pub failures: FailureConfig,
    /// Max concurrent containers per NM (control-plane cap). Effective
    /// concurrency is additionally bounded by the node's typed executor
    /// slots (`NodeSpec::map_slots`/`reduce_slots`) — the node substrate
    /// enforces them, so a cap above `map_slots + reduce_slots` has no
    /// extra effect. (The pre-redesign RM ignored typed slots, which
    /// violated `Node::add_task`'s slot invariant in debug builds.)
    pub max_containers_per_node: u32,
    /// Headroom factor on the declared-fit check (1.0 = strict fit).
    pub fit_headroom: f64,
    /// A task failing this many times kills its application.
    pub max_task_attempts: u32,
    pub max_sim_time: Time,
    /// Per-heartbeat queue-view cap (mirrors
    /// `TrackerConfig::queue_cap`): one heartbeat scores at most this
    /// many jobs, so scheduling work is O(cap) even with a deep backlog.
    pub queue_cap: usize,
    /// Recycle drained jobs' arena slots (mirrors
    /// `TrackerConfig::reclaim_jobs`) — required for O(active) memory on
    /// million-job streaming replays.
    pub reclaim_jobs: bool,
}

impl Default for YarnConfig {
    fn default() -> Self {
        YarnConfig {
            heartbeat: HeartbeatConfig::default(),
            overload_rule: OverloadRule::default(),
            failures: FailureConfig::default(),
            max_containers_per_node: 6,
            fit_headroom: 1.0,
            max_task_attempts: 4,
            max_sim_time: 1e7,
            queue_cap: usize::MAX,
            reclaim_jobs: false,
        }
    }
}

/// Deterministic per-job misdeclaration factor: actual = declared × factor.
/// Heavy classes under-declare more (the YARN failure mode we model).
pub fn actual_factor(job: &crate::job::job::Job) -> f64 {
    let phi = 0.618_033_988_749_894_9_f64;
    // keyed on the serial (submission number): stable under slot recycling
    let noise = (job.id.serial as f64 * phi).fract(); // [0,1), deterministic
    use crate::job::profile::JobClass::*;
    match job.spec.class {
        CpuHeavy | MemHeavy => 1.0 + 0.5 * noise, // up to 1.5x declared
        IoHeavy | NetHeavy => 0.9 + 0.35 * noise,
        Small => 0.8 + 0.3 * noise,
    }
}

/// Build a policy by name (see [`SchedulerPolicy::by_name`]).
pub fn yarn_policy_by_name(name: &str, alpha: f32) -> Result<SchedulerPolicy> {
    SchedulerPolicy::by_name(name, alpha)
}

struct PendingFeedback {
    feats: crate::bayes::features::FeatureVec,
}

/// Which live attempt of a task an event refers to (speculative backups
/// give a task up to two concurrent attempts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Attempt {
    Primary,
    Backup,
}

/// The RM: owns the whole YARN-mode simulation.
pub struct ResourceManager {
    pub engine: Engine,
    pub cluster: Cluster,
    pub hdfs: Namespace,
    pub jobs: JobTable,
    pub policy: SchedulerPolicy,
    pub metrics: Metrics,
    pub cfg: YarnConfig,
    /// Failure history feeding the failure-aware features (shared with the
    /// policy through `SchedView::failures`).
    pub failures: FailureHistory,
    /// Declared resource usage per node (fit-check bookkeeping — actual
    /// usage lives in the Node's contention state).
    declared: Vec<crate::cluster::resources::Resources>,
    /// Workload in submit-time order, drained into arrival events. A
    /// boxed iterator so streaming replays
    /// ([`ResourceManager::new_streaming`]) pull specs into existence
    /// one ahead of the virtual clock instead of materializing them all.
    pending_specs: Box<dyn Iterator<Item = JobSpec>>,
    /// Spec whose arrival event is in flight (submitted when it fires).
    next_spec: Option<JobSpec>,
    /// Scratch buffer for the per-heartbeat queue view (reused across
    /// heartbeats; capped at `cfg.queue_cap`).
    queue_scratch: Vec<JobId>,
    pending_feedback: Vec<Vec<PendingFeedback>>,
    /// OOM-doomed attempts, per node: excluded from completion
    /// rescheduling so their pending TaskFail stays valid (same per-node
    /// linear-scan layout as the MRv1 tracker — a node runs a handful of
    /// containers, so scanning beats hashing and never allocates).
    doomed: Vec<Vec<TaskRef>>,
    /// Launch-time feature rows of in-flight attempts, per node (OOM kills
    /// feed back a `Bad` sample for the row the decision was scored on).
    inflight_feats: Vec<Vec<(TaskRef, crate::bayes::features::FeatureVec)>>,
    /// Failure-injection RNG (own stream: does not perturb workloads).
    fail_rng: crate::sim::rng::Pcg,
    arrivals_done: bool,
    /// Protocol audit tap, mirroring the MRv1 tracker: shadow auditor in
    /// debug builds, disabled in release.
    pub audit: AuditSink,
    /// Observability tap, mirroring the MRv1 tracker: disabled (one
    /// `Option` check per use) until [`ResourceManager::enable_obs`].
    pub obs: DriverObs,
}

impl ResourceManager {
    pub fn new(
        cluster: Cluster,
        policy: SchedulerPolicy,
        mut specs: Vec<JobSpec>,
        seed: u64,
        cfg: YarnConfig,
    ) -> ResourceManager {
        specs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time));
        ResourceManager::new_streaming(
            cluster,
            policy,
            Box::new(specs.into_iter()),
            seed,
            cfg,
        )
    }

    /// Build an RM over a streaming workload (mirrors
    /// [`crate::coordinator::jobtracker::JobTracker::new_streaming`]):
    /// `specs` is pulled one job ahead of the virtual clock, so a
    /// million-job replay never holds more than one unsubmitted spec in
    /// memory. The iterator MUST yield specs in nondecreasing
    /// `submit_time` order (workload generators and saved traces
    /// qualify; an out-of-order spec would have its arrival clamped to
    /// `now` and counted in `engine.clamped_events()`).
    pub fn new_streaming(
        cluster: Cluster,
        policy: SchedulerPolicy,
        specs: Box<dyn Iterator<Item = JobSpec>>,
        seed: u64,
        cfg: YarnConfig,
    ) -> ResourceManager {
        let n = cluster.len();
        let hdfs =
            Namespace::new(cluster.topology.n_nodes, cluster.topology.n_racks, seed);
        let reclaim = cfg.reclaim_jobs;
        let mut rm = ResourceManager {
            engine: Engine::new(),
            cluster,
            hdfs,
            jobs: JobTable::new(),
            policy,
            metrics: Metrics::new(),
            cfg,
            failures: FailureHistory::new(),
            declared: vec![crate::cluster::resources::Resources::ZERO; n],
            pending_specs: specs,
            next_spec: None,
            queue_scratch: Vec::new(),
            pending_feedback: (0..n).map(|_| Vec::new()).collect(),
            doomed: vec![Vec::new(); n],
            inflight_feats: vec![Vec::new(); n],
            fail_rng: crate::sim::rng::Pcg::new(seed, 0xFA17),
            arrivals_done: false,
            audit: AuditSink::default_for_build(),
            obs: DriverObs::default(),
        };
        rm.jobs.set_reclaim(reclaim);
        rm.emit_preamble();
        rm.schedule_next_arrival();
        for node in rm.cluster.topology.all_nodes() {
            let t = rm.cfg.heartbeat.first_beat(node);
            rm.engine.schedule(t, Event::Heartbeat(node));
            rm.schedule_next_failure(node);
        }
        rm
    }

    fn schedule_next_failure(&mut self, node: NodeId) {
        if let Some(mtbf) = self.cfg.failures.mtbf {
            let dt = self.fail_rng.exp(1.0 / mtbf);
            self.engine.schedule_in(dt, Event::NodeFail(node));
        }
    }

    /// Feed one scheduler-visible event through the audit tap and then to
    /// the policy. Every `SchedEvent` the RM produces MUST go through here.
    fn emit(&mut self, ev: SchedEvent) {
        self.audit.sched(&ev);
        self.obs.on_event(ev.obs_index(), ev.obs_name(), self.engine.now());
        self.policy.observe(&ev);
    }

    /// Audit preamble (node capacities + cluster info); the `ClusterInfo`
    /// half is also the policy's contractual startup notification.
    fn emit_preamble(&mut self) {
        for n in &self.cluster.nodes {
            self.audit.push(AuditEvent::NodeSpec {
                node: n.id,
                maps: n.spec.map_slots,
                reduces: n.spec.reduce_slots,
            });
        }
        self.emit(SchedEvent::ClusterInfo { total_slots: self.cluster.total_slots() });
    }

    /// Swap in an audit sink before `run()`; the preamble is replayed into
    /// it (the policy does NOT re-observe it).
    pub fn set_audit(&mut self, mut sink: AuditSink) {
        for n in &self.cluster.nodes {
            sink.push(AuditEvent::NodeSpec {
                node: n.id,
                maps: n.spec.map_slots,
                reduces: n.spec.reduce_slots,
            });
        }
        sink.push(AuditEvent::Sched(SchedEvent::ClusterInfo {
            total_slots: self.cluster.total_slots(),
        }));
        self.audit = sink;
    }

    /// Switch on the observability layer (mirrors
    /// `JobTracker::enable_obs`). Call before `run()`.
    pub fn enable_obs(&mut self, opts: &ObsOptions) {
        let registry = self.obs.enable(opts, &OBS_EVENT_NAMES);
        self.policy.install_obs(&registry);
        self.metrics.install_obs(&registry);
    }

    /// Drain engine counters into gauges and write the requested exporter
    /// files. Call after `run()`; a no-op when obs was never enabled.
    pub fn finish_obs(&mut self, opts: &ObsOptions) -> Result<()> {
        if let Some((registry, tracer, windows)) = self.obs.finish(self.engine.now()) {
            registry.gauge("engine_events_dispatched").set(self.engine.processed());
            registry.gauge("engine_clamped_events").set(self.engine.clamped_events());
            registry.gauge("engine_bucket_scan_steps").set(self.engine.scan_steps());
            crate::obs::export::write_all(opts, &registry, &tracer, &windows)?;
        }
        Ok(())
    }

    fn schedule_next_arrival(&mut self) {
        match self.pending_specs.next() {
            Some(spec) => {
                let at = spec.submit_time;
                self.next_spec = Some(spec);
                self.engine.schedule(at, Event::JobArrival);
            }
            None => self.arrivals_done = true,
        }
    }

    /// AM registration == job enters the table when its arrival fires
    /// (paper §2.3 steps 1-3 collapsed to one control-plane event).
    fn on_job_arrival(&mut self) {
        if let Some(spec) = self.next_spec.take() {
            let id = self.jobs.submit(spec, &mut self.hdfs);
            self.audit.push(AuditEvent::JobArrived { job: id });
        }
        self.schedule_next_arrival();
    }

    /// Run to completion; returns makespan.
    pub fn run(&mut self) -> Time {
        while let Some((t, ev)) = self.engine.pop() {
            if t > self.cfg.max_sim_time {
                break;
            }
            // close any window boundaries the clock just crossed; reads
            // only, so the sim stays bit-identical with obs on
            self.obs.window_tick(t);
            match ev {
                Event::JobArrival => self.on_job_arrival(),
                Event::Heartbeat(node) => self.on_heartbeat(node),
                Event::TaskComplete { node, task, generation } => {
                    self.on_complete(node, task, generation)
                }
                Event::TaskFail { node, task, generation } => {
                    self.on_fail(node, task, generation)
                }
                Event::NodeFail(node) => self.on_node_fail(node),
                Event::NodeRecover(node) => self.on_node_recover(node),
                Event::MetricsTick => {}
            }
            if self.arrivals_done
                && self.jobs.all_complete()
                && !self.jobs.is_empty()
                && self.cluster.nodes.iter().all(|n| n.running().is_empty())
            {
                break;
            }
        }
        self.metrics.overload_seconds =
            self.cluster.nodes.iter().map(|n| n.overload_seconds).sum();
        self.metrics.oom_kills =
            self.cluster.nodes.iter().map(|n| n.oom_kills as u64).sum();
        self.metrics.makespan
    }

    /// Declared headroom left on a node under the fit-check policy.
    fn headroom(&self, node_id: NodeId) -> crate::cluster::resources::Resources {
        let cap = self.cluster.node(node_id).spec.capacity;
        let mut h =
            cap.scale(self.cfg.fit_headroom) - self.declared[node_id.0 as usize];
        h.clamp_non_negative();
        h
    }

    // --------------------------------------------------------- attempts --

    fn doom_insert(&mut self, node: NodeId, tref: TaskRef) {
        self.doomed[node.0 as usize].push(tref);
    }

    fn doom_remove(&mut self, node: NodeId, tref: &TaskRef) {
        self.doomed[node.0 as usize].retain(|t| t != tref);
    }

    fn doom_contains(&self, node: NodeId, tref: &TaskRef) -> bool {
        self.doomed[node.0 as usize].contains(tref)
    }

    fn feats_insert(
        &mut self,
        node: NodeId,
        tref: TaskRef,
        feats: crate::bayes::features::FeatureVec,
    ) {
        self.inflight_feats[node.0 as usize].push((tref, feats));
    }

    fn feats_remove(
        &mut self,
        node: NodeId,
        tref: &TaskRef,
    ) -> Option<crate::bayes::features::FeatureVec> {
        let v = &mut self.inflight_feats[node.0 as usize];
        let i = v.iter().position(|(t, _)| t == tref)?;
        Some(v.swap_remove(i).1)
    }

    fn current_attempt(
        &self,
        tref: &TaskRef,
        node: NodeId,
        generation: u32,
    ) -> Option<Attempt> {
        // a released (reclaimed) job makes every in-flight event stale
        let task = self.jobs.try_get(tref.job)?.task(tref);
        if let TaskState::Running { node: n, .. } = task.state {
            if n == node && task.generation == generation {
                return Some(Attempt::Primary);
            }
        }
        if let Some(s) = task.speculative {
            if s.node == node && task.spec_generation == generation {
                return Some(Attempt::Backup);
            }
        }
        None
    }

    /// `JobCompleted` (AM unregistration) only once the job's last attempt
    /// has drained — the contract that lets schedulers drop per-job state.
    fn notify_if_drained(&mut self, id: JobId) {
        let Some(job) = self.jobs.try_get(id) else { return };
        if job.finish_time.is_some() && job.fully_drained() {
            self.emit(SchedEvent::JobCompleted { job: id });
            self.failures.forget_job(id);
            // recycle the arena slot (no-op unless reclamation is enabled)
            self.jobs.release(id);
        }
    }

    /// Remove the losing copy of `tref` from `node_id` after the other
    /// copy won (reported as `TaskFinished`, not a failure).
    fn cancel_attempt_on(&mut self, node_id: NodeId, tref: TaskRef, now: Time) {
        let horizons = self.release(&tref, node_id, now);
        self.doom_remove(node_id, &tref);
        self.feats_remove(node_id, &tref);
        self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
        self.emit(SchedEvent::TaskFinished {
            job: tref.job,
            node: node_id,
            kind: tref.kind,
        });
        self.reschedule(node_id, horizons);
    }

    // ---------------------------------------------------------- failure --

    fn on_node_fail(&mut self, node_id: NodeId) {
        if !self.cluster.node(node_id).alive {
            return;
        }
        let now = self.engine.now();
        self.metrics.node_failures += 1;
        let lost = self.cluster.node_mut(node_id).fail(now);
        for rec in lost {
            let tref = rec.task;
            self.doom_remove(node_id, &tref);
            self.feats_remove(node_id, &tref);
            self.failures.record_failure(tref.job, node_id, now);
            self.metrics.task_failures += 1;
            let task = self.jobs.get(tref.job).task(&tref);
            let attempt = task.attempts;
            let lost_backup =
                task.speculative.is_some_and(|s| s.node == node_id);
            let surviving_backup = !lost_backup && task.speculative.is_some();
            self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
            self.emit(SchedEvent::TaskFailed {
                job: tref.job,
                node: node_id,
                kind: tref.kind,
                attempt,
                reason: FailReason::NodeLost,
            });
            if lost_backup {
                self.jobs.get_mut(tref.job).task_mut(&tref).cancel_speculative();
            } else if surviving_backup {
                self.jobs.get_mut(tref.job).task_mut(&tref).promote_speculative();
            } else if self.jobs.get(tref.job).finish_time.is_none() {
                self.jobs.requeue_task(&tref);
            } else {
                self.jobs.get_mut(tref.job).task_mut(&tref).requeue();
            }
            self.notify_if_drained(tref.job);
        }
        // every container on the node is gone: declared tally resets
        self.declared[node_id.0 as usize] =
            crate::cluster::resources::Resources::ZERO;
        self.pending_feedback[node_id.0 as usize].clear();
        self.emit(SchedEvent::NodeFailed { node: node_id });
        let mttr = self.cfg.failures.mttr.max(1.0);
        let dt = self.fail_rng.exp(1.0 / mttr);
        self.engine.schedule_in(dt, Event::NodeRecover(node_id));
    }

    fn on_node_recover(&mut self, node_id: NodeId) {
        let now = self.engine.now();
        self.cluster.node_mut(node_id).recover(now);
        self.emit(SchedEvent::NodeRecovered { node: node_id });
        self.engine
            .schedule(self.cfg.heartbeat.next_beat(now), Event::Heartbeat(node_id));
        self.schedule_next_failure(node_id);
    }

    // -------------------------------------------------------- heartbeat --

    fn on_heartbeat(&mut self, node_id: NodeId) {
        if !self.cluster.node(node_id).alive {
            return; // dead NM: heartbeats resume on recovery
        }
        let now = self.engine.now();
        let hb_sw = self.obs.is_enabled().then(Stopwatch::start);
        self.metrics.heartbeats += 1;
        self.cluster.node_mut(node_id).advance(now);

        // feedback from allocations since last beat
        let pend = std::mem::take(&mut self.pending_feedback[node_id.0 as usize]);
        if !pend.is_empty() {
            let obs = self.cluster.node(node_id).observation();
            let label = self.cfg.overload_rule.label(&obs);
            for p in pend {
                self.emit(SchedEvent::Feedback { feats: p.feats, label });
                self.metrics.record_feedback(label);
            }
        }

        // one batched assignment per heartbeat, like the MRv1 tracker.
        // The per-kind budget respects the node's typed executor slots;
        // the free-container count additionally caps the whole batch
        // (containers themselves are not slot-typed).
        let free_containers = self
            .cfg
            .max_containers_per_node
            .saturating_sub(self.cluster.node(node_id).running().len() as u32);
        if free_containers > 0 {
            // requests that fit the free *declared* headroom right now —
            // the (possibly capped) queue view reuses the scratch buffer,
            // so a warm heartbeat allocates nothing
            let headroom = self.headroom(node_id);
            let mut queue = std::mem::take(&mut self.queue_scratch);
            self.jobs.schedulable_prefix(self.cfg.queue_cap, &mut queue);
            queue.retain(|id| self.jobs.get(*id).demand.fits_within(&headroom));
            let node_feats = self.cluster.node(node_id).features();
            let (budget, node_total_slots) = {
                let node = self.cluster.node(node_id);
                (
                    SlotBudget {
                        maps: free_containers.min(node.free_slots(TaskKind::Map)),
                        reduces: free_containers
                            .min(node.free_slots(TaskKind::Reduce)),
                    },
                    node.spec.map_slots + node.spec.reduce_slots,
                )
            };
            if budget.total() > 0 {
                let (assignments, assign_nanos) = {
                    let view = SchedView {
                        jobs: &self.jobs,
                        hdfs: &self.hdfs,
                        queue: &queue,
                        failures: &self.failures,
                        now,
                    };
                    let node = self.cluster.node(node_id);
                    // real (not virtual) time: the policy's own compute
                    // cost for E6
                    let sw = Stopwatch::start();
                    let out = self.policy.assign(&view, node, budget);
                    (out, sw.elapsed_nanos())
                };
                let mut remaining = free_containers;
                let mut launched = 0usize;
                for a in assignments {
                    if remaining == 0 {
                        break; // container cap truncates the batch
                    }
                    // re-validate: earlier launches in this batch consumed
                    // declared headroom and typed slots
                    let declared = self.jobs.get(a.task.job).demand;
                    if !declared.fits_within(&self.headroom(node_id)) {
                        continue;
                    }
                    if self.cluster.node(node_id).free_slots(a.task.kind) == 0 {
                        debug_assert!(false, "batch overflowed slots: {}", a.task);
                        continue;
                    }
                    if a.decision.speculative {
                        if !self.speculation_target_ok(&a.task, node_id) {
                            debug_assert!(
                                false,
                                "broken speculative proposal: {}",
                                a.task
                            );
                            continue;
                        }
                        self.launch_container(a, node_id, now, &node_feats, true);
                    } else {
                        if !self.jobs.get(a.task.job).task(&a.task).is_pending() {
                            debug_assert!(
                                false,
                                "batch contract broken: {}",
                                a.task
                            );
                            continue;
                        }
                        self.launch_container(a, node_id, now, &node_feats, false);
                    }
                    remaining -= 1;
                    launched += 1;
                }
                // metrics count launched containers, not proposals — the
                // container cap and the fit re-check may drop proposals
                self.metrics.record_assign(assign_nanos, launched);
                if self.obs.is_enabled() {
                    let total = u64::from(node_total_slots);
                    let free = u64::from(budget.total());
                    let util_pct =
                        if total == 0 { 0 } else { (total - free) * 100 / total };
                    self.obs.record_assign(
                        now,
                        assign_nanos,
                        launched,
                        queue.len(),
                        util_pct,
                    );
                }
            }
            self.queue_scratch = queue;
        }

        if !self.arrivals_done || !self.jobs.all_complete() {
            self.engine
                .schedule(self.cfg.heartbeat.next_beat(now), Event::Heartbeat(node_id));
        }
        if let Some(sw) = hb_sw {
            self.obs.record_heartbeat(now, sw.elapsed_nanos());
        }
    }

    /// Speculation contract: primary running on a *different* node, no
    /// live backup, job still live.
    fn speculation_target_ok(&self, tref: &TaskRef, node_id: NodeId) -> bool {
        let job = self.jobs.get(tref.job);
        if job.finish_time.is_some() {
            return false;
        }
        let task = job.task(tref);
        task.speculative.is_none()
            && matches!(task.state, TaskState::Running { node: n, .. } if n != node_id)
    }

    fn launch_container(
        &mut self,
        assignment: Assignment,
        node_id: NodeId,
        now: Time,
        node_feats: &crate::bayes::features::NodeFeatures,
        speculative: bool,
    ) {
        let tref = assignment.task;
        let job = self.jobs.get(tref.job);
        let declared = job.demand;
        // actual usage diverges from declared (misdeclaration model)
        let mut actual = declared.scale(actual_factor(job));
        let mut work = job.task(&tref).work;
        if tref.kind == TaskKind::Map {
            // submit() assigns every map a block -- lint: allow(unwrap-in-lib)
            let block = job.task(&tref).block.unwrap();
            let loc = self.hdfs.locality(block, node_id);
            self.metrics.record_locality(loc);
            work *= locality_multiplier(loc);
            actual.net += locality_net_demand(loc);
        } else {
            actual.net += 0.05;
        }
        actual.clamp_non_negative();

        let fail = self.failures.feats_for(tref.job, node_id, now);
        let feats = feature_vec(&job.spec.profile, node_feats, fail);
        self.pending_feedback[node_id.0 as usize].push(PendingFeedback { feats });
        self.feats_insert(node_id, tref, feats);

        let dooms = self.cluster.node(node_id).would_oom(&actual);
        let generation = if speculative {
            self.jobs.start_speculative(&tref, node_id, now);
            self.metrics.speculative_launches += 1;
            self.jobs.get(tref.job).task(&tref).spec_generation
        } else {
            self.jobs.start_task(&tref, node_id, now);
            self.jobs.get(tref.job).task(&tref).generation
        };
        self.audit.push(AuditEvent::Launched {
            task: tref,
            node: node_id,
            speculative,
            feats,
        });
        self.emit(SchedEvent::TaskStarted {
            job: tref.job,
            node: node_id,
            kind: tref.kind,
        });
        self.metrics
            .record_trace(now, node_id, tref, assignment.decision);
        self.declared[node_id.0 as usize] += declared;
        let horizons =
            self.cluster.node_mut(node_id).add_task(tref, actual, work, now);
        if dooms {
            self.cluster.node_mut(node_id).oom_kills += 1;
            self.doom_insert(node_id, tref);
            self.engine.schedule(
                now + 4.0,
                Event::TaskFail { node: node_id, task: tref, generation },
            );
        }
        self.reschedule(node_id, horizons);
    }

    /// Re-issue completion events for every attempt on a node with fresh
    /// per-attempt stamps (doomed attempts keep their pending TaskFail).
    fn reschedule(&mut self, node_id: NodeId, horizons: Vec<(TaskRef, Time)>) {
        for (tref, at) in horizons {
            if self.doom_contains(node_id, &tref) {
                continue;
            }
            let task = self.jobs.get_mut(tref.job).task_mut(&tref);
            let stamp = task.next_stamp();
            let on_primary =
                matches!(task.state, TaskState::Running { node: n, .. } if n == node_id);
            if on_primary {
                task.generation = stamp;
            } else if task.speculative.is_some_and(|s| s.node == node_id) {
                task.spec_generation = stamp;
            } else {
                debug_assert!(false, "rescheduling {tref} which is not on {node_id}");
                continue;
            }
            self.engine.schedule(
                at,
                Event::TaskComplete { node: node_id, task: tref, generation: stamp },
            );
        }
    }

    /// Remove one attempt from a node, returning the declared resources
    /// and the surviving tasks' new horizons.
    fn release(&mut self, tref: &TaskRef, node_id: NodeId, now: Time) -> Vec<(TaskRef, Time)> {
        self.cluster.node_mut(node_id).advance(now);
        let (_rec, horizons) = self.cluster.node_mut(node_id).remove_task(tref, now);
        let declared = self.jobs.get(tref.job).demand;
        let slot = &mut self.declared[node_id.0 as usize];
        *slot -= declared;
        slot.clamp_non_negative();
        horizons
    }

    fn on_complete(&mut self, node_id: NodeId, tref: TaskRef, generation: u32) {
        let Some(which) = self.current_attempt(&tref, node_id, generation) else {
            return;
        };
        let now = self.engine.now();
        let horizons = self.release(&tref, node_id, now);
        self.doom_remove(node_id, &tref);
        self.feats_remove(node_id, &tref);
        match which {
            Attempt::Primary => {
                if let Some(s) = self.jobs.get(tref.job).task(&tref).speculative {
                    self.cancel_attempt_on(s.node, tref, now);
                    self.jobs.get_mut(tref.job).task_mut(&tref).cancel_speculative();
                }
            }
            Attempt::Backup => {
                self.metrics.speculative_wins += 1;
                let pnode = match self.jobs.get(tref.job).task(&tref).state {
                    TaskState::Running { node, .. } => node,
                    _ => unreachable!("backup without running primary"),
                };
                self.cancel_attempt_on(pnode, tref, now);
                self.jobs.get_mut(tref.job).task_mut(&tref).promote_speculative();
            }
        }
        self.jobs.complete_task(&tref, now);
        self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
        self.emit(SchedEvent::TaskFinished {
            job: tref.job,
            node: node_id,
            kind: tref.kind,
        });
        let job = self.jobs.get(tref.job);
        let finished = !job.failed && job.is_complete();
        if finished {
            // AM unregisters (paper §2.3 final step)
            self.jobs.mark_complete(tref.job, now);
            // Some by construction: mark_complete just set finish_time
            // lint: allow(unwrap-in-lib)
            let outcome = self.jobs.get(tref.job).outcome().unwrap();
            self.metrics.record_outcome(outcome);
        }
        self.notify_if_drained(tref.job);
        self.reschedule(node_id, horizons);
    }

    fn on_fail(&mut self, node_id: NodeId, tref: TaskRef, generation: u32) {
        let Some(which) = self.current_attempt(&tref, node_id, generation) else {
            return;
        };
        let now = self.engine.now();
        let horizons = self.release(&tref, node_id, now);
        self.doom_remove(node_id, &tref);
        self.failures.record_failure(tref.job, node_id, now);
        self.metrics.task_failures += 1;
        self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
        if let Some(feats) = self.feats_remove(node_id, &tref) {
            self.emit(SchedEvent::Feedback { feats, label: Label::Bad });
            self.metrics.record_feedback(Label::Bad);
        }
        self.jobs.get_mut(tref.job).task_mut(&tref).failed_attempts += 1;
        let attempt = self.jobs.get(tref.job).task(&tref).attempts;
        self.emit(SchedEvent::TaskFailed {
            job: tref.job,
            node: node_id,
            kind: tref.kind,
            attempt,
            reason: FailReason::Oom,
        });
        let other_alive = match which {
            Attempt::Backup => true,
            Attempt::Primary => {
                self.jobs.get(tref.job).task(&tref).speculative.is_some()
            }
        };
        if other_alive {
            match which {
                Attempt::Backup => {
                    self.jobs.get_mut(tref.job).task_mut(&tref).cancel_speculative();
                }
                Attempt::Primary => {
                    self.jobs.get_mut(tref.job).task_mut(&tref).promote_speculative();
                }
            }
        } else {
            self.jobs.requeue_task(&tref);
            let job = self.jobs.get(tref.job);
            // kill on FAILED attempts, not launches (speculative copies
            // and node losses must not erode the budget)
            let kill = job.task(&tref).failed_attempts
                >= self.cfg.max_task_attempts
                && job.finish_time.is_none();
            if kill {
                self.jobs.mark_failed(tref.job, now);
                self.metrics.failed_jobs += 1;
            }
        }
        self.notify_if_drained(tref.job);
        self.reschedule(node_id, horizons);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::{generate, WorkloadConfig};

    fn run(policy: &str, seed: u64) -> ResourceManager {
        let cluster = Cluster::homogeneous(6, 2);
        let specs = generate(&WorkloadConfig {
            n_jobs: 12,
            arrival_rate: 1.0,
            seed,
            ..Default::default()
        });
        let mut rm = ResourceManager::new(
            cluster,
            yarn_policy_by_name(policy, 1.0).unwrap(),
            specs,
            seed,
            YarnConfig::default(),
        );
        rm.run();
        rm
    }

    #[test]
    fn all_policies_complete_workload() {
        for p in ["yarn-fifo", "yarn-fair", "yarn-bayes"] {
            let rm = run(p, 1);
            assert!(rm.jobs.all_complete(), "{p} left jobs unfinished");
            // jobs either succeed or are killed after max attempts
            assert_eq!(
                rm.metrics.completed_jobs() + rm.jobs.failed_count(),
                12,
                "{p}"
            );
            // the bulk of the workload must still succeed
            assert!(rm.metrics.completed_jobs() >= 8, "{p}");
        }
    }

    #[test]
    fn any_mrv1_scheduler_runs_under_the_rm() {
        // the unified-trait payoff: every by_name scheduler drives YARN mode
        for p in crate::scheduler::ALL_NAMES {
            let rm = run(p, 3);
            assert!(rm.jobs.all_complete(), "{p} stalled under the RM");
        }
    }

    #[test]
    fn deterministic() {
        let a = run("yarn-bayes", 5);
        let b = run("yarn-bayes", 5);
        assert_eq!(a.metrics.makespan, b.metrics.makespan);
        assert_eq!(a.engine.processed(), b.engine.processed());
    }

    #[test]
    fn declared_bookkeeping_returns_to_zero() {
        let rm = run("yarn-fifo", 2);
        for d in &rm.declared {
            assert!(d.max_component() < 1e-9, "leaked declared resources {d:?}");
        }
        for n in &rm.cluster.nodes {
            assert!(n.running().is_empty());
        }
    }

    #[test]
    fn declared_bookkeeping_survives_node_churn() {
        let cluster = Cluster::homogeneous(6, 2);
        let specs = generate(&WorkloadConfig {
            n_jobs: 15,
            arrival_rate: 1.0,
            seed: 8,
            ..Default::default()
        });
        let mut rm = ResourceManager::new(
            cluster,
            yarn_policy_by_name("yarn-bayes", 1.0).unwrap(),
            specs,
            8,
            YarnConfig {
                failures: FailureConfig { mtbf: Some(250.0), mttr: 40.0 },
                ..Default::default()
            },
        );
        rm.run();
        assert!(rm.metrics.node_failures > 0, "no failures injected");
        assert!(rm.jobs.all_complete(), "churn stalled the RM");
        for d in &rm.declared {
            assert!(d.max_component() < 1e-9, "leaked declared resources {d:?}");
        }
        for n in &rm.cluster.nodes {
            assert!(n.running().is_empty());
        }
    }

    #[test]
    fn tiny_container_cap_still_drains() {
        let cluster = Cluster::homogeneous(3, 1);
        let specs = generate(&WorkloadConfig {
            n_jobs: 8,
            arrival_rate: 2.0,
            seed: 9,
            ..Default::default()
        });
        let mut tight = ResourceManager::new(
            cluster,
            yarn_policy_by_name("yarn-fifo", 1.0).unwrap(),
            specs,
            9,
            YarnConfig { max_containers_per_node: 1, ..Default::default() },
        );
        tight.run();
        assert!(tight.jobs.all_complete());
        for n in &tight.cluster.nodes {
            assert!(n.running().is_empty());
        }
    }

    #[test]
    fn unknown_policy_rejected() {
        assert!(yarn_policy_by_name("nope", 1.0).is_err());
    }

    #[test]
    fn streaming_replay_reclaims_job_slots() {
        let cluster = Cluster::homogeneous(6, 2);
        let cfg = WorkloadConfig {
            n_jobs: 20,
            arrival_rate: 0.5,
            seed: 11,
            ..Default::default()
        };
        let mut rm = ResourceManager::new_streaming(
            cluster,
            yarn_policy_by_name("yarn-fifo", 1.0).unwrap(),
            Box::new(crate::workload::generator::stream(&cfg)),
            11,
            YarnConfig { queue_cap: 64, reclaim_jobs: true, ..Default::default() },
        );
        rm.run();
        assert!(rm.jobs.all_complete(), "streamed workload must drain");
        assert_eq!(rm.metrics.completed_jobs() + rm.jobs.failed_count(), 20);
        // reclamation keeps the arena at O(active), not O(submitted)
        assert!(
            rm.jobs.resident() < 20,
            "resident {} should shrink below the 20 submitted jobs",
            rm.jobs.resident()
        );
        assert!(rm.jobs.peak_active() <= 20);
    }
}
