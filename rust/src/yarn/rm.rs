//! The ResourceManager driver: NM heartbeats, declared-fit container
//! allocation via the pluggable policy, actual-demand contention on nodes,
//! overload feedback, AM lifecycle (register on job arrival, unregister on
//! completion — paper §2.3's application flow).

use crate::errors::{anyhow, Result};

use crate::bayes::features::feature_vec;
use crate::bayes::overload::OverloadRule;
use crate::cluster::heartbeat::HeartbeatConfig;
use crate::cluster::node::NodeId;
use crate::cluster::Cluster;
use crate::hdfs::locality::{locality_multiplier, locality_net_demand};
use crate::hdfs::Namespace;
use crate::job::job::JobSpec;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef, TaskState};
use crate::metrics::Metrics;
use crate::sim::engine::{Engine, Time};
use crate::sim::event::Event;

use super::policy::{AppRequest, YarnPolicy};

/// YARN-mode knobs.
#[derive(Debug, Clone)]
pub struct YarnConfig {
    pub heartbeat: HeartbeatConfig,
    pub overload_rule: OverloadRule,
    /// Max concurrent containers per NM (control-plane cap).
    pub max_containers_per_node: u32,
    /// Headroom factor on the declared-fit check (1.0 = strict fit).
    pub fit_headroom: f64,
    /// A task failing this many times kills its application.
    pub max_task_attempts: u32,
    pub max_sim_time: Time,
}

impl Default for YarnConfig {
    fn default() -> Self {
        YarnConfig {
            heartbeat: HeartbeatConfig::default(),
            overload_rule: OverloadRule::default(),
            max_containers_per_node: 6,
            fit_headroom: 1.0,
            max_task_attempts: 4,
            max_sim_time: 1e7,
        }
    }
}

/// Deterministic per-job misdeclaration factor: actual = declared × factor.
/// Heavy classes under-declare more (the YARN failure mode we model).
pub fn actual_factor(job: &crate::job::job::Job) -> f64 {
    let phi = 0.618_033_988_749_894_9_f64;
    let noise = (job.id.0 as f64 * phi).fract(); // [0,1), deterministic
    use crate::job::profile::JobClass::*;
    match job.spec.class {
        CpuHeavy | MemHeavy => 1.0 + 0.5 * noise, // up to 1.5x declared
        IoHeavy | NetHeavy => 0.9 + 0.35 * noise,
        Small => 0.8 + 0.3 * noise,
    }
}

/// Build a policy by name.
pub fn yarn_policy_by_name(name: &str, alpha: f32) -> Result<Box<dyn YarnPolicy>> {
    match name {
        "yarn-fifo" => Ok(Box::new(super::policy::YarnFifo)),
        "yarn-fair" => Ok(Box::new(super::policy::YarnFair)),
        "yarn-bayes" => Ok(Box::new(super::policy::YarnBayes::new(alpha))),
        _ => Err(anyhow!("unknown yarn policy '{name}'")),
    }
}

struct PendingFeedback {
    feats: crate::bayes::features::FeatureVec,
}

/// The RM: owns the whole YARN-mode simulation.
pub struct ResourceManager {
    pub engine: Engine,
    pub cluster: Cluster,
    pub hdfs: Namespace,
    pub jobs: JobTable,
    pub policy: Box<dyn YarnPolicy>,
    pub metrics: Metrics,
    pub cfg: YarnConfig,
    /// Declared resource usage per node (fit-check bookkeeping — actual
    /// usage lives in the Node's contention state).
    declared: Vec<crate::cluster::resources::Resources>,
    pending_specs: std::vec::IntoIter<JobSpec>,
    /// Spec whose arrival event is in flight (submitted when it fires).
    next_spec: Option<JobSpec>,
    pending_feedback: Vec<Vec<PendingFeedback>>,
    /// OOM-doomed tasks: excluded from completion rescheduling so their
    /// pending TaskFail stays valid (same mechanism as the MRv1 tracker).
    doomed: std::collections::HashSet<TaskRef>,
    arrivals_done: bool,
}

impl ResourceManager {
    pub fn new(
        cluster: Cluster,
        policy: Box<dyn YarnPolicy>,
        mut specs: Vec<JobSpec>,
        seed: u64,
        cfg: YarnConfig,
    ) -> ResourceManager {
        specs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time));
        let n = cluster.len();
        let hdfs =
            Namespace::new(cluster.topology.n_nodes, cluster.topology.n_racks, seed);
        let mut rm = ResourceManager {
            engine: Engine::new(),
            cluster,
            hdfs,
            jobs: JobTable::new(),
            policy,
            metrics: Metrics::new(),
            cfg,
            declared: vec![crate::cluster::resources::Resources::ZERO; n],
            pending_specs: specs.into_iter(),
            next_spec: None,
            pending_feedback: (0..n).map(|_| Vec::new()).collect(),
            doomed: std::collections::HashSet::new(),
            arrivals_done: false,
        };
        rm.schedule_next_arrival();
        for node in rm.cluster.topology.all_nodes() {
            let t = rm.cfg.heartbeat.first_beat(node);
            rm.engine.schedule(t, Event::Heartbeat(node));
        }
        rm
    }

    fn schedule_next_arrival(&mut self) {
        match self.pending_specs.next() {
            Some(spec) => {
                let at = spec.submit_time;
                self.next_spec = Some(spec);
                self.engine
                    .schedule(at, Event::JobArrival(crate::job::JobId(u32::MAX)));
            }
            None => self.arrivals_done = true,
        }
    }

    /// AM registration == job enters the table when its arrival fires
    /// (paper §2.3 steps 1-3 collapsed to one control-plane event).
    fn on_job_arrival(&mut self) {
        if let Some(spec) = self.next_spec.take() {
            self.jobs.submit(spec, &mut self.hdfs);
        }
        self.schedule_next_arrival();
    }

    /// Run to completion; returns makespan.
    pub fn run(&mut self) -> Time {
        while let Some((t, ev)) = self.engine.pop() {
            if t > self.cfg.max_sim_time {
                break;
            }
            match ev {
                Event::JobArrival(_) => self.on_job_arrival(),
                Event::Heartbeat(node) => self.on_heartbeat(node),
                Event::TaskComplete { node, task, generation } => {
                    self.on_complete(node, task, generation)
                }
                Event::TaskFail { node, task, generation } => {
                    self.on_fail(node, task, generation)
                }
                _ => {}
            }
            if self.arrivals_done
                && self.jobs.all_complete()
                && !self.jobs.is_empty()
                && self.cluster.nodes.iter().all(|n| n.running().is_empty())
            {
                break;
            }
        }
        self.metrics.overload_seconds =
            self.cluster.nodes.iter().map(|n| n.overload_seconds).sum();
        self.metrics.oom_kills =
            self.cluster.nodes.iter().map(|n| n.oom_kills as u64).sum();
        self.metrics.makespan
    }

    fn on_heartbeat(&mut self, node_id: NodeId) {
        let now = self.engine.now();
        self.metrics.heartbeats += 1;
        self.cluster.node_mut(node_id).advance(now);

        // feedback from allocations since last beat
        let pend = std::mem::take(&mut self.pending_feedback[node_id.0 as usize]);
        if !pend.is_empty() {
            let obs = self.cluster.node(node_id).observation();
            let label = self.cfg.overload_rule.label(&obs);
            for p in pend {
                self.policy.feedback(p.feats, label);
                self.metrics.record_feedback(label);
            }
        }

        // allocate containers while requests fit (declared) and caps allow
        loop {
            let node = self.cluster.node(node_id);
            if node.running().len() as u32 >= self.cfg.max_containers_per_node {
                break;
            }
            let cap = node.spec.capacity;
            let free = (cap.scale(self.cfg.fit_headroom)) - self.declared[node_id.0 as usize];
            let queue = self.jobs.schedulable();
            // requests that fit the free declared headroom
            let reqs: Vec<AppRequest> = queue
                .iter()
                .map(|id| self.jobs.get(*id))
                .filter(|j| {
                    j.has_schedulable_task() && j.demand.fits_within(&free)
                })
                .map(|j| AppRequest {
                    app: j.id,
                    job: j,
                    declared: j.demand,
                    running: j.running_tasks() as u32,
                })
                .collect();
            if reqs.is_empty() {
                break;
            }
            let node_feats = self.cluster.node(node_id).features();
            let t0 = std::time::Instant::now();
            let choice = self.policy.choose(&reqs, free, &node_feats, now);
            self.metrics.record_decision(t0.elapsed().as_nanos());
            let Some(idx) = choice else { break };
            let app = reqs[idx].app;
            // container -> concrete task (locality-first, like MRv1 path)
            let job = self.jobs.get(app);
            let kind = if job.pending_maps() > 0 {
                TaskKind::Map
            } else {
                TaskKind::Reduce
            };
            // the container cap is not the only limit: the node's typed
            // executor slots must also be free (Node::add_task enforces
            // this with a debug assertion)
            if self.cluster.node(node_id).free_slots(kind) == 0 {
                break;
            }
            let Some(tref) =
                crate::scheduler::api::pick_task(job, self.cluster.node(node_id), &self.hdfs, kind)
            else {
                break;
            };
            self.launch_container(tref, node_id, now);
        }

        if !self.arrivals_done || !self.jobs.all_complete() {
            self.engine
                .schedule(self.cfg.heartbeat.next_beat(now), Event::Heartbeat(node_id));
        }
    }

    fn launch_container(&mut self, tref: TaskRef, node_id: NodeId, now: Time) {
        let job = self.jobs.get(tref.job);
        let declared = job.demand;
        // actual usage diverges from declared (misdeclaration model)
        let mut actual = declared.scale(actual_factor(job));
        let mut work = job.task(&tref).work;
        if tref.kind == TaskKind::Map {
            let block = job.task(&tref).block.unwrap();
            let loc = self.hdfs.locality(block, node_id);
            self.metrics.record_locality(loc);
            work *= locality_multiplier(loc);
            actual.net += locality_net_demand(loc);
        } else {
            actual.net += 0.05;
        }
        actual.clamp_non_negative();

        let node_feats = self.cluster.node(node_id).features();
        let feats = feature_vec(&job.spec.profile, &node_feats);
        self.pending_feedback[node_id.0 as usize].push(PendingFeedback { feats });

        let dooms = self.cluster.node(node_id).would_oom(&actual);
        self.jobs.start_task(&tref, node_id, now);
        let generation = self.jobs.get(tref.job).task(&tref).generation;
        self.declared[node_id.0 as usize] += declared;
        let horizons =
            self.cluster.node_mut(node_id).add_task(tref, actual, work, now);
        if dooms {
            self.cluster.node_mut(node_id).oom_kills += 1;
            self.doomed.insert(tref);
            self.engine.schedule(
                now + 4.0,
                Event::TaskFail { node: node_id, task: tref, generation },
            );
        }
        self.reschedule(node_id, horizons);
    }

    fn reschedule(&mut self, node_id: NodeId, horizons: Vec<(TaskRef, Time)>) {
        for (tref, at) in horizons {
            if self.doomed.contains(&tref) {
                continue;
            }
            let task = self.jobs.get_mut(tref.job).task_mut(&tref);
            task.generation += 1;
            let generation = task.generation;
            self.engine
                .schedule(at, Event::TaskComplete { node: node_id, task: tref, generation });
        }
    }

    fn current(&self, tref: &TaskRef, node: NodeId, generation: u32) -> bool {
        let task = self.jobs.get(tref.job).task(tref);
        task.generation == generation
            && matches!(task.state, TaskState::Running { node: n, .. } if n == node)
    }

    fn release(&mut self, tref: &TaskRef, node_id: NodeId, now: Time) -> Vec<(TaskRef, Time)> {
        self.cluster.node_mut(node_id).advance(now);
        let (_rec, horizons) = self.cluster.node_mut(node_id).remove_task(tref, now);
        let declared = self.jobs.get(tref.job).demand;
        let slot = &mut self.declared[node_id.0 as usize];
        *slot -= declared;
        slot.clamp_non_negative();
        horizons
    }

    fn on_complete(&mut self, node_id: NodeId, tref: TaskRef, generation: u32) {
        if !self.current(&tref, node_id, generation) {
            return;
        }
        let now = self.engine.now();
        let horizons = self.release(&tref, node_id, now);
        self.jobs.complete_task(&tref, now);
        self.doomed.remove(&tref);
        let job = self.jobs.get(tref.job);
        let finished = !job.failed && job.is_complete();
        if finished {
            // AM unregisters (paper §2.3 final step)
            self.jobs.mark_complete(tref.job, now);
            let outcome = self.jobs.get(tref.job).outcome().unwrap();
            self.metrics.record_outcome(tref.job, outcome);
        }
        self.reschedule(node_id, horizons);
    }

    fn on_fail(&mut self, node_id: NodeId, tref: TaskRef, generation: u32) {
        if !self.current(&tref, node_id, generation) {
            return;
        }
        let now = self.engine.now();
        let horizons = self.release(&tref, node_id, now);
        self.doomed.remove(&tref);
        self.jobs.requeue_task(&tref);
        let job = self.jobs.get(tref.job);
        let kill = job.task(&tref).attempts >= self.cfg.max_task_attempts
            && job.finish_time.is_none();
        if kill {
            self.jobs.mark_failed(tref.job, now);
            self.metrics.failed_jobs += 1;
        }
        self.reschedule(node_id, horizons);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::{generate, WorkloadConfig};

    fn run(policy: &str, seed: u64) -> ResourceManager {
        let cluster = Cluster::homogeneous(6, 2);
        let specs = generate(&WorkloadConfig {
            n_jobs: 12,
            arrival_rate: 1.0,
            seed,
            ..Default::default()
        });
        let mut rm = ResourceManager::new(
            cluster,
            yarn_policy_by_name(policy, 1.0).unwrap(),
            specs,
            seed,
            YarnConfig::default(),
        );
        rm.run();
        rm
    }

    #[test]
    fn all_policies_complete_workload() {
        for p in ["yarn-fifo", "yarn-fair", "yarn-bayes"] {
            let rm = run(p, 1);
            assert!(rm.jobs.all_complete(), "{p} left jobs unfinished");
            // jobs either succeed or are killed after max attempts
            assert_eq!(
                rm.metrics.outcomes.len() + rm.jobs.failed_count(),
                12,
                "{p}"
            );
            // the bulk of the workload must still succeed
            assert!(rm.metrics.outcomes.len() >= 8, "{p}");
        }
    }

    #[test]
    fn deterministic() {
        let a = run("yarn-bayes", 5);
        let b = run("yarn-bayes", 5);
        assert_eq!(a.metrics.makespan, b.metrics.makespan);
        assert_eq!(a.engine.processed(), b.engine.processed());
    }

    #[test]
    fn declared_bookkeeping_returns_to_zero() {
        let rm = run("yarn-fifo", 2);
        for d in &rm.declared {
            assert!(d.max_component() < 1e-9, "leaked declared resources {d:?}");
        }
        for n in &rm.cluster.nodes {
            assert!(n.running().is_empty());
        }
    }

    #[test]
    fn unknown_policy_rejected() {
        assert!(yarn_policy_by_name("nope", 1.0).is_err());
    }
}
