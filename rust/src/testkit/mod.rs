//! Tiny property-testing helper (proptest substitute — not in the offline
//! crate cache). Runs a property over many seeded random cases and reports
//! the first failing seed so failures are reproducible:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla_extension rpath in this image)
//! use bayes_sched::testkit::forall;
//! forall("sum is commutative", 200, |g| {
//!     let a = g.rng.f64();
//!     let b = g.rng.f64();
//!     assert!((a + b - (b + a)).abs() < 1e-12);
//! });
//! ```

use crate::sim::rng::Pcg;

/// Case generator handed to properties.
pub struct Gen {
    pub rng: Pcg,
    pub case: usize,
}

impl Gen {
    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.rng.index(n)
    }

    /// Uniform u64 in [lo, hi].
    pub fn int(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    /// Uniform f64 in [lo, hi).
    pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Random vector of length in [1, max_len] from `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.int(1, max_len as u64) as usize;
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Run `prop` for `cases` seeded cases. Panics (with the failing case id)
/// on the first failure; re-running reproduces it exactly.
///
/// Honors `TESTKIT_SEED` to re-run one specific case in isolation.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    if let Ok(s) = std::env::var("TESTKIT_SEED") {
        let case: usize = s.parse().expect("TESTKIT_SEED must be an integer");
        let mut g = Gen { rng: Pcg::new(case as u64, 0x7E57), case };
        prop(&mut g);
        return;
    }
    for case in 0..cases {
        let mut g = Gen { rng: Pcg::new(case as u64, 0x7E57), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed on case {case} \
                 (re-run with TESTKIT_SEED={case})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        forall("counting", 50, |_| count += 1);
        assert_eq!(count, 50);
    }

    #[test]
    fn deterministic_cases() {
        let mut first = Vec::new();
        forall("collect", 10, |g| first.push(g.rng.next_u64()));
        let mut second = Vec::new();
        forall("collect", 10, |g| second.push(g.rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn propagates_failures() {
        forall("failing", 5, |g| {
            if g.case == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn gen_helpers_in_range() {
        forall("ranges", 100, |g| {
            let i = g.int(3, 9);
            assert!((3..=9).contains(&i));
            let f = g.float(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec(5, |g| g.index(10));
            assert!(!v.is_empty() && v.len() <= 5);
        });
    }
}
