//! Rack-aware block placement: HDFS's default policy — first replica on a
//! "local" (here: random) node, second on a different rack, third on the
//! second replica's rack but a different node.

use crate::cluster::node::NodeId;
use crate::cluster::topology::Topology;
use crate::sim::rng::Pcg;

use super::locality::Locality;
use super::BlockId;

/// Replication factor (HDFS default).
pub const REPLICATION: usize = 3;

/// The block namespace: block → replica locations.
#[derive(Debug)]
pub struct Namespace {
    topology: Topology,
    replicas: Vec<Vec<NodeId>>,
    rng: Pcg,
}

impl Namespace {
    pub fn new(n_nodes: u32, n_racks: u32, seed: u64) -> Namespace {
        Namespace {
            topology: Topology::new(n_nodes, n_racks),
            replicas: Vec::new(),
            rng: Pcg::new(seed, 0xB10C),
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    pub fn block_count(&self) -> usize {
        self.replicas.len()
    }

    /// Allocate `n` new blocks with rack-aware replica placement.
    pub fn allocate_blocks(&mut self, n: usize) -> Vec<BlockId> {
        (0..n).map(|_| self.allocate_one()).collect()
    }

    fn allocate_one(&mut self) -> BlockId {
        let id = BlockId(self.replicas.len() as u64);
        let n_nodes = self.topology.n_nodes;
        let mut locs = Vec::with_capacity(REPLICATION.min(n_nodes as usize));

        // replica 1: uniform random node
        let first = NodeId(self.rng.below(n_nodes as u64) as u32);
        locs.push(first);

        if n_nodes > 1 {
            // replica 2: different rack if one exists, else any other node
            let second = self.pick(|ns, cand| {
                if ns.topology.n_racks > 1 {
                    !ns.topology.same_rack(cand, first)
                } else {
                    cand != first
                }
            });
            if let Some(second) = second {
                locs.push(second);
                // replica 3: same rack as replica 2, different node; fall
                // back to any node not yet used
                let third = self
                    .pick(|ns, cand| {
                        ns.topology.same_rack(cand, second)
                            && cand != second
                            && cand != first
                    })
                    .or_else(|| self.pick(|_, cand| cand != first && cand != second));
                if let Some(third) = third {
                    locs.push(third);
                }
            }
        }
        self.replicas.push(locs);
        id
    }

    /// Rejection-sample a node satisfying `pred` (bounded attempts, then
    /// linear scan for determinism).
    fn pick<F>(&mut self, pred: F) -> Option<NodeId>
    where
        F: Fn(&Namespace, NodeId) -> bool,
    {
        let n = self.topology.n_nodes as u64;
        for _ in 0..16 {
            let cand = NodeId(self.rng.below(n) as u32);
            if pred(self, cand) {
                return Some(cand);
            }
        }
        // deterministic fallback: first satisfying node after a random start
        let start = self.rng.below(n) as u32;
        (0..n as u32)
            .map(|k| NodeId((start + k) % n as u32))
            .find(|&c| pred(self, c))
    }

    pub fn replicas(&self, block: BlockId) -> &[NodeId] {
        &self.replicas[block.0 as usize]
    }

    /// Locality of `block` w.r.t. `node`.
    pub fn locality(&self, block: BlockId, node: NodeId) -> Locality {
        let reps = self.replicas(block);
        if reps.contains(&node) {
            return Locality::NodeLocal;
        }
        if reps.iter().any(|r| self.topology.same_rack(*r, node)) {
            return Locality::RackLocal;
        }
        Locality::Remote
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocates_three_distinct_replicas() {
        let mut ns = Namespace::new(12, 3, 1);
        for b in ns.allocate_blocks(200) {
            let reps = ns.replicas(b);
            assert_eq!(reps.len(), 3, "{reps:?}");
            let mut d = reps.to_vec();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), 3, "duplicate replicas {reps:?}");
        }
    }

    #[test]
    fn replicas_span_two_racks() {
        let mut ns = Namespace::new(12, 3, 2);
        for b in ns.allocate_blocks(100) {
            let reps = ns.replicas(b).to_vec();
            let racks: std::collections::HashSet<u32> = reps
                .iter()
                .map(|r| ns.topology().rack_of(*r).0)
                .collect();
            assert_eq!(racks.len(), 2, "default policy spans exactly 2 racks");
        }
    }

    #[test]
    fn single_node_cluster_gets_one_replica() {
        let mut ns = Namespace::new(1, 1, 3);
        let b = ns.allocate_blocks(1)[0];
        assert_eq!(ns.replicas(b), &[NodeId(0)]);
    }

    #[test]
    fn two_node_cluster_gets_two_replicas() {
        let mut ns = Namespace::new(2, 1, 4);
        let b = ns.allocate_blocks(1)[0];
        assert_eq!(ns.replicas(b).len(), 2);
    }

    #[test]
    fn locality_classification() {
        let mut ns = Namespace::new(12, 3, 5);
        let b = ns.allocate_blocks(1)[0];
        let reps = ns.replicas(b).to_vec();
        assert_eq!(ns.locality(b, reps[0]), Locality::NodeLocal);
        // a node sharing a rack with some replica but not holding one
        let rack_mate = ns
            .topology()
            .all_nodes()
            .find(|n| {
                !reps.contains(n)
                    && reps.iter().any(|r| ns.topology().same_rack(*r, *n))
            })
            .unwrap();
        assert_eq!(ns.locality(b, rack_mate), Locality::RackLocal);
    }

    #[test]
    fn deterministic_placement() {
        let mut a = Namespace::new(10, 2, 99);
        let mut b = Namespace::new(10, 2, 99);
        let ba = a.allocate_blocks(50);
        let bb = b.allocate_blocks(50);
        for (x, y) in ba.iter().zip(&bb) {
            assert_eq!(a.replicas(*x), b.replicas(*y));
        }
    }

    #[test]
    fn block_distribution_roughly_uniform() {
        let mut ns = Namespace::new(10, 2, 6);
        let blocks = ns.allocate_blocks(2000);
        let mut per_node = vec![0usize; 10];
        for b in blocks {
            for r in ns.replicas(b) {
                per_node[r.0 as usize] += 1;
            }
        }
        // 2000 blocks * 3 replicas / 10 nodes = 600 each
        for (i, c) in per_node.iter().enumerate() {
            assert!(
                (300..900).contains(c),
                "node {i} has {c} replicas: {per_node:?}"
            );
        }
    }
}
