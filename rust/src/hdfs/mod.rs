//! HDFS substrate (paper §1): block namespace with rack-aware 3-replica
//! placement and the data-locality classification the schedulers use
//! ("select the required data in the job to schedule the tasks on the
//! TaskTracker firstly", §4.2).

pub mod locality;
pub mod placement;

pub use locality::{locality_multiplier, Locality};
pub use placement::Namespace;

/// HDFS block identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u64);
