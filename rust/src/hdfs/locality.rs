//! Data locality levels and their execution-time cost. "The bad assigning
//! of tasks results in the increments of mount of network" (paper §3) — a
//! non-local map must stream its input block over the network, inflating
//! both its runtime and the node's network load.

/// Where a map task's input block lives relative to the executing node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Locality {
    /// A replica is on the executing node.
    NodeLocal,
    /// A replica is in the same rack (one switch hop).
    RackLocal,
    /// All replicas are off-rack (core-switch transfer).
    Remote,
}

impl Locality {
    pub fn name(&self) -> &'static str {
        match self {
            Locality::NodeLocal => "node_local",
            Locality::RackLocal => "rack_local",
            Locality::Remote => "remote",
        }
    }
}

/// Work multiplier for a map task executed at the given locality.
pub fn locality_multiplier(l: Locality) -> f64 {
    match l {
        Locality::NodeLocal => 1.0,
        Locality::RackLocal => 1.15,
        Locality::Remote => 1.40,
    }
}

/// Extra network demand (fraction of a standard node's NIC) while a
/// non-local map streams its input.
pub fn locality_net_demand(l: Locality) -> f64 {
    match l {
        Locality::NodeLocal => 0.0,
        Locality::RackLocal => 0.10,
        Locality::Remote => 0.25,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multipliers_are_ordered() {
        assert!(locality_multiplier(Locality::NodeLocal)
            < locality_multiplier(Locality::RackLocal));
        assert!(locality_multiplier(Locality::RackLocal)
            < locality_multiplier(Locality::Remote));
        assert_eq!(locality_multiplier(Locality::NodeLocal), 1.0);
    }

    #[test]
    fn net_demand_only_for_non_local() {
        assert_eq!(locality_net_demand(Locality::NodeLocal), 0.0);
        assert!(locality_net_demand(Locality::RackLocal) > 0.0);
        assert!(
            locality_net_demand(Locality::Remote)
                > locality_net_demand(Locality::RackLocal)
        );
    }
}
