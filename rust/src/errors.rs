//! Minimal error plumbing (anyhow substitute — the offline crate cache has
//! no anyhow). [`Error`] is a contextual message string; the [`anyhow!`] /
//! [`bail!`] macros build one, and [`Context`] layers context onto any
//! `Result` or `Option`, exactly like the anyhow idioms the crate uses.

use std::fmt;

/// A contextual error message.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

/// Crate-wide result alias (anyhow-style defaulted error type).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Wrap with an outer context layer ("context: cause").
    pub fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: deliberately no `impl std::error::Error for Error` — its absence is
// what makes the blanket conversion below coherent (anyhow's trick).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Format an [`Error`] in place (anyhow! substitute).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::errors::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`] (bail! substitute).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

pub use crate::{anyhow, bail};

/// Context layering for `Result` and `Option` (anyhow::Context substitute).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_layers() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
        let e = io_fail()
            .with_context(|| format!("pass {}", 2))
            .unwrap_err();
        assert_eq!(e.to_string(), "pass 2: gone");
    }

    #[test]
    fn option_context() {
        let x: Option<i32> = None;
        assert_eq!(x.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(5).context("missing").unwrap(), 5);
    }

    #[test]
    fn wrap_chains() {
        let e = Error::msg("inner").wrap("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
