//! Generational arenas: dense slot-indexed storage with stale-handle
//! detection, the backing store for per-job state across the whole stack
//! (job table, scheduler per-job maps, failure history).
//!
//! A key (see [`SlotKey`]) is a pair `(slot, serial)`:
//!
//! * `slot` — dense index into the backing storage. Slots are recycled
//!   LIFO through a free list, so long simulations keep the storage at
//!   O(peak live entries) instead of O(total ever inserted).
//! * `serial` — a generation stamp allocated by the *caller* (for jobs:
//!   the globally monotone submission counter). A recycled slot gets a new
//!   serial, so a stale key held by any layer can never alias the slot's
//!   new occupant: lookups compare serials and miss.
//!
//! Hot-path discipline (enforced by the `engine-hot-loop` lint, see
//! LINTS.md): insert/get/remove never allocate except for amortized
//! backing growth, and nothing here recurses.

/// A generational handle: dense slot index plus caller-allocated serial.
/// Implemented by `JobId`; anything slot-shaped can use these containers.
pub trait SlotKey: Copy {
    fn slot_index(self) -> u32;
    fn serial_stamp(self) -> u32;
}

#[derive(Debug, Clone)]
enum Entry<T> {
    Occupied { serial: u32, value: T },
    Vacant,
}

/// Primary owner of per-entity values (e.g. the job table's `Job`s).
/// The caller allocates serials; [`Arena::insert`] fills the slot that
/// [`Arena::next_slot`] predicts, so ids can be built before the value.
#[derive(Debug, Clone)]
pub struct Arena<T> {
    entries: Vec<Entry<T>>,
    free: Vec<u32>,
    live: u32,
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena {
            entries: Vec::with_capacity(0),
            free: Vec::with_capacity(0),
            live: 0,
        }
    }
}

impl<T> Arena<T> {
    pub fn new() -> Arena<T> {
        Arena::default()
    }

    /// The slot the next [`Arena::insert`] will use (top of the free list,
    /// else one past the end). Lets callers mint the id first.
    pub fn next_slot(&self) -> u32 {
        match self.free.last() {
            Some(&slot) => slot,
            None => self.entries.len() as u32,
        }
    }

    /// Store `value` under caller-allocated generation `serial`; returns
    /// the slot used (always equal to what `next_slot()` reported).
    pub fn insert(&mut self, serial: u32, value: T) -> u32 {
        self.live += 1;
        match self.free.pop() {
            Some(slot) => {
                self.entries[slot as usize] = Entry::Occupied { serial, value };
                slot
            }
            None => {
                let slot = self.entries.len() as u32;
                self.entries.push(Entry::Occupied { serial, value });
                slot
            }
        }
    }

    /// Lookup; `None` for vacant slots and for stale keys (serial
    /// mismatch after the slot was recycled).
    pub fn get(&self, key: impl SlotKey) -> Option<&T> {
        match self.entries.get(key.slot_index() as usize) {
            Some(Entry::Occupied { serial, value }) if *serial == key.serial_stamp() => {
                Some(value)
            }
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: impl SlotKey) -> Option<&mut T> {
        match self.entries.get_mut(key.slot_index() as usize) {
            Some(Entry::Occupied { serial, value }) if *serial == key.serial_stamp() => {
                Some(value)
            }
            _ => None,
        }
    }

    /// Free the slot and return its value; stale/vacant keys are a no-op
    /// (`None`), so double-release cannot corrupt the free list.
    pub fn remove(&mut self, key: impl SlotKey) -> Option<T> {
        let e = self.entries.get_mut(key.slot_index() as usize)?;
        match e {
            Entry::Occupied { serial, .. } if *serial == key.serial_stamp() => {
                let old = std::mem::replace(e, Entry::Vacant);
                self.free.push(key.slot_index());
                self.live -= 1;
                match old {
                    Entry::Occupied { value, .. } => Some(value),
                    Entry::Vacant => None,
                }
            }
            _ => None,
        }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.live as usize
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Backing slots allocated (live + recyclable) — the O(peak) bound.
    pub fn slot_count(&self) -> usize {
        self.entries.len()
    }

    /// Live entries in slot order as `(slot, serial, &value)`.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32, &T)> {
        self.entries.iter().enumerate().filter_map(|(i, e)| match e {
            Entry::Occupied { serial, value } => Some((i as u32, *serial, value)),
            Entry::Vacant => None,
        })
    }
}

/// Secondary per-entity map keyed by the *same* generational keys as the
/// owning [`Arena`] — the replacement for `BTreeMap<JobId, V>` side tables
/// (scheduler pool/queue membership, failure counts). Storage is a dense
/// `Vec` indexed by slot; every access checks the serial, so state left
/// behind for a dead entity is invisible to (and reclaimed by) the slot's
/// next occupant.
#[derive(Debug, Clone)]
pub struct SlotMap<V> {
    entries: Vec<Option<(u32, V)>>,
    live: u32,
}

impl<V> Default for SlotMap<V> {
    fn default() -> Self {
        SlotMap { entries: Vec::with_capacity(0), live: 0 }
    }
}

impl<V> SlotMap<V> {
    pub fn new() -> SlotMap<V> {
        SlotMap::default()
    }

    fn ensure_slot(&mut self, slot: u32) {
        let i = slot as usize;
        if i >= self.entries.len() {
            self.entries.resize_with(i + 1, || None);
        }
    }

    /// Insert/overwrite. A stale entry left behind by a previous occupant
    /// of the slot is silently evicted (that is the aliasing fix: the old
    /// occupant's state can never be read through the new key or vice
    /// versa). Returns the previous value only if it belonged to the SAME
    /// serial.
    pub fn insert(&mut self, key: impl SlotKey, value: V) -> Option<V> {
        self.ensure_slot(key.slot_index());
        let e = &mut self.entries[key.slot_index() as usize];
        match e.take() {
            Some((serial, old)) if serial == key.serial_stamp() => {
                *e = Some((serial, value));
                Some(old)
            }
            prev => {
                if prev.is_none() {
                    self.live += 1;
                }
                *e = Some((key.serial_stamp(), value));
                None
            }
        }
    }

    pub fn get(&self, key: impl SlotKey) -> Option<&V> {
        match self.entries.get(key.slot_index() as usize) {
            Some(Some((serial, v))) if *serial == key.serial_stamp() => Some(v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: impl SlotKey) -> Option<&mut V> {
        match self.entries.get_mut(key.slot_index() as usize) {
            Some(Some((serial, v))) if *serial == key.serial_stamp() => Some(v),
            _ => None,
        }
    }

    /// Current value for `key`, inserting `make()` first when the slot is
    /// empty or holds a stale serial.
    pub fn get_or_insert_with(
        &mut self,
        key: impl SlotKey,
        make: impl FnOnce() -> V,
    ) -> &mut V {
        self.ensure_slot(key.slot_index());
        let i = key.slot_index() as usize;
        let fresh = !matches!(
            &self.entries[i],
            Some((serial, _)) if *serial == key.serial_stamp()
        );
        if fresh {
            if self.entries[i].is_none() {
                self.live += 1;
            }
            self.entries[i] = Some((key.serial_stamp(), make()));
        }
        match &mut self.entries[i] {
            Some((_, v)) => v,
            // written one line above; the match exists only to re-borrow
            None => unreachable!(),
        }
    }

    pub fn remove(&mut self, key: impl SlotKey) -> Option<V> {
        let e = self.entries.get_mut(key.slot_index() as usize)?;
        match e.take() {
            Some((serial, v)) if serial == key.serial_stamp() => {
                self.live -= 1;
                Some(v)
            }
            prev => {
                *e = prev;
                None
            }
        }
    }

    /// Occupied slots (live entries for ANY serial, including ones whose
    /// owner has left — the leak-regression guards count these).
    pub fn len(&self) -> usize {
        self.live as usize
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Live entries in slot order as `(slot, serial, &value)`.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32, &V)> {
        self.entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|(s, v)| (i as u32, *s, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    struct Key {
        slot: u32,
        serial: u32,
    }
    impl SlotKey for Key {
        fn slot_index(self) -> u32 {
            self.slot
        }
        fn serial_stamp(self) -> u32 {
            self.serial
        }
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut a: Arena<&'static str> = Arena::new();
        let s0 = a.insert(0, "zero");
        let s1 = a.insert(1, "one");
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(a.len(), 2);
        let k0 = Key { slot: 0, serial: 0 };
        assert_eq!(a.get(k0), Some(&"zero"));
        assert_eq!(a.remove(k0), Some("zero"));
        assert_eq!(a.get(k0), None);
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn slots_recycle_lifo_and_stale_keys_miss() {
        let mut a: Arena<u64> = Arena::new();
        a.insert(0, 100);
        a.insert(1, 200);
        let old = Key { slot: 1, serial: 1 };
        a.remove(old);
        assert_eq!(a.next_slot(), 1, "freed slot must be recycled first");
        let slot = a.insert(2, 300);
        assert_eq!(slot, 1);
        // the stale handle to the old occupant misses; the new one hits
        assert_eq!(a.get(old), None);
        assert_eq!(a.get(Key { slot: 1, serial: 2 }), Some(&300));
        // storage stayed dense: 2 slots for 2 live entries
        assert_eq!(a.slot_count(), 2);
    }

    #[test]
    fn double_remove_is_inert() {
        let mut a: Arena<u8> = Arena::new();
        a.insert(7, 1);
        let k = Key { slot: 0, serial: 7 };
        assert_eq!(a.remove(k), Some(1));
        assert_eq!(a.remove(k), None, "second release must not corrupt");
        assert_eq!(a.next_slot(), 0);
        a.insert(8, 2);
        assert_eq!(a.len(), 1);
        assert_eq!(a.next_slot(), 1, "free list must hold slot 0 only once");
    }

    #[test]
    fn arena_iter_skips_vacant() {
        let mut a: Arena<i32> = Arena::new();
        a.insert(0, 10);
        a.insert(1, 11);
        a.insert(2, 12);
        a.remove(Key { slot: 1, serial: 1 });
        let got: Vec<(u32, u32, i32)> =
            a.iter().map(|(s, g, v)| (s, g, *v)).collect();
        assert_eq!(got, vec![(0, 0, 10), (2, 2, 12)]);
    }

    #[test]
    fn slotmap_serial_mismatch_misses() {
        let mut m: SlotMap<&'static str> = SlotMap::new();
        let old = Key { slot: 3, serial: 5 };
        let new = Key { slot: 3, serial: 9 };
        m.insert(old, "old");
        assert_eq!(m.get(new), None, "new occupant must not see stale state");
        assert_eq!(m.remove(new), None, "stale entry survives a mismatched remove");
        assert_eq!(m.get(old), Some(&"old"));
    }

    #[test]
    fn slotmap_insert_evicts_stale_entry() {
        let mut m: SlotMap<u32> = SlotMap::new();
        m.insert(Key { slot: 0, serial: 1 }, 111);
        // slot recycled to serial 2: the write takes over the slot
        assert_eq!(m.insert(Key { slot: 0, serial: 2 }, 222), None);
        assert_eq!(m.get(Key { slot: 0, serial: 1 }), None);
        assert_eq!(m.get(Key { slot: 0, serial: 2 }), Some(&222));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn slotmap_get_or_insert_with_replaces_stale() {
        let mut m: SlotMap<u32> = SlotMap::new();
        *m.get_or_insert_with(Key { slot: 2, serial: 0 }, || 0) += 5;
        *m.get_or_insert_with(Key { slot: 2, serial: 0 }, || 0) += 5;
        assert_eq!(m.get(Key { slot: 2, serial: 0 }), Some(&10));
        // recycled slot: counter must restart, not inherit 10
        *m.get_or_insert_with(Key { slot: 2, serial: 4 }, || 0) += 1;
        assert_eq!(m.get(Key { slot: 2, serial: 4 }), Some(&1));
    }

    #[test]
    fn slotmap_len_and_iter() {
        let mut m: SlotMap<char> = SlotMap::new();
        m.insert(Key { slot: 0, serial: 0 }, 'a');
        m.insert(Key { slot: 4, serial: 2 }, 'b');
        assert_eq!(m.len(), 2);
        let got: Vec<(u32, u32, char)> =
            m.iter().map(|(s, g, v)| (s, g, *v)).collect();
        assert_eq!(got, vec![(0, 0, 'a'), (4, 2, 'b')]);
        m.remove(Key { slot: 0, serial: 0 });
        assert_eq!(m.len(), 1);
    }
}
