//! Calendar-queue event storage (Brown 1988): the O(1) amortized backend
//! behind [`crate::sim::Engine`], replacing the binary heap whose
//! per-operation cost grows O(log n) with pending events.
//!
//! Layout: a power-of-two ring of unsorted buckets. Virtual time is cut
//! into fixed-width "days"; an event lands in bucket `day & mask` where
//! `day = floor(at / width)`. Pop scans the current day's bucket for the
//! minimum `(at, seq)` (the same total order the heap used, so FIFO
//! tie-breaking by seq is preserved bit-for-bit), advancing day by day;
//! when a full rotation finds nothing — the sparse-tail case — the cursor
//! jumps straight to the day of the global minimum instead of spinning.
//!
//! The ring resizes by doubling/halving when the event count crosses 2x /
//! 0.5x the bucket count, recomputing the day width from the live span so
//! the steady state keeps O(1) events per bucket. Buckets retain their
//! capacity across pushes and pops, so the steady state allocates nothing.
//!
//! Determinism: pop order is a pure function of the multiset of pushed
//! `(at, seq)` pairs — bucketing, rotation and resizing only change WHERE
//! an event waits, never the order selected — which the differential test
//! in `tests/engine_differential.rs` checks against the heap backend.
//!
//! Invariant relied on throughout: callers never push an `at` below the
//! time of the last popped event (the engine clamps past/non-finite
//! times), so no event can ever land behind the day cursor.

use super::engine::Time;
use super::event::Event;

/// Backend interface the generic engine drives. Implementations must pop
/// strictly by `(at, seq)` order and may assume pushes are monotone with
/// respect to the last popped `at` (the engine's clamp guarantees it).
pub trait EventQueue {
    fn push(&mut self, at: Time, seq: u64, event: Event);
    fn pop(&mut self) -> Option<(Time, u64, Event)>;
    /// Earliest pending timestamp. May cost O(n); not a hot-path call.
    fn peek_time(&self) -> Option<Time>;
    fn len(&self) -> usize;
    /// Cumulative bucket-scan depth: day-advance steps taken by `pop`
    /// across the queue's lifetime (obs gauge `engine_bucket_scan_steps`).
    /// Backends without a scan (the heap) report 0.
    fn scan_steps(&self) -> u64 {
        0
    }
}

type Item = (Time, u64, Event);

/// Smallest ring size; also the size below which we never shrink.
const MIN_BUCKETS: usize = 16;

#[derive(Debug)]
pub struct CalendarQueue {
    buckets: Vec<Vec<Item>>,
    /// `buckets.len() - 1`; the ring size is always a power of two.
    mask: u64,
    /// Day width in virtual seconds.
    width: Time,
    /// Day of the last popped event (events never land behind it).
    cur_day: u64,
    /// Timestamp of the last popped event (resize re-anchors on it).
    cur_time: Time,
    len: usize,
    /// Day-advance steps taken by `pop` since construction — a plain u64
    /// (no atomics in the hot loop) drained into an obs gauge at export.
    scan_steps: u64,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        let mut buckets = Vec::with_capacity(MIN_BUCKETS);
        buckets.resize_with(MIN_BUCKETS, || Vec::with_capacity(0));
        CalendarQueue {
            buckets,
            mask: (MIN_BUCKETS - 1) as u64,
            width: 1.0,
            cur_day: 0,
            cur_time: 0.0,
            len: 0,
            scan_steps: 0,
        }
    }
}

impl CalendarQueue {
    pub fn new() -> CalendarQueue {
        CalendarQueue::default()
    }

    #[inline]
    fn day_of(&self, at: Time) -> u64 {
        // finite, non-negative by the engine's clamp; the cast saturates
        (at / self.width) as u64
    }

    #[inline]
    fn place(&mut self, item: Item) {
        let day = self.day_of(item.0);
        let b = (day & self.mask) as usize;
        self.buckets[b].push(item);
    }

    /// Index of the minimum `(at, seq)` entry of `bucket` belonging to
    /// exactly `day` (the bucket may also hold later ring laps).
    fn min_in_day(&self, bucket: usize, day: u64) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, it) in self.buckets[bucket].iter().enumerate() {
            if self.day_of(it.0) != day {
                continue;
            }
            best = match best {
                Some(j) => {
                    let b = &self.buckets[bucket][j];
                    if (it.0, it.1) < (b.0, b.1) {
                        Some(i)
                    } else {
                        Some(j)
                    }
                }
                None => Some(i),
            };
        }
        best
    }

    /// Locate the global minimum `(at, seq)` as `(bucket, index)`.
    /// Only runs when a full rotation found nothing (sparse tail) or for
    /// `peek_time`; O(n) but off the steady-state path.
    fn global_min(&self) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, it) in bucket.iter().enumerate() {
                best = match best {
                    Some((bb, bi)) => {
                        let cur = &self.buckets[bb][bi];
                        if (it.0, it.1) < (cur.0, cur.1) {
                            Some((b, i))
                        } else {
                            Some((bb, bi))
                        }
                    }
                    None => Some((b, i)),
                };
            }
        }
        best
    }

    /// Extract `index` from `bucket`, advancing the cursor to the item's
    /// day, then maybe shrink the ring.
    fn take(&mut self, bucket: usize, index: usize) -> Item {
        let item = self.buckets[bucket].swap_remove(index);
        self.len -= 1;
        self.cur_day = self.day_of(item.0);
        self.cur_time = item.0;
        if self.len < self.buckets.len() / 2 && self.buckets.len() > MIN_BUCKETS {
            self.resize(self.buckets.len() / 2);
        }
        item
    }

    /// Rebuild the ring at `n` buckets (power of two), recomputing the day
    /// width so the live span averages about one event per day. Iterative
    /// throughout — the hot-loop lint forbids recursion here.
    fn resize(&mut self, n: usize) {
        debug_assert!(n.is_power_of_two() && n >= MIN_BUCKETS);
        let mut items: Vec<Item> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            items.append(b);
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for it in &items {
            lo = lo.min(it.0);
            hi = hi.max(it.0);
        }
        let span = hi - lo;
        self.width = if items.len() < 2 || span <= 0.0 {
            1.0
        } else {
            (span / items.len() as f64).max(1e-9)
        };
        if self.buckets.len() != n {
            self.buckets.resize_with(n, || Vec::with_capacity(0));
        }
        self.mask = (n - 1) as u64;
        self.cur_day = self.day_of(self.cur_time);
        for item in items {
            self.place(item);
        }
    }
}

impl EventQueue for CalendarQueue {
    fn push(&mut self, at: Time, seq: u64, event: Event) {
        debug_assert!(at.is_finite() && at >= self.cur_time);
        self.place((at, seq, event));
        self.len += 1;
        if self.len > self.buckets.len() * 2 {
            self.resize(self.buckets.len() * 2);
        }
    }

    fn pop(&mut self) -> Option<(Time, u64, Event)> {
        if self.len == 0 {
            return None;
        }
        let mut day = self.cur_day;
        for _ in 0..self.buckets.len() {
            self.scan_steps += 1;
            let b = (day & self.mask) as usize;
            if let Some(i) = self.min_in_day(b, day) {
                self.cur_day = day;
                return Some(self.take(b, i));
            }
            day += 1;
        }
        // sparse tail: one rotation was empty — jump to the global min
        match self.global_min() {
            Some((b, i)) => Some(self.take(b, i)),
            None => None,
        }
    }

    fn peek_time(&self) -> Option<Time> {
        self.global_min().map(|(b, i)| self.buckets[b][i].0)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn scan_steps(&self) -> u64 {
        self.scan_steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::NodeId;
    use crate::sim::Pcg;

    fn ev(i: u32) -> Event {
        Event::Heartbeat(NodeId(i))
    }

    fn drain(q: &mut CalendarQueue) -> Vec<(Time, u64)> {
        std::iter::from_fn(|| q.pop().map(|(t, s, _)| (t, s))).collect()
    }

    #[test]
    fn pops_by_time_then_seq() {
        let mut q = CalendarQueue::new();
        q.push(5.0, 0, ev(0));
        q.push(1.0, 1, ev(1));
        q.push(5.0, 2, ev(2));
        q.push(3.0, 3, ev(3));
        assert_eq!(drain(&mut q), vec![(1.0, 1), (3.0, 3), (5.0, 0), (5.0, 2)]);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn resize_preserves_order_across_growth() {
        let mut q = CalendarQueue::new();
        // far more than 2x MIN_BUCKETS so the ring doubles repeatedly
        let mut rng = Pcg::new(7, 1);
        let mut expect: Vec<(Time, u64)> = Vec::new();
        for seq in 0..5000u64 {
            let at = rng.range_f64(0.0, 1000.0);
            expect.push((at, seq));
            q.push(at, seq, ev(seq as u32));
        }
        expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        assert_eq!(drain(&mut q), expect);
    }

    #[test]
    fn shrink_keeps_remaining_events() {
        let mut q = CalendarQueue::new();
        for seq in 0..1000u64 {
            q.push(seq as f64, seq, ev(0));
        }
        // drain most of it so the ring halves on the way down
        for want in 0..990u64 {
            assert_eq!(q.pop().map(|(_, s, _)| s), Some(want));
        }
        assert_eq!(q.len(), 10);
        assert_eq!(
            drain(&mut q).iter().map(|(_, s)| *s).collect::<Vec<_>>(),
            (990..1000).collect::<Vec<_>>()
        );
    }

    #[test]
    fn sparse_tail_jumps_instead_of_spinning() {
        let mut q = CalendarQueue::new();
        q.push(2.0, 0, ev(0));
        q.pop();
        // next event millions of days ahead of the cursor
        q.push(9.0e6, 1, ev(1));
        assert_eq!(q.pop().map(|(t, s, _)| (t, s)), Some((9.0e6, 1)));
    }

    #[test]
    fn massive_tie_bucket_stays_fifo() {
        let mut q = CalendarQueue::new();
        for seq in 0..500u64 {
            q.push(42.0, seq, ev(seq as u32));
        }
        let seqs: Vec<u64> = drain(&mut q).iter().map(|(_, s)| *s).collect();
        assert_eq!(seqs, (0..500).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_hold_pattern() {
        // the classic hold model: pop one, push one slightly later
        let mut q = CalendarQueue::new();
        let mut rng = Pcg::new(3, 9);
        for seq in 0..64u64 {
            q.push(rng.range_f64(0.0, 10.0), seq, ev(0));
        }
        let mut seq = 64u64;
        let mut last = 0.0;
        for _ in 0..10_000 {
            let (t, _, _) = q.pop().expect("hold queue never empties");
            assert!(t >= last, "time went backwards: {t} < {last}");
            last = t;
            q.push(t + rng.range_f64(0.0, 5.0), seq, ev(0));
            seq += 1;
        }
        assert_eq!(q.len(), 64);
    }

    #[test]
    fn scan_steps_accumulate_per_pop() {
        let mut q = CalendarQueue::new();
        assert_eq!(q.scan_steps(), 0);
        q.push(1.0, 0, ev(0));
        q.pop();
        let after_first = q.scan_steps();
        assert!(after_first >= 1, "pop must visit at least one day");
        q.push(2.0, 1, ev(1));
        q.pop();
        assert!(q.scan_steps() > after_first);
    }

    #[test]
    fn peek_matches_next_pop() {
        let mut q = CalendarQueue::new();
        assert_eq!(q.peek_time(), None);
        let mut rng = Pcg::new(11, 2);
        for seq in 0..200u64 {
            q.push(rng.range_f64(0.0, 50.0), seq, ev(0));
        }
        while q.len() > 0 {
            let peeked = q.peek_time().unwrap();
            let (t, _, _) = q.pop().unwrap();
            assert_eq!(peeked, t);
        }
    }
}
