//! Simulation events. The coordinator owns the semantic handling; the
//! engine only orders them in virtual time.

use crate::cluster::node::NodeId;
use crate::job::task::TaskRef;
use crate::job::JobId;

/// Everything that can happen in the simulated cluster.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A job enters the JobTracker queue.
    JobArrival(JobId),
    /// A TaskTracker heartbeat: the node reports status and receives task
    /// assignments (Hadoop assigns work on the heartbeat RPC).
    Heartbeat(NodeId),
    /// A task finishes on a node. `generation` guards against stale
    /// completions: contention changes reschedule completions, bumping the
    /// task's generation so superseded events are ignored.
    TaskComplete { node: NodeId, task: TaskRef, generation: u32 },
    /// A task fails (e.g. OOM from memory oversubscription) and will be
    /// re-queued.
    TaskFail { node: NodeId, task: TaskRef, generation: u32 },
    /// A TaskTracker dies (crash / network partition): its tasks are lost
    /// and re-queued, heartbeats stop until recovery.
    NodeFail(NodeId),
    /// A failed TaskTracker rejoins the cluster.
    NodeRecover(NodeId),
    /// Periodic metrics sampling tick.
    MetricsTick,
    /// End of workload injection (no more arrivals); used to detect drain.
    ArrivalsDone,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_comparable() {
        let a = Event::JobArrival(JobId(1));
        let b = Event::JobArrival(JobId(1));
        assert_eq!(a, b);
        assert_ne!(a, Event::MetricsTick);
    }
}
