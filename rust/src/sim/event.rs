//! Simulation events. The coordinator owns the semantic handling; the
//! engine only orders them in virtual time.

use crate::cluster::node::NodeId;
use crate::job::task::TaskRef;

/// Everything that can happen in the simulated cluster.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// The next queued job spec enters the JobTracker queue. Payload-free
    /// by design: the coordinator holds the in-flight spec (`next_spec`)
    /// and submits it when the event fires, so no placeholder job id can
    /// ever be observed by handlers.
    JobArrival,
    /// A TaskTracker heartbeat: the node reports status and receives task
    /// assignments (Hadoop assigns work on the heartbeat RPC).
    Heartbeat(NodeId),
    /// A task attempt finishes on a node. `generation` guards against stale
    /// completions: contention changes reschedule completions, bumping the
    /// attempt's generation so superseded events are ignored. With
    /// speculative execution a task can have two live attempts on two
    /// nodes; the `(node, generation)` pair identifies which one fired.
    TaskComplete { node: NodeId, task: TaskRef, generation: u32 },
    /// A task attempt fails (e.g. OOM from memory oversubscription) and
    /// will be re-queued unless a backup attempt is still running.
    TaskFail { node: NodeId, task: TaskRef, generation: u32 },
    /// A TaskTracker dies (crash / network partition): its tasks are lost
    /// and re-queued, heartbeats stop until recovery.
    NodeFail(NodeId),
    /// A failed TaskTracker rejoins the cluster.
    NodeRecover(NodeId),
    /// Periodic metrics sampling tick.
    MetricsTick,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_comparable() {
        let a = Event::Heartbeat(NodeId(1));
        let b = Event::Heartbeat(NodeId(1));
        assert_eq!(a, b);
        assert_ne!(a, Event::MetricsTick);
        assert_eq!(Event::JobArrival, Event::JobArrival);
    }
}
