//! Deterministic PCG-XSH-RR 64/32 random number generator.
//!
//! The simulator's reproducibility contract — identical seed ⇒ identical
//! event trace — requires a self-contained RNG (the offline crate cache has
//! no `rand`). PCG is small, fast, and statistically solid for simulation.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed the generator. `stream` selects one of 2^63 independent
    /// sequences — used to give each subsystem (arrivals, task durations,
    /// placement, ...) its own stream so adding draws in one subsystem
    /// never perturbs another.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Exponential with the given rate (mean 1/rate). Inter-arrival times of
    /// a Poisson process.
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        // avoid ln(0)
        let u = 1.0 - self.f64();
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal: exp(N(mu, sigma)). Used for heavy-tailed task durations.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// True with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element index for a slice length.
    pub fn index(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg::new(7, 0);
        let mut b = Pcg::new(7, 1);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Pcg::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Pcg::seeded(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Pcg::seeded(6);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seeded(7);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Pcg::seeded(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg::seeded(10);
        for _ in 0..1000 {
            let x = r.range_u64(5, 9);
            assert!((5..=9).contains(&x));
            let y = r.range_f64(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&y));
        }
    }
}
