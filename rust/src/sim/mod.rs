//! Discrete-event simulation substrate: virtual clock, event heap, and the
//! deterministic RNG that gives the reproducibility contract (same seed ⇒
//! same event trace).

pub mod engine;
pub mod event;
pub mod rng;

pub use engine::{Engine, Time};
pub use event::Event;
pub use rng::Pcg;
