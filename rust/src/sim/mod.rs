//! Discrete-event simulation substrate: virtual clock, event queue,
//! generational arenas, and the deterministic RNG that gives the
//! reproducibility contract (same seed ⇒ same event trace).
//!
//! # Design: the million-job core
//!
//! Everything per-event and per-job in the hot loop is O(1) amortized and
//! allocation-free in the steady state, so simulated cluster size and job
//! count scale without the simulator's own bookkeeping dominating (the
//! E13 experiment drives 1M jobs over 10k nodes through this substrate).
//!
//! ## Generational ids ([`arena`])
//!
//! Per-job state everywhere in the stack — the job table, scheduler
//! side-tables, failure history — lives in dense slot-indexed storage
//! ([`arena::Arena`] for owners, [`arena::SlotMap`] for side tables)
//! keyed by `(slot, serial)` pairs ([`arena::SlotKey`], implemented by
//! `JobId`). Invariants:
//!
//! * **Serials are never reused.** The job table allocates them from a
//!   monotone submission counter; the serial doubles as the submission-
//!   order sort key and the display id.
//! * **Slots are recycled** through a LIFO free list once a job leaves
//!   the system fully drained, keeping storage O(peak live).
//! * **Stale handles miss, never alias.** Every lookup compares the
//!   key's serial against the slot's current occupant; a key minted for
//!   a dead job returns `None` rather than the recycled slot's new
//!   occupant. Side-table writes through a fresh key evict any stale
//!   leftover state.
//!
//! ## Calendar-queue engine ([`calendar`], [`engine`])
//!
//! The event queue is a calendar queue (ring of day buckets, see the
//! module doc) behind the same `Engine` API the binary heap served. The
//! determinism contract is unchanged and backend-independent:
//!
//! * equal timestamps pop in insertion order (monotone seq tie-break);
//! * past and non-finite timestamps are clamped to `now` and counted via
//!   `clamped_events()`, identically in debug and release, **in the
//!   engine wrapper itself** — so every backend inherits the policy;
//! * pop order is a pure function of the pushed `(at, seq)` multiset.
//!
//! `tests/engine_differential.rs` feeds identical randomized schedules
//! (ties, past times, NaN/±inf) to the calendar engine and the retained
//! heap engine ([`engine::HeapEngine`]) and requires bit-identical pop
//! sequences and clamp counts.

pub mod arena;
pub mod calendar;
pub mod engine;
pub mod event;
pub mod rng;

pub use arena::{Arena, SlotKey, SlotMap};
pub use calendar::{CalendarQueue, EventQueue};
pub use engine::{Engine, HeapEngine, Time};
pub use event::Event;
pub use rng::Pcg;
