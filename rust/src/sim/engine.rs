//! The discrete-event engine: a virtual clock over a pluggable,
//! time-ordered event queue with deterministic FIFO tie-breaking.
//!
//! Determinism contract: given the same seed (all randomness flows through
//! [`crate::sim::Pcg`] streams) and the same schedule() call sequence, the
//! pop() sequence is identical — equal timestamps are served in insertion
//! order via a monotone sequence number. The clamp policy for past and
//! non-finite timestamps lives HERE, in [`EngineImpl`], so every backend
//! ([`CalendarQueue`] in production, [`HeapQueue`] as the differential
//! reference) inherits the identical behavior.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::calendar::{CalendarQueue, EventQueue};
use super::event::Event;

/// Virtual time in seconds since simulation start.
pub type Time = f64;

#[derive(Debug)]
struct Entry {
    at: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so earliest time (then lowest
        // seq) pops first. total_cmp gives a total order on f64.
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The original binary-heap backend. Kept as the reference implementation
/// the calendar queue is differentially tested against, and as the
/// baseline arm of the `engine_events_per_sec` bench.
#[derive(Debug, Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Entry>,
}

impl EventQueue for HeapQueue {
    fn push(&mut self, at: Time, seq: u64, event: Event) {
        self.heap.push(Entry { at, seq, event });
    }

    fn pop(&mut self) -> Option<(Time, u64, Event)> {
        self.heap.pop().map(|e| (e.at, e.seq, e.event))
    }

    fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// The event queue + clock, generic over the queue backend.
#[derive(Debug)]
pub struct EngineImpl<Q> {
    queue: Q,
    now: Time,
    seq: u64,
    processed: u64,
    clamped: u64,
}

/// The production engine: calendar-queue backend (amortized O(1) per
/// event, no steady-state allocation).
pub type Engine = EngineImpl<CalendarQueue>;

/// Heap-backed engine, for differential tests and the engine bench.
pub type HeapEngine = EngineImpl<HeapQueue>;

impl<Q: EventQueue + Default> Default for EngineImpl<Q> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Q: EventQueue + Default> EngineImpl<Q> {
    pub fn new() -> EngineImpl<Q> {
        EngineImpl {
            queue: Q::default(),
            now: 0.0,
            seq: 0,
            processed: 0,
            clamped: 0,
        }
    }
}

impl<Q: EventQueue> EngineImpl<Q> {
    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending events.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.len() == 0
    }

    /// Past-time schedules observed (and clamped) so far.
    pub fn clamped_events(&self) -> u64 {
        self.clamped
    }

    /// Cumulative bucket-scan depth of the queue backend (0 for the
    /// heap). Drained into the `engine_bucket_scan_steps` obs gauge.
    pub fn scan_steps(&self) -> u64 {
        self.queue.scan_steps()
    }

    /// Schedule `event` at absolute time `at`. A past or non-finite `at`
    /// (NaN, ±inf — always a driver bug) is clamped to `now` and counted
    /// in [`EngineImpl::clamped_events`] — the SAME policy in debug and
    /// release builds, with no assert, so a buggy timestamp can never
    /// change behavior between profiles or stall the drain at +inf.
    pub fn schedule(&mut self, at: Time, event: Event) {
        let at = if at >= self.now && at.is_finite() {
            at
        } else {
            self.clamped += 1;
            self.now
        };
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(at, seq, event);
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: Time, event: Event) {
        self.schedule(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, Event)> {
        let (at, _, event) = self.queue.pop()?;
        debug_assert!(at >= self.now);
        self.now = at;
        self.processed += 1;
        Some((at, event))
    }

    /// Peek the next event time without advancing.
    pub fn peek_time(&self) -> Option<Time> {
        self.queue.peek_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::NodeId;

    fn ev(i: u32) -> Event {
        Event::Heartbeat(NodeId(i))
    }

    #[test]
    fn pops_in_time_order() {
        let mut e = Engine::new();
        e.schedule(3.0, ev(3));
        e.schedule(1.0, ev(1));
        e.schedule(2.0, ev(2));
        let order: Vec<f64> = std::iter::from_fn(|| e.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e = Engine::new();
        for i in 0..100 {
            e.schedule(5.0, ev(i));
        }
        for i in 0..100 {
            match e.pop().unwrap().1 {
                Event::Heartbeat(NodeId(j)) => assert_eq!(j, i),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn past_time_schedules_clamp_to_now_in_every_profile() {
        // the one policy for past-time scheduling: clamp + count, never
        // panic — identical in debug and release builds
        let mut e = Engine::new();
        e.schedule(10.0, ev(0));
        e.pop(); // now = 10.0
        assert_eq!(e.clamped_events(), 0);
        e.schedule(3.0, ev(1)); // into the past
        assert_eq!(e.clamped_events(), 1);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 10.0, "past event must fire at now, not at 3.0");
        assert_eq!(e.now(), 10.0);
    }

    #[test]
    fn clamped_events_counts_every_offender() {
        let mut e = Engine::new();
        e.schedule(5.0, ev(0));
        e.pop();
        for _ in 0..4 {
            e.schedule(1.0, ev(1));
        }
        e.schedule(5.0, ev(2)); // at == now is NOT past
        e.schedule(6.0, ev(3));
        assert_eq!(e.clamped_events(), 4);
        // clamped events still pop in deterministic insertion order
        let times: Vec<f64> = std::iter::from_fn(|| e.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![5.0, 5.0, 5.0, 5.0, 5.0, 6.0]);
    }

    #[test]
    fn non_finite_times_clamp_instead_of_diverging() {
        // NaN and ±inf are driver bugs; the one policy is clamp + count in
        // every build profile (an uncaught +inf would stall the drain)
        let mut e = Engine::new();
        e.schedule(1.0, ev(0));
        e.pop();
        e.schedule(f64::NAN, ev(1));
        e.schedule(f64::INFINITY, ev(2));
        e.schedule(f64::NEG_INFINITY, ev(3));
        assert_eq!(e.clamped_events(), 3);
        let times: Vec<f64> = std::iter::from_fn(|| e.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut e = Engine::new();
        e.schedule(2.0, ev(0));
        e.schedule(2.0, ev(1));
        e.schedule(7.5, ev(2));
        let mut last = 0.0;
        while let Some((t, _)) = e.pop() {
            assert!(t >= last);
            last = t;
            assert_eq!(e.now(), t);
        }
        assert_eq!(last, 7.5);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut e = Engine::new();
        e.schedule(10.0, ev(0));
        e.pop();
        e.schedule_in(5.0, ev(1));
        assert_eq!(e.pop().unwrap().0, 15.0);
    }

    #[test]
    fn processed_counts() {
        let mut e = Engine::new();
        e.schedule(1.0, ev(0));
        e.schedule(2.0, ev(1));
        assert_eq!(e.processed(), 0);
        e.pop();
        e.pop();
        assert_eq!(e.processed(), 2);
        assert!(e.is_empty());
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut e = Engine::new();
        e.schedule(1.0, ev(0));
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 1.0);
        e.schedule_in(0.5, ev(1));
        e.schedule_in(0.25, ev(2));
        assert_eq!(e.pop().unwrap().0, 1.25);
        assert_eq!(e.pop().unwrap().0, 1.5);
        assert!(e.pop().is_none());
    }

    #[test]
    fn heap_backend_honors_the_same_contract() {
        // the reference backend behind the differential suite: same clamp
        // policy (it lives in EngineImpl), same tie-breaking
        let mut e = HeapEngine::new();
        e.schedule(5.0, ev(0));
        e.schedule(5.0, ev(1));
        e.pop();
        e.schedule(1.0, ev(2)); // past -> clamped to 5.0
        e.schedule(f64::NAN, ev(3));
        assert_eq!(e.clamped_events(), 2);
        let got: Vec<u32> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::Heartbeat(NodeId(i)) => i,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(got, vec![1, 2, 3]);
    }
}
