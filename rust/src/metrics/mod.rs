//! Metrics: per-run collector + summary statistics.

pub mod collector;
pub mod stats;
pub mod timeline;

pub use collector::{DecisionRecord, FeedbackWindow, Metrics};
pub use timeline::{Timeline, TimelineSample};
