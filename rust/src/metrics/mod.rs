//! Metrics: per-run collector + summary statistics.

pub mod collector;
pub mod stats;
pub mod timeline;

pub use collector::{FeedbackWindow, Metrics};
pub use timeline::TimelineSample;
