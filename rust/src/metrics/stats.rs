//! Summary statistics used across experiment reports: moments, percentiles,
//! and Jain's fairness index.

/// Mean of a sample (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (std/mean); 0 when mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    // div-by-zero guard, exact sentinel -- lint: allow(float-eq)
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// p-th percentile (0..=100), linear interpolation, sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Jain's fairness index: (Σx)² / (n·Σx²), 1.0 = perfectly fair.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    // div-by-zero guard, exact sentinel -- lint: allow(float-eq)
    if s2 == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * s2)
    }
}

/// Max element (0 for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(jain_fairness(&[]), 1.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((median(&xs) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 95.0) - 95.05).abs() < 1e-9);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(median(&xs), 5.0);
    }

    #[test]
    fn jain_bounds() {
        assert_eq!(jain_fairness(&[3.0, 3.0, 3.0]), 1.0);
        // one user hogging: 1/n
        let j = jain_fairness(&[1.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12);
    }

    #[test]
    fn cv_scale_invariant() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0, 30.0];
        assert!((cv(&a) - cv(&b)).abs() < 1e-12);
    }
}
