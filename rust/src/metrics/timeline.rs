//! Timeline sampling: periodic cluster snapshots for utilization plots and
//! failure-injection visibility (`repro run --timeline out.csv`).

use crate::sim::engine::Time;

/// One periodic snapshot of cluster state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelineSample {
    pub time: Time,
    /// Mean over alive nodes of the bottleneck-dimension utilization.
    pub mean_bottleneck_util: f64,
    pub running_tasks: u32,
    pub queued_jobs: u32,
    pub alive_nodes: u32,
}

/// Render samples as CSV (header + rows).
pub fn to_csv(samples: &[TimelineSample]) -> String {
    let mut out =
        String::from("time_s,mean_bottleneck_util,running_tasks,queued_jobs,alive_nodes\n");
    for s in samples {
        out.push_str(&format!(
            "{:.1},{:.4},{},{},{}\n",
            s.time, s.mean_bottleneck_util, s.running_tasks, s.queued_jobs, s.alive_nodes
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_shape() {
        let samples = vec![
            TimelineSample {
                time: 10.0,
                mean_bottleneck_util: 0.5,
                running_tasks: 12,
                queued_jobs: 3,
                alive_nodes: 8,
            },
            TimelineSample {
                time: 20.0,
                mean_bottleneck_util: 0.75,
                running_tasks: 16,
                queued_jobs: 1,
                alive_nodes: 7,
            },
        ];
        let csv = to_csv(&samples);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("time_s,"));
        assert!(lines[2].contains("0.7500"));
        assert!(lines[2].ends_with(",7"));
    }

    #[test]
    fn empty_is_header_only() {
        assert_eq!(to_csv(&[]).lines().count(), 1);
    }
}
