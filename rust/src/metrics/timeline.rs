//! Timeline sampling: periodic cluster snapshots for utilization plots and
//! failure-injection visibility (`repro run --timeline out.csv`).
//!
//! [`Timeline`] is bounded: it never holds more than its cap, no matter how
//! long the simulated run is. When the buffer fills it halves itself by
//! dropping every other kept sample and doubles its sampling stride, so a
//! week-long simulation costs the same memory as a minute-long one while
//! still covering the whole run at uniform (coarser) resolution.

use crate::sim::engine::Time;

/// One periodic snapshot of cluster state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelineSample {
    pub time: Time,
    /// Mean over alive nodes of the bottleneck-dimension utilization.
    pub mean_bottleneck_util: f64,
    pub running_tasks: u32,
    pub queued_jobs: u32,
    pub alive_nodes: u32,
}

/// Default cap: 4096 samples ≈ 160 KiB, plenty for any plot.
pub const DEFAULT_CAP: usize = 4096;

/// A bounded, stride-compacting sample buffer — O(cap) memory regardless
/// of run length.
#[derive(Debug)]
pub struct Timeline {
    samples: Vec<TimelineSample>,
    cap: usize,
    /// Keep every `stride`-th offered sample (doubles on each compaction).
    stride: u64,
    /// Samples offered since construction.
    offered: u64,
}

impl Default for Timeline {
    fn default() -> Timeline {
        Timeline::with_cap(DEFAULT_CAP)
    }
}

impl Timeline {
    pub fn with_cap(cap: usize) -> Timeline {
        Timeline {
            samples: Vec::new(),
            cap: cap.max(2),
            stride: 1,
            offered: 0,
        }
    }

    /// Offer one sample; kept only if it lands on the current stride.
    pub fn push(&mut self, s: TimelineSample) {
        let keep = self.offered % self.stride == 0;
        self.offered += 1;
        if !keep {
            return;
        }
        self.samples.push(s);
        if self.samples.len() >= self.cap {
            // drop every other kept sample, keep covering the whole run
            let mut i = 0;
            self.samples.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.stride *= 2;
        }
    }

    /// Samples currently held (≤ cap).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Total samples ever offered (kept + compacted away).
    pub fn offered(&self) -> u64 {
        self.offered
    }

    /// Current sampling stride (1 until the first compaction).
    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn samples(&self) -> &[TimelineSample] {
        &self.samples
    }

    /// Render the kept samples as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        to_csv(&self.samples)
    }
}

/// Render samples as CSV (header + rows).
pub fn to_csv(samples: &[TimelineSample]) -> String {
    let mut out =
        String::from("time_s,mean_bottleneck_util,running_tasks,queued_jobs,alive_nodes\n");
    for s in samples {
        out.push_str(&format!(
            "{:.1},{:.4},{},{},{}\n",
            s.time, s.mean_bottleneck_util, s.running_tasks, s.queued_jobs, s.alive_nodes
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64) -> TimelineSample {
        TimelineSample {
            time: t,
            mean_bottleneck_util: 0.5,
            running_tasks: 12,
            queued_jobs: 3,
            alive_nodes: 8,
        }
    }

    #[test]
    fn csv_shape() {
        let samples = vec![
            TimelineSample {
                time: 10.0,
                mean_bottleneck_util: 0.5,
                running_tasks: 12,
                queued_jobs: 3,
                alive_nodes: 8,
            },
            TimelineSample {
                time: 20.0,
                mean_bottleneck_util: 0.75,
                running_tasks: 16,
                queued_jobs: 1,
                alive_nodes: 7,
            },
        ];
        let csv = to_csv(&samples);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("time_s,"));
        assert!(lines[2].contains("0.7500"));
        assert!(lines[2].ends_with(",7"));
    }

    #[test]
    fn empty_is_header_only() {
        assert_eq!(to_csv(&[]).lines().count(), 1);
        assert_eq!(Timeline::default().to_csv().lines().count(), 1);
    }

    #[test]
    fn stays_bounded_forever() {
        // the O(active)-memory regression guard: a run 1000x the cap still
        // holds at most `cap` samples
        let cap = 64;
        let mut tl = Timeline::with_cap(cap);
        for i in 0..(cap as u64 * 1000) {
            tl.push(sample(i as f64));
        }
        assert!(tl.len() <= cap, "len={} cap={cap}", tl.len());
        assert_eq!(tl.offered(), cap as u64 * 1000);
        assert!(tl.stride() >= 1000, "stride={}", tl.stride());
    }

    #[test]
    fn compaction_keeps_whole_run_coverage() {
        let mut tl = Timeline::with_cap(8);
        for i in 0..1000 {
            tl.push(sample(i as f64));
        }
        let s = tl.samples();
        assert!(s.first().map(|x| x.time) == Some(0.0), "lost run start");
        // strided samples stay in time order and span most of the run
        assert!(s.windows(2).all(|w| w[0].time < w[1].time));
        assert!(s.last().map(|x| x.time).unwrap_or(0.0) >= 500.0);
    }

    #[test]
    fn below_cap_keeps_everything() {
        let mut tl = Timeline::with_cap(100);
        for i in 0..50 {
            tl.push(sample(i as f64));
        }
        assert_eq!(tl.len(), 50);
        assert_eq!(tl.stride(), 1);
        assert_eq!(tl.samples()[49].time, 49.0);
    }
}
