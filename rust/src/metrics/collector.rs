//! The per-run metrics collector: everything the experiment reports need,
//! accumulated by the coordinator during simulation.

use std::collections::BTreeMap;

use crate::bayes::classifier::Label;
use crate::cluster::node::NodeId;
use crate::hdfs::Locality;
use crate::job::task::TaskRef;
use crate::job::JobOutcome;
use crate::scheduler::api::Decision;
use crate::sim::engine::Time;
use crate::sim::rng::Pcg;

/// Bound on the per-run outcome reservoir: latency/wait *distributions*
/// (percentiles) are estimated from at most this many jobs, while the
/// means and counts stay exact via streaming sums. Keeps metrics memory
/// O(1) in completed jobs — a million-job run must not retain a million
/// outcomes.
pub const SAMPLE_CAP: usize = 4096;

/// One `--explain` trace entry: what was launched, where, and why.
#[derive(Debug, Clone, Copy)]
pub struct DecisionRecord {
    pub time: Time,
    pub node: NodeId,
    pub task: TaskRef,
    pub decision: Decision,
}

impl std::fmt::Display for DecisionRecord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "t={:>9.2}s {} -> {} {}",
            self.time, self.node, self.task, self.decision
        )
    }
}

/// A point on the overload learning curve (E3): allocations and overload
/// feedback within one window.
#[derive(Debug, Clone, Copy, Default)]
pub struct FeedbackWindow {
    pub allocations: u32,
    pub overloads: u32,
}

/// Collected over one simulation run.
///
/// Job outcomes are folded in **streaming**: exact counters and sums plus
/// a fixed-size reservoir sample (Algorithm R, deterministic seed) for
/// the distribution views. Nothing here grows with completed-job count.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Completed jobs (exact).
    completed: u64,
    /// Sum of job latencies (submit -> finish), exact.
    latency_sum: f64,
    /// Sum of queue waits (submit -> first launch) and its sample count.
    wait_sum: f64,
    wait_n: u64,
    /// Total wasted task attempts (failure re-runs), exact.
    wasted: u64,
    /// Uniform reservoir of (latency, wait) pairs; wait is None for jobs
    /// whose outcome never recorded a first launch.
    sample: Vec<(f64, Option<f64>)>,
    /// Reservoir RNG (fixed seed: replacement choices are part of the
    /// determinism contract). Lazy so `Default` stays derivable.
    sample_rng: Option<Pcg>,
    /// Map-task locality decisions.
    pub locality: BTreeMap<&'static str, u64>,
    /// Total feedback labels seen (good, bad).
    pub feedback: [u64; 2],
    /// Learning curve: one window per `window_allocs` allocations.
    pub windows: Vec<FeedbackWindow>,
    pub window_allocs: u32,
    /// OOM kills (re-queued tasks).
    pub oom_kills: u64,
    /// Jobs killed after exhausting task attempts.
    pub failed_jobs: u64,
    /// TaskTracker failures injected.
    pub node_failures: u64,
    /// Task attempts that ended in failure (OOM kill or node loss).
    pub task_failures: u64,
    /// Speculative backup copies launched.
    pub speculative_launches: u64,
    /// Backup copies that finished before their primary (stragglers saved).
    pub speculative_wins: u64,
    /// Periodic cluster snapshots (empty unless timeline_interval > 0).
    /// Bounded: compacts itself instead of growing with run length.
    pub timeline: super::timeline::Timeline,
    /// Scheduling decisions taken (tasks assigned).
    pub decisions: u64,
    /// Wall-clock time spent inside scheduler assign() calls: a
    /// log-bucketed histogram whose exact count/sum pair doubles as the
    /// old `assign_calls`/`decision_nanos` accumulators. Detached (and
    /// always-on) by default; [`Metrics::install_obs`] swaps in the
    /// registry's `driver_assign_nanos` so the same recordings feed the
    /// experiment tables AND every obs exporter from one code path.
    assign_latency: crate::obs::Histogram,
    /// When true, every assignment's [`Decision`] lands in `decision_log`
    /// (the `--explain` trace).
    pub explain: bool,
    /// Per-assignment explanations (empty unless `explain`).
    pub decision_log: Vec<DecisionRecord>,
    /// Heartbeats processed.
    pub heartbeats: u64,
    /// Virtual time of the last job completion.
    pub makespan: Time,
    /// Sum over nodes of overload-seconds (cluster instability measure).
    pub overload_seconds: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { window_allocs: 100, ..Default::default() }
    }

    /// Fold one completed job's outcome into the streaming accumulators.
    pub fn record_outcome(&mut self, o: JobOutcome) {
        self.makespan = self.makespan.max(o.finish_time);
        let latency = o.finish_time - o.submit_time;
        let wait = o.first_launch.map(|f| f - o.submit_time);
        self.completed += 1;
        self.latency_sum += latency;
        if let Some(w) = wait {
            self.wait_sum += w;
            self.wait_n += 1;
        }
        self.wasted += o.wasted_attempts as u64;
        // Algorithm R: the first SAMPLE_CAP outcomes land in submission
        // order (so small runs see every job, in order); after that each
        // new outcome replaces a uniformly random slot with probability
        // cap/completed.
        if self.sample.len() < SAMPLE_CAP {
            self.sample.push((latency, wait));
        } else {
            let rng = self
                .sample_rng
                .get_or_insert_with(|| Pcg::new(0x5EED_CA55, 0xA11));
            let j = rng.below(self.completed) as usize;
            if j < SAMPLE_CAP {
                self.sample[j] = (latency, wait);
            }
        }
    }

    pub fn record_locality(&mut self, l: Locality) {
        *self.locality.entry(l.name()).or_insert(0) += 1;
    }

    pub fn record_feedback(&mut self, label: Label) {
        self.feedback[label as usize] += 1;
        if self.windows.is_empty() {
            self.windows.push(FeedbackWindow::default());
        }
        let Some(w) = self.windows.last_mut() else { return };
        w.allocations += 1;
        if label == Label::Bad {
            w.overloads += 1;
        }
        if w.allocations >= self.window_allocs {
            self.windows.push(FeedbackWindow::default());
        }
    }

    /// Account one batched assign() call that produced `assigned` tasks.
    pub fn record_assign(&mut self, nanos: u64, assigned: usize) {
        self.decisions += assigned as u64;
        self.assign_latency.record(nanos);
    }

    /// Re-point the assign-latency histogram at an obs registry (as
    /// `driver_assign_nanos`), so decision-latency numbers in the
    /// experiment tables and the exporters come from one recording.
    /// Call before the run starts: any prior recordings stay behind on
    /// the detached histogram.
    pub fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.assign_latency = registry.histogram("driver_assign_nanos");
    }

    /// Batched assign() invocations (at most one per heartbeat).
    pub fn assign_calls(&self) -> u64 {
        self.assign_latency.count()
    }

    /// Keep one assignment's decision for the `--explain` trace.
    pub fn record_trace(
        &mut self,
        time: Time,
        node: NodeId,
        task: TaskRef,
        decision: Decision,
    ) {
        if self.explain {
            self.decision_log.push(DecisionRecord { time, node, task, decision });
        }
    }

    /// Completed-job count (exact).
    pub fn completed_jobs(&self) -> usize {
        self.completed as usize
    }

    /// Job latency (submit -> finish) samples — the full population up to
    /// [`SAMPLE_CAP`] jobs, a uniform reservoir beyond that.
    pub fn latencies(&self) -> Vec<f64> {
        self.sample.iter().map(|&(l, _)| l).collect()
    }

    /// Queue-wait (submit -> first task launch) samples (same reservoir).
    pub fn waits(&self) -> Vec<f64> {
        self.sample.iter().filter_map(|&(_, w)| w).collect()
    }

    /// Mean job latency over **all** completed jobs (exact, streaming).
    pub fn mean_latency(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_sum / self.completed as f64
        }
    }

    /// Mean queue wait over all jobs that launched (exact, streaming).
    pub fn mean_wait(&self) -> f64 {
        if self.wait_n == 0 {
            0.0
        } else {
            self.wait_sum / self.wait_n as f64
        }
    }

    /// Jobs per second of virtual time.
    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.makespan
        }
    }

    /// Fraction of map tasks that ran node-local.
    pub fn locality_fraction(&self, name: &str) -> f64 {
        let total: u64 = self.locality.values().sum();
        if total == 0 {
            return 0.0;
        }
        *self.locality.get(name).unwrap_or(&0) as f64 / total as f64
    }

    /// Overload rate among all feedback samples.
    pub fn overload_rate(&self) -> f64 {
        let total = self.feedback[0] + self.feedback[1];
        if total == 0 {
            0.0
        } else {
            self.feedback[1] as f64 / total as f64
        }
    }

    /// Mean scheduler cost per assigned task, microseconds (assign() time
    /// amortized over the tasks it placed).
    pub fn mean_decision_micros(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.assign_latency.sum() as f64 / self.decisions as f64 / 1000.0
        }
    }

    /// Mean per-heartbeat batch latency in microseconds (one assign() call
    /// scores the queue once and fills every free slot).
    pub fn mean_assign_micros(&self) -> f64 {
        self.assign_latency.mean() / 1000.0
    }

    /// Wasted task attempts across all jobs (failure re-runs, exact).
    pub fn wasted_attempts(&self) -> u64 {
        self.wasted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(submit: f64, finish: f64) -> JobOutcome {
        JobOutcome {
            submit_time: submit,
            first_launch: Some(submit + 1.0),
            finish_time: finish,
            wasted_attempts: 2,
        }
    }

    #[test]
    fn makespan_tracks_max_finish() {
        let mut m = Metrics::new();
        m.record_outcome(outcome(0.0, 50.0));
        m.record_outcome(outcome(10.0, 30.0));
        assert_eq!(m.makespan, 50.0);
        assert_eq!(m.completed_jobs(), 2);
        assert_eq!(m.latencies(), vec![50.0, 20.0]);
        assert_eq!(m.waits(), vec![1.0, 1.0]);
        assert_eq!(m.mean_latency(), 35.0);
        assert_eq!(m.mean_wait(), 1.0);
        assert_eq!(m.throughput(), 2.0 / 50.0);
        assert_eq!(m.wasted_attempts(), 4);
    }

    #[test]
    fn reservoir_is_bounded_but_counts_stay_exact() {
        let mut m = Metrics::new();
        let n = SAMPLE_CAP + 1000;
        for i in 0..n {
            m.record_outcome(outcome(i as f64, i as f64 + 7.0));
        }
        assert_eq!(m.completed_jobs(), n);
        assert_eq!(m.latencies().len(), SAMPLE_CAP);
        assert!(m.waits().len() <= SAMPLE_CAP);
        assert_eq!(m.mean_latency(), 7.0);
        assert_eq!(m.wasted_attempts(), 2 * n as u64);
        // every reservoir entry is a real observation
        assert!(m.latencies().iter().all(|&l| l == 7.0));
    }

    #[test]
    fn reservoir_replacement_is_deterministic() {
        let run = || {
            let mut m = Metrics::new();
            for i in 0..(SAMPLE_CAP + 500) {
                m.record_outcome(outcome(0.0, (i % 97) as f64 + 1.0));
            }
            m.latencies()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn feedback_windows_roll() {
        let mut m = Metrics::new();
        m.window_allocs = 10;
        for i in 0..25 {
            let l = if i % 5 == 0 { Label::Bad } else { Label::Good };
            m.record_feedback(l);
        }
        assert_eq!(m.feedback, [20, 5]);
        assert_eq!(m.windows.len(), 3);
        assert_eq!(m.windows[0].allocations, 10);
        assert_eq!(m.windows[0].overloads, 2);
        assert_eq!(m.windows[2].allocations, 5);
        assert!((m.overload_rate() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn locality_fractions() {
        let mut m = Metrics::new();
        for _ in 0..3 {
            m.record_locality(Locality::NodeLocal);
        }
        m.record_locality(Locality::Remote);
        assert_eq!(m.locality_fraction("node_local"), 0.75);
        assert_eq!(m.locality_fraction("remote"), 0.25);
        assert_eq!(m.locality_fraction("rack_local"), 0.0);
    }

    #[test]
    fn assign_and_decision_latency() {
        let mut m = Metrics::new();
        m.record_assign(2000, 1);
        m.record_assign(4000, 2);
        assert_eq!(m.assign_calls(), 2);
        assert_eq!(m.decisions, 3);
        assert_eq!(m.mean_assign_micros(), 3.0);
        assert_eq!(m.mean_decision_micros(), 2.0);
    }

    #[test]
    fn install_obs_routes_assign_latency_into_the_registry() {
        let registry = crate::obs::Registry::new();
        let mut m = Metrics::new();
        m.record_assign(999, 1); // stays behind on the detached histogram
        m.install_obs(&registry);
        m.record_assign(2000, 1);
        m.record_assign(4000, 2);
        assert_eq!(m.assign_calls(), 2);
        assert_eq!(m.mean_assign_micros(), 3.0);
        let h = registry.histogram("driver_assign_nanos");
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), 6000);
    }

    #[test]
    fn trace_only_recorded_when_explain() {
        use crate::job::task::TaskKind;
        use crate::job::JobId;
        use crate::scheduler::api::Decision;
        let rec = |m: &mut Metrics| {
            m.record_trace(
                1.0,
                NodeId(0),
                TaskRef { job: JobId::dense(0), kind: TaskKind::Map, index: 0 },
                Decision::unscored(JobId::dense(0), TaskKind::Map, None, 1),
            )
        };
        let mut m = Metrics::new();
        rec(&mut m);
        assert!(m.decision_log.is_empty());
        m.explain = true;
        rec(&mut m);
        assert_eq!(m.decision_log.len(), 1);
        assert!(m.decision_log[0].to_string().contains("job_0000"));
    }
}
