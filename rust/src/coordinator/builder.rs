//! Convenience builder: assemble a [`JobTracker`] from an experiment
//! config, including the XLA-backed Bayes scheduler variant.

use std::path::Path;

use crate::errors::{anyhow, Result};

use crate::bayes::classifier::NaiveBayes;
use crate::cluster::Cluster;
use crate::job::job::JobSpec;
use crate::runtime::XlaClassifier;
use crate::scheduler::{self, BayesScheduler, Scheduler, StarvationPolicy};
use crate::workload::generator::{generate, WorkloadConfig};

use super::jobtracker::{JobTracker, TrackerConfig};

/// Declarative run description (mirrors the TOML config schema).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub scheduler: String,
    pub n_nodes: u32,
    pub n_racks: u32,
    pub workload: WorkloadConfig,
    pub tracker: TrackerConfig,
    /// Laplace alpha for bayes variants.
    pub alpha: f32,
    /// Starvation policy for bayes variants.
    pub starvation_wait: bool,
    /// Artifacts dir for `bayes-xla`.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Warm-start model for `bayes` (JSON from `--save-model`).
    pub model_path: Option<std::path::PathBuf>,
    /// Observability layer (`--obs-*` flags). Disabled by default; when
    /// any exporter output is requested the run drivers call
    /// `enable_obs`/`finish_obs` around `run()`.
    pub obs: crate::obs::ObsOptions,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            scheduler: "bayes".into(),
            n_nodes: 40,
            n_racks: 4,
            workload: WorkloadConfig::default(),
            tracker: TrackerConfig::default(),
            alpha: 1.0,
            starvation_wait: false,
            artifacts_dir: None,
            model_path: None,
            obs: crate::obs::ObsOptions::default(),
        }
    }
}

/// Build the scheduler named in the config.
pub fn build_scheduler(cfg: &RunConfig) -> Result<Box<dyn Scheduler>> {
    let policy = if cfg.starvation_wait {
        StarvationPolicy::Wait
    } else {
        StarvationPolicy::WaitUnlessIdle
    };
    match cfg.scheduler.as_str() {
        "bayes" => {
            let nb = match &cfg.model_path {
                Some(p) => crate::bayes::persist::load(p)?,
                None => NaiveBayes::new(cfg.alpha),
            };
            Ok(Box::new(BayesScheduler::new(nb).with_policy(policy)))
        }
        "bayes-xla" => {
            if cfg.model_path.is_some() {
                return Err(anyhow!(
                    "--load-model is only supported with scheduler 'bayes'                      (the XLA path derives its state from feedback)"
                ));
            }
            let dir = cfg
                .artifacts_dir
                .clone()
                .unwrap_or_else(crate::runtime::artifacts::default_dir);
            let classifier = XlaClassifier::load(Path::new(&dir), cfg.alpha)?;
            Ok(Box::new(BayesScheduler::new(classifier).with_policy(policy)))
        }
        name => scheduler::by_name(name, cfg.workload.seed)
            .ok_or_else(|| anyhow!("unknown scheduler '{name}'")),
    }
}

/// Build a complete tracker (cluster + workload + scheduler).
pub fn build_tracker(cfg: &RunConfig) -> Result<JobTracker> {
    let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
    let specs = generate(&cfg.workload);
    build_tracker_with(cfg, cluster, specs)
}

/// Build with an explicit cluster and job stream (heterogeneous / replay
/// experiments).
pub fn build_tracker_with(
    cfg: &RunConfig,
    cluster: Cluster,
    specs: Vec<JobSpec>,
) -> Result<JobTracker> {
    let sched = build_scheduler(cfg)?;
    Ok(JobTracker::new(
        cluster,
        sched,
        specs,
        cfg.workload.seed,
        cfg.tracker.clone(),
    ))
}

/// Build over a streaming spec source (bounded-memory trace replay):
/// the specs never materialize as a vector. The iterator must yield
/// nondecreasing `submit_time`s, like [`JobTracker::new_streaming`]
/// requires.
pub fn build_tracker_streaming(
    cfg: &RunConfig,
    cluster: Cluster,
    specs: Box<dyn Iterator<Item = JobSpec>>,
) -> Result<JobTracker> {
    let sched = build_scheduler(cfg)?;
    Ok(JobTracker::new_streaming(
        cluster,
        sched,
        specs,
        cfg.workload.seed,
        cfg.tracker.clone(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_named_scheduler() {
        for name in crate::scheduler::ALL_NAMES {
            let cfg = RunConfig { scheduler: name.into(), ..Default::default() };
            assert!(build_scheduler(&cfg).is_ok(), "{name}");
        }
    }

    #[test]
    fn unknown_scheduler_errors() {
        let cfg = RunConfig { scheduler: "nope".into(), ..Default::default() };
        assert!(build_scheduler(&cfg).is_err());
    }

    #[test]
    fn end_to_end_tiny_run() {
        let cfg = RunConfig {
            scheduler: "bayes".into(),
            n_nodes: 4,
            n_racks: 2,
            workload: WorkloadConfig { n_jobs: 6, ..Default::default() },
            ..Default::default()
        };
        let mut jt = build_tracker(&cfg).unwrap();
        jt.run();
        assert!(jt.jobs.all_complete());
    }
}
