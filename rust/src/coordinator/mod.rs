//! L3 coordinator: the JobTracker event loop (MRv1 leader) and the run
//! builder that assembles cluster + workload + scheduler from a config.

pub mod builder;
pub mod jobtracker;

pub use builder::{
    build_scheduler, build_tracker, build_tracker_streaming, build_tracker_with,
    RunConfig,
};
pub use jobtracker::{JobTracker, TrackerConfig};
