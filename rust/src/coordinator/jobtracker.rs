//! The JobTracker: "the center of the Map-reduce framework, which needs to
//! communicate with the cluster machine timing (heartbeat), and need to
//! manage what program should be run on which machines, to manage job
//! failed, restart operation" (paper §1).
//!
//! Drives the discrete-event simulation: job arrivals enter the queue,
//! TaskTracker heartbeats trigger scheduling decisions and overload-rule
//! feedback, task completions update job progress, OOM kills and node
//! deaths re-queue (or fail over) task attempts, and every lifecycle
//! transition is narrated to the scheduler through the [`SchedEvent`]
//! stream — including the failure detail the learned policy conditions on.
//!
//! Speculative execution: a scheduler may propose a backup copy of a
//! running task (see `scheduler/api.rs` module docs, D6). The tracker
//! launches it like any attempt; the first copy to complete wins and the
//! loser is cancelled through per-attempt event stamps.

use crate::analysis::protocol::{AuditEvent, AuditSink};
use crate::bayes::classifier::Label;
use crate::bayes::features::FailureHistory;
use crate::bayes::overload::OverloadRule;
use crate::cluster::heartbeat::HeartbeatConfig;
use crate::cluster::node::NodeId;
use crate::cluster::Cluster;
use crate::hdfs::locality::{locality_multiplier, locality_net_demand};
use crate::hdfs::Namespace;
use crate::job::job::JobSpec;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef, TaskState};
use crate::job::JobId;
use crate::metrics::Metrics;
use crate::obs::{DriverObs, ObsOptions, Stopwatch};
use crate::scheduler::api::{
    Assignment, FailReason, OBS_EVENT_NAMES, SchedEvent, SchedView, Scheduler, SlotBudget,
};
use crate::sim::engine::{Engine, Time};
use crate::sim::event::Event;

/// A placement awaiting overload-rule judgment at the node's next
/// heartbeat (deviation D5: "next hop" = next heartbeat).
#[derive(Debug, Clone, Copy)]
struct PendingFeedback {
    feats: crate::bayes::features::FeatureVec,
}

/// Which live attempt of a task an event refers to (speculative execution
/// gives a task up to two concurrent attempts on two different nodes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Attempt {
    Primary,
    Backup,
}

/// Node failure injection: exponential time-to-failure / time-to-repair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureConfig {
    /// Mean time between failures per node, seconds. None = no failures.
    pub mtbf: Option<f64>,
    /// Mean time to repair, seconds.
    pub mttr: f64,
}

impl Default for FailureConfig {
    fn default() -> Self {
        FailureConfig { mtbf: None, mttr: 120.0 }
    }
}

/// JobTracker configuration knobs.
#[derive(Debug, Clone)]
pub struct TrackerConfig {
    pub heartbeat: HeartbeatConfig,
    pub overload_rule: OverloadRule,
    pub failures: FailureConfig,
    /// Seconds between cluster-utilization timeline samples (0 = off).
    pub timeline_interval: f64,
    /// Seconds an OOM-doomed task survives before being killed.
    pub oom_kill_delay: f64,
    /// A task failing this many times kills its job (Hadoop's
    /// mapreduce.*.maxattempts semantics; breaks OOM-churn livelock).
    pub max_task_attempts: u32,
    /// Hard stop for the virtual clock (safety net against livelock).
    pub max_sim_time: Time,
    /// Max schedulable jobs exposed per heartbeat (`SchedView::queue` is
    /// the first `queue_cap` jobs of the backlog, submission order). At
    /// million-job scale this bounds one heartbeat's scoring work to
    /// O(cap) instead of O(backlog); `usize::MAX` = the full queue.
    pub queue_cap: usize,
    /// Recycle a job's arena slot once it leaves the system fully drained
    /// (keeps the job table O(active) on huge runs). Off by default:
    /// tests and reports inspect completed jobs in place.
    pub reclaim_jobs: bool,
}

impl Default for TrackerConfig {
    fn default() -> Self {
        TrackerConfig {
            heartbeat: HeartbeatConfig::default(),
            overload_rule: OverloadRule::default(),
            failures: FailureConfig::default(),
            timeline_interval: 0.0,
            oom_kill_delay: 4.0,
            max_task_attempts: 4,
            max_sim_time: 1e7,
            queue_cap: usize::MAX,
            reclaim_jobs: false,
        }
    }
}

/// The leader: owns every substrate plus the pluggable scheduler.
pub struct JobTracker {
    pub engine: Engine,
    pub cluster: Cluster,
    pub hdfs: Namespace,
    pub jobs: JobTable,
    pub scheduler: Box<dyn Scheduler>,
    pub metrics: Metrics,
    pub cfg: TrackerConfig,
    /// Failure history feeding the failure-aware features; maintained here
    /// (the tracker observes every attempt end) and shared with the
    /// scheduler through `SchedView::failures`.
    pub failures: FailureHistory,
    /// Workload in submit-time order, drained into arrival events. A boxed
    /// iterator so million-job runs can stream specs into existence
    /// instead of materializing them all up front
    /// ([`JobTracker::new_streaming`]).
    pending_specs: Box<dyn Iterator<Item = JobSpec>>,
    /// The spec whose arrival event is in flight (submitted when it fires,
    /// so jobs are never schedulable before their submit time).
    next_spec: Option<JobSpec>,
    /// Per-node placements since that node's last heartbeat.
    pending_feedback: Vec<Vec<PendingFeedback>>,
    /// Attempts doomed to OOM, per node (a speculative pair can doom
    /// independently): excluded from completion rescheduling so their
    /// pending TaskFail event stays valid. A node runs a handful of tasks,
    /// so the inner vectors are scanned linearly — allocation-free and
    /// faster than hashing at this size.
    doomed: Vec<Vec<TaskRef>>,
    /// Launch-time feature rows of in-flight attempts, per node, so an OOM
    /// kill can feed back a `Bad` sample for the exact row the decision
    /// was scored on.
    inflight_feats: Vec<Vec<(TaskRef, crate::bayes::features::FeatureVec)>>,
    /// Scratch buffer for the per-heartbeat queue view (reused across
    /// heartbeats; capped at `cfg.queue_cap`).
    queue_scratch: Vec<JobId>,
    /// Failure-injection RNG (own stream: does not perturb workloads).
    fail_rng: crate::sim::rng::Pcg,
    arrivals_done: bool,
    /// Protocol audit tap: every scheduler-visible event plus driver-side
    /// launch/end records flow through here. Debug builds shadow-audit by
    /// default; release builds run disabled (zero overhead).
    pub audit: AuditSink,
    /// Observability tap (event counters, latency histograms, span
    /// tracer). Disabled — a single `Option` check per use — until
    /// [`JobTracker::enable_obs`].
    pub obs: DriverObs,
}

impl JobTracker {
    /// Build a tracker. `specs` need not be sorted; they are submitted in
    /// `submit_time` order.
    pub fn new(
        cluster: Cluster,
        scheduler: Box<dyn Scheduler>,
        mut specs: Vec<JobSpec>,
        seed: u64,
        cfg: TrackerConfig,
    ) -> JobTracker {
        specs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time));
        JobTracker::new_streaming(cluster, scheduler, Box::new(specs.into_iter()), seed, cfg)
    }

    /// Build a tracker over a streaming workload: `specs` is pulled one
    /// job ahead of the virtual clock, so a million-job run never holds
    /// more than one unsubmitted spec in memory. The iterator MUST yield
    /// specs in nondecreasing `submit_time` order (workload generators
    /// produce cumulative arrival times, so their streams qualify; an
    /// out-of-order spec would have its arrival clamped to `now` and
    /// counted in `engine.clamped_events()`).
    pub fn new_streaming(
        cluster: Cluster,
        scheduler: Box<dyn Scheduler>,
        specs: Box<dyn Iterator<Item = JobSpec>>,
        seed: u64,
        cfg: TrackerConfig,
    ) -> JobTracker {
        let n_nodes = cluster.len();
        let hdfs = Namespace::new(
            cluster.topology.n_nodes,
            cluster.topology.n_racks,
            seed,
        );
        let reclaim = cfg.reclaim_jobs;
        let mut jt = JobTracker {
            engine: Engine::new(),
            cluster,
            hdfs,
            jobs: JobTable::new(),
            scheduler,
            metrics: Metrics::new(),
            cfg,
            failures: FailureHistory::new(),
            pending_specs: specs,
            next_spec: None,
            pending_feedback: vec![Vec::new(); n_nodes],
            doomed: vec![Vec::new(); n_nodes],
            inflight_feats: vec![Vec::new(); n_nodes],
            queue_scratch: Vec::new(),
            fail_rng: crate::sim::rng::Pcg::new(seed, 0xFA11),
            arrivals_done: false,
            audit: AuditSink::default_for_build(),
            obs: DriverObs::default(),
        };
        jt.jobs.set_reclaim(reclaim);
        jt.emit_preamble();
        // prime: first arrival + first heartbeat per node (+ failures)
        jt.schedule_next_arrival();
        for node in jt.cluster.topology.all_nodes() {
            let t = jt.cfg.heartbeat.first_beat(node);
            jt.engine.schedule(t, Event::Heartbeat(node));
            jt.schedule_next_failure(node);
        }
        if jt.cfg.timeline_interval > 0.0 {
            jt.engine.schedule(jt.cfg.timeline_interval, Event::MetricsTick);
        }
        jt
    }

    /// Feed one scheduler-visible event through the audit tap and then to
    /// the scheduler. Every `SchedEvent` the tracker produces MUST go
    /// through here — a direct `scheduler.observe` call would hide the
    /// event from the protocol auditor.
    fn emit(&mut self, ev: SchedEvent) {
        self.audit.sched(&ev);
        self.obs.on_event(ev.obs_index(), ev.obs_name(), self.engine.now());
        self.scheduler.observe(&ev);
    }

    /// The audit preamble (node capacities + cluster info). The
    /// `ClusterInfo` half also goes to the scheduler — it is the startup
    /// notification the trait contract promises.
    fn emit_preamble(&mut self) {
        for n in &self.cluster.nodes {
            self.audit.push(AuditEvent::NodeSpec {
                node: n.id,
                maps: n.spec.map_slots,
                reduces: n.spec.reduce_slots,
            });
        }
        self.emit(SchedEvent::ClusterInfo { total_slots: self.cluster.total_slots() });
    }

    /// Swap in an audit sink (recording or collecting mode). Call before
    /// `run()`: the preamble is replayed into the new sink so a recorded
    /// trace is self-contained. The scheduler does NOT re-observe it.
    pub fn set_audit(&mut self, mut sink: AuditSink) {
        for n in &self.cluster.nodes {
            sink.push(AuditEvent::NodeSpec {
                node: n.id,
                maps: n.spec.map_slots,
                reduces: n.spec.reduce_slots,
            });
        }
        sink.push(AuditEvent::Sched(SchedEvent::ClusterInfo {
            total_slots: self.cluster.total_slots(),
        }));
        self.audit = sink;
    }

    /// Switch on the observability layer: event counters, driver latency
    /// histograms, and the span tracer, plus whatever the installed
    /// scheduler registers for itself. Call before `run()`.
    pub fn enable_obs(&mut self, opts: &ObsOptions) {
        let registry = self.obs.enable(opts, &OBS_EVENT_NAMES);
        self.scheduler.install_obs(&registry);
        self.metrics.install_obs(&registry);
    }

    /// Drain engine counters into gauges and write every exporter file
    /// requested in `opts`. Call after `run()`; a no-op when obs was
    /// never enabled.
    pub fn finish_obs(&mut self, opts: &ObsOptions) -> crate::errors::Result<()> {
        if let Some((registry, tracer, windows)) = self.obs.finish(self.engine.now()) {
            registry.gauge("engine_events_dispatched").set(self.engine.processed());
            registry.gauge("engine_clamped_events").set(self.engine.clamped_events());
            registry.gauge("engine_bucket_scan_steps").set(self.engine.scan_steps());
            crate::obs::export::write_all(opts, &registry, &tracer, &windows)?;
        }
        Ok(())
    }

    fn schedule_next_failure(&mut self, node: NodeId) {
        if let Some(mtbf) = self.cfg.failures.mtbf {
            let dt = self.fail_rng.exp(1.0 / mtbf);
            self.engine.schedule_in(dt, Event::NodeFail(node));
        }
    }

    fn schedule_next_arrival(&mut self) {
        match self.pending_specs.next() {
            Some(spec) => {
                let at = spec.submit_time;
                self.next_spec = Some(spec);
                // payload-free: the spec is submitted when the event fires
                self.engine.schedule(at, Event::JobArrival);
            }
            None => self.arrivals_done = true,
        }
    }

    fn on_job_arrival(&mut self) {
        if let Some(spec) = self.next_spec.take() {
            let id = self.jobs.submit(spec, &mut self.hdfs);
            self.audit.push(AuditEvent::JobArrived { job: id });
        }
        self.schedule_next_arrival();
    }

    /// Run until every job completes (or `max_sim_time`).
    /// Returns the virtual makespan.
    pub fn run(&mut self) -> Time {
        while let Some((t, ev)) = self.engine.pop() {
            if t > self.cfg.max_sim_time {
                crate::obs_log!(
                    crate::obs::log::WARN,
                    "warning: hit max_sim_time with {} active jobs",
                    self.jobs.active_count()
                );
                break;
            }
            // close any window boundaries the clock just crossed; reads
            // only, so the sim stays bit-identical with obs on
            self.obs.window_tick(t);
            match ev {
                Event::JobArrival => self.on_job_arrival(),
                Event::Heartbeat(node) => self.on_heartbeat(node),
                Event::TaskComplete { node, task, generation } => {
                    self.on_task_complete(node, task, generation)
                }
                Event::TaskFail { node, task, generation } => {
                    self.on_task_fail(node, task, generation)
                }
                Event::NodeFail(node) => self.on_node_fail(node),
                Event::NodeRecover(node) => self.on_node_recover(node),
                Event::MetricsTick => self.on_metrics_tick(),
            }
            if self.arrivals_done
                && self.jobs.all_complete()
                && !self.jobs.is_empty()
                && self.cluster.nodes.iter().all(|n| n.running().is_empty())
            {
                break;
            }
        }
        self.finalize_metrics();
        self.metrics.makespan
    }

    fn finalize_metrics(&mut self) {
        self.metrics.overload_seconds =
            self.cluster.nodes.iter().map(|n| n.overload_seconds).sum();
        self.metrics.oom_kills =
            self.cluster.nodes.iter().map(|n| n.oom_kills as u64).sum();
    }

    // --------------------------------------------------------- attempts --

    fn doom_insert(&mut self, node: NodeId, tref: TaskRef) {
        self.doomed[node.0 as usize].push(tref);
    }

    fn doom_remove(&mut self, node: NodeId, tref: &TaskRef) {
        self.doomed[node.0 as usize].retain(|t| t != tref);
    }

    fn doom_contains(&self, node: NodeId, tref: &TaskRef) -> bool {
        self.doomed[node.0 as usize].contains(tref)
    }

    fn feats_insert(
        &mut self,
        node: NodeId,
        tref: TaskRef,
        feats: crate::bayes::features::FeatureVec,
    ) {
        self.inflight_feats[node.0 as usize].push((tref, feats));
    }

    fn feats_remove(
        &mut self,
        node: NodeId,
        tref: &TaskRef,
    ) -> Option<crate::bayes::features::FeatureVec> {
        let v = &mut self.inflight_feats[node.0 as usize];
        let i = v.iter().position(|(t, _)| t == tref)?;
        Some(v.swap_remove(i).1)
    }

    /// Resolve which live attempt of `tref` an event with `(node,
    /// generation)` refers to; `None` = the event is stale.
    fn current_attempt(
        &self,
        tref: &TaskRef,
        node: NodeId,
        generation: u32,
    ) -> Option<Attempt> {
        // a released (reclaimed) job makes every in-flight event stale
        let task = self.jobs.try_get(tref.job)?.task(tref);
        if let TaskState::Running { node: n, .. } = task.state {
            if n == node && task.generation == generation {
                return Some(Attempt::Primary);
            }
        }
        if let Some(s) = task.speculative {
            if s.node == node && task.spec_generation == generation {
                return Some(Attempt::Backup);
            }
        }
        None
    }

    /// Remove the losing copy of `tref` from `node_id` (it was cancelled
    /// because the other copy won). Reported as a `TaskFinished` — a
    /// cancelled loser is not a failure signal.
    fn cancel_attempt_on(&mut self, node_id: NodeId, tref: TaskRef, now: Time) {
        self.cluster.node_mut(node_id).advance(now);
        let (_rec, horizons) = self.cluster.node_mut(node_id).remove_task(&tref, now);
        self.doom_remove(node_id, &tref);
        self.feats_remove(node_id, &tref);
        self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
        self.emit(SchedEvent::TaskFinished {
            job: tref.job,
            node: node_id,
            kind: tref.kind,
        });
        self.reschedule(node_id, horizons);
    }

    /// If `id` has left the system (succeeded or killed) and no attempt of
    /// it remains on any node, tell the scheduler it is gone and drop its
    /// failure history. Every attempt-end path funnels through this, so
    /// the notification fires exactly once, after the true last attempt.
    fn notify_if_drained(&mut self, id: JobId) {
        let Some(job) = self.jobs.try_get(id) else { return };
        if job.finish_time.is_some() && job.fully_drained() {
            self.emit(SchedEvent::JobCompleted { job: id });
            self.failures.forget_job(id);
            // recycle the arena slot (no-op unless cfg.reclaim_jobs)
            self.jobs.release(id);
        }
    }

    // ---------------------------------------------------------- failure --

    fn on_node_fail(&mut self, node_id: NodeId) {
        if !self.cluster.node(node_id).alive {
            return;
        }
        let now = self.engine.now();
        self.metrics.node_failures += 1;
        // lost attempts: every task copy the node was running. Stale
        // completion events die via the per-attempt stamp checks.
        let lost = self.cluster.node_mut(node_id).fail(now);
        for rec in lost {
            let tref = rec.task;
            self.doom_remove(node_id, &tref);
            self.feats_remove(node_id, &tref);
            self.failures.record_failure(tref.job, node_id, now);
            self.metrics.task_failures += 1;
            let task = self.jobs.get(tref.job).task(&tref);
            let attempt = task.attempts;
            let lost_backup =
                task.speculative.is_some_and(|s| s.node == node_id);
            let surviving_backup = !lost_backup && task.speculative.is_some();
            self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
            self.emit(SchedEvent::TaskFailed {
                job: tref.job,
                node: node_id,
                kind: tref.kind,
                attempt,
                reason: FailReason::NodeLost,
            });
            if lost_backup {
                // the backup died; the primary keeps running elsewhere
                self.jobs.get_mut(tref.job).task_mut(&tref).cancel_speculative();
            } else if surviving_backup {
                // the primary died but its backup lives: fail over in
                // place, no work re-queued
                self.jobs.get_mut(tref.job).task_mut(&tref).promote_speculative();
            } else if self.jobs.get(tref.job).finish_time.is_none() {
                self.jobs.requeue_task(&tref);
            } else {
                // keep the task state machine consistent for drained jobs
                self.jobs.get_mut(tref.job).task_mut(&tref).requeue();
            }
            self.notify_if_drained(tref.job);
        }
        self.pending_feedback[node_id.0 as usize].clear();
        self.emit(SchedEvent::NodeFailed { node: node_id });
        let mttr = self.cfg.failures.mttr.max(1.0);
        let dt = self.fail_rng.exp(1.0 / mttr);
        self.engine.schedule_in(dt, Event::NodeRecover(node_id));
    }

    fn on_node_recover(&mut self, node_id: NodeId) {
        let now = self.engine.now();
        self.cluster.node_mut(node_id).recover(now);
        self.emit(SchedEvent::NodeRecovered { node: node_id });
        // rejoin the heartbeat cycle and the failure process
        self.engine
            .schedule(self.cfg.heartbeat.next_beat(now), Event::Heartbeat(node_id));
        self.schedule_next_failure(node_id);
    }

    fn on_metrics_tick(&mut self) {
        let now = self.engine.now();
        let mut util = 0.0;
        let mut running = 0usize;
        let mut alive = 0usize;
        for n in &self.cluster.nodes {
            if n.alive {
                alive += 1;
                util += n.utilization().max_component().min(2.0);
                running += n.running().len();
            }
        }
        self.metrics.timeline.push(crate::metrics::TimelineSample {
            time: now,
            mean_bottleneck_util: if alive > 0 { util / alive as f64 } else { 0.0 },
            running_tasks: running as u32,
            queued_jobs: self.jobs.ready_count() as u32,
            alive_nodes: alive as u32,
        });
        if !self.arrivals_done || !self.jobs.all_complete() {
            self.engine
                .schedule_in(self.cfg.timeline_interval, Event::MetricsTick);
        }
    }

    // -------------------------------------------------------- heartbeat --

    fn on_heartbeat(&mut self, node_id: NodeId) {
        if !self.cluster.node(node_id).alive {
            return; // dead node: heartbeats resume on recovery
        }
        let now = self.engine.now();
        let hb_sw = self.obs.is_enabled().then(Stopwatch::start);
        self.metrics.heartbeats += 1;
        self.cluster.node_mut(node_id).advance(now);

        // 1. overload-rule feedback for placements since the last beat
        let pending = std::mem::take(&mut self.pending_feedback[node_id.0 as usize]);
        if !pending.is_empty() {
            let obs = self.cluster.node(node_id).observation();
            let label = self.cfg.overload_rule.label(&obs);
            for p in pending {
                self.emit(SchedEvent::Feedback { feats: p.feats, label });
                self.metrics.record_feedback(label);
            }
        }

        // 2. one batched assign() call fills every free slot of this
        // heartbeat (perf §Perf: the queue is scored once per heartbeat,
        // not once per slot — Hadoop's assignTasks batch semantics). The
        // call happens even with an empty pending queue: schedulers with a
        // straggler path propose speculative copies exactly when nothing
        // is pending but slow attempts are still running.
        let (budget, node_total_slots) = {
            let node = self.cluster.node(node_id);
            (
                SlotBudget {
                    maps: node.free_slots(TaskKind::Map),
                    reduces: node.free_slots(TaskKind::Reduce),
                },
                node.spec.map_slots + node.spec.reduce_slots,
            )
        };
        // reuse the scratch buffer for the (possibly capped) queue view —
        // no per-heartbeat allocation once the buffer is warm
        let mut queue = std::mem::take(&mut self.queue_scratch);
        self.jobs.schedulable_prefix(self.cfg.queue_cap, &mut queue);
        if budget.total() > 0 {
            // snapshot the features the whole batch was scored against, so
            // each placement's feedback sample matches its decision input
            let node_feats = self.cluster.node(node_id).features();
            let (assignments, assign_nanos) = {
                let view = SchedView {
                    jobs: &self.jobs,
                    hdfs: &self.hdfs,
                    queue: &queue,
                    failures: &self.failures,
                    now,
                };
                let node = self.cluster.node(node_id);
                // real (not virtual) time: measures the scheduler's own
                // compute cost for E6
                let sw = Stopwatch::start();
                let out = self.scheduler.assign(&view, node, budget);
                (out, sw.elapsed_nanos())
            };
            let mut launched = 0usize;
            for a in assignments {
                // driver-side validation: the batch contract forbids these,
                // but a buggy scheduler must not corrupt the simulation
                if a.decision.speculative {
                    let valid = self.cluster.node(node_id).free_slots(a.task.kind)
                        > 0
                        && self.speculation_target_ok(&a.task, node_id);
                    debug_assert!(valid, "broken speculative proposal: {}", a.task);
                    if !valid {
                        continue;
                    }
                    self.launch(a, node_id, now, &node_feats, true);
                } else {
                    let valid = self.cluster.node(node_id).free_slots(a.task.kind)
                        > 0
                        && self.jobs.get(a.task.job).task(&a.task).is_pending();
                    debug_assert!(valid, "scheduler broke the batch contract: {}", a.task);
                    if !valid {
                        continue;
                    }
                    self.launch(a, node_id, now, &node_feats, false);
                }
                launched += 1;
            }
            // metrics count what actually launched, not what was proposed
            self.metrics.record_assign(assign_nanos, launched);
            if self.obs.is_enabled() {
                let total = u64::from(node_total_slots);
                let free = u64::from(budget.total());
                let util_pct =
                    if total == 0 { 0 } else { (total - free) * 100 / total };
                self.obs
                    .record_assign(now, assign_nanos, launched, queue.len(), util_pct);
            }
        }
        self.queue_scratch = queue;

        // 3. next beat — only while there is (or may be) work
        if !self.arrivals_done || !self.jobs.all_complete() {
            self.engine.schedule(
                self.cfg.heartbeat.next_beat(now),
                Event::Heartbeat(node_id),
            );
        }
        if let Some(sw) = hb_sw {
            self.obs.record_heartbeat(now, sw.elapsed_nanos());
        }
    }

    /// Speculation contract: the task's primary runs on a *different*
    /// node, no backup exists yet, and the job is still live.
    fn speculation_target_ok(&self, tref: &TaskRef, node_id: NodeId) -> bool {
        let job = self.jobs.get(tref.job);
        if job.finish_time.is_some() {
            return false;
        }
        let task = job.task(tref);
        task.speculative.is_none()
            && matches!(task.state, TaskState::Running { node: n, .. } if n != node_id)
    }

    // ----------------------------------------------------------- launch --

    /// Per-attempt demand/work for launching `tref` on `node_id`, adjusted
    /// for input locality (recorded in metrics).
    fn attempt_demand_work(
        &mut self,
        tref: &TaskRef,
        node_id: NodeId,
    ) -> (crate::cluster::resources::Resources, f64) {
        let job = self.jobs.get(tref.job);
        let mut demand = job.demand;
        let mut work = job.task(tref).work;
        if tref.kind == TaskKind::Map {
            // submit() assigns every map a block -- lint: allow(unwrap-in-lib)
            let block = job.task(tref).block.expect("map without block");
            let loc = self.hdfs.locality(block, node_id);
            self.metrics.record_locality(loc);
            work *= locality_multiplier(loc);
            demand.net += locality_net_demand(loc);
        } else {
            // shuffle traffic: reduces pull map output across the network
            demand.net += 0.05;
        }
        demand.clamp_non_negative();
        (demand, work)
    }

    /// Launch one attempt on `node_id` — a regular launch of a pending
    /// task, or (`speculative`) a backup copy of a task already running
    /// elsewhere. Resource/feedback treatment is identical; only the
    /// job-side bookkeeping and the event stamp differ.
    fn launch(
        &mut self,
        assignment: Assignment,
        node_id: NodeId,
        now: Time,
        node_feats: &crate::bayes::features::NodeFeatures,
        speculative: bool,
    ) {
        let task_ref = assignment.task;
        let (demand, work) = self.attempt_demand_work(&task_ref, node_id);

        // queue overload feedback sample for this node's next heartbeat,
        // built from the heartbeat-start features the batch was scored on
        let fail = self.failures.feats_for(task_ref.job, node_id, now);
        let feats = crate::bayes::features::feature_vec(
            &self.jobs.get(task_ref.job).spec.profile,
            node_feats,
            fail,
        );
        self.pending_feedback[node_id.0 as usize].push(PendingFeedback { feats });
        self.feats_insert(node_id, task_ref, feats);

        // OOM cliff check *before* mutating the node
        let dooms = self.cluster.node(node_id).would_oom(&demand);

        // job/task state (start_task maintains the pending counters and
        // the table's ready set; a backup leaves them untouched)
        let generation = if speculative {
            self.jobs.start_speculative(&task_ref, node_id, now);
            self.metrics.speculative_launches += 1;
            self.jobs.get(task_ref.job).task(&task_ref).spec_generation
        } else {
            self.jobs.start_task(&task_ref, node_id, now);
            self.jobs.get(task_ref.job).task(&task_ref).generation
        };
        self.audit.push(AuditEvent::Launched {
            task: task_ref,
            node: node_id,
            speculative,
            feats,
        });
        self.emit(SchedEvent::TaskStarted {
            job: task_ref.job,
            node: node_id,
            kind: task_ref.kind,
        });
        self.metrics
            .record_trace(now, node_id, task_ref, assignment.decision);

        // node state + completion rescheduling for all tasks on the node
        let horizons = self
            .cluster
            .node_mut(node_id)
            .add_task(task_ref, demand, work, now);
        if dooms {
            self.cluster.node_mut(node_id).oom_kills += 1;
            self.doom_insert(node_id, task_ref);
            self.engine.schedule(
                now + self.cfg.oom_kill_delay,
                Event::TaskFail { node: node_id, task: task_ref, generation },
            );
        }
        // other tasks still slow down; reschedule their completions
        self.reschedule(node_id, horizons);
    }

    /// Re-issue completion events for every attempt running on a node,
    /// stamping each with a fresh per-attempt generation. Doomed attempts
    /// are skipped so their pending TaskFail stays valid.
    fn reschedule(&mut self, node_id: NodeId, horizons: Vec<(TaskRef, Time)>) {
        for (tref, at) in horizons {
            if self.doom_contains(node_id, &tref) {
                continue;
            }
            let task = self.jobs.get_mut(tref.job).task_mut(&tref);
            let stamp = task.next_stamp();
            let on_primary =
                matches!(task.state, TaskState::Running { node: n, .. } if n == node_id);
            if on_primary {
                task.generation = stamp;
            } else if task.speculative.is_some_and(|s| s.node == node_id) {
                task.spec_generation = stamp;
            } else {
                debug_assert!(false, "rescheduling {tref} which is not on {node_id}");
                continue;
            }
            self.engine.schedule(
                at,
                Event::TaskComplete { node: node_id, task: tref, generation: stamp },
            );
        }
    }

    // ------------------------------------------------------- completion --

    fn on_task_complete(&mut self, node_id: NodeId, tref: TaskRef, generation: u32) {
        let Some(which) = self.current_attempt(&tref, node_id, generation) else {
            return; // stale event
        };
        let now = self.engine.now();
        self.cluster.node_mut(node_id).advance(now);
        let (_rec, horizons) = self.cluster.node_mut(node_id).remove_task(&tref, now);
        self.doom_remove(node_id, &tref);
        self.feats_remove(node_id, &tref);
        // first copy to finish wins; cancel the losing copy, if any
        match which {
            Attempt::Primary => {
                if let Some(s) = self.jobs.get(tref.job).task(&tref).speculative {
                    self.cancel_attempt_on(s.node, tref, now);
                    self.jobs.get_mut(tref.job).task_mut(&tref).cancel_speculative();
                }
            }
            Attempt::Backup => {
                self.metrics.speculative_wins += 1;
                let pnode = match self.jobs.get(tref.job).task(&tref).state {
                    TaskState::Running { node, .. } => node,
                    _ => unreachable!("backup without running primary"),
                };
                self.cancel_attempt_on(pnode, tref, now);
                // the winner becomes the primary so completion below sees
                // a task running on `node_id`
                self.jobs.get_mut(tref.job).task_mut(&tref).promote_speculative();
            }
        }
        self.jobs.complete_task(&tref, now);
        self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
        self.emit(SchedEvent::TaskFinished {
            job: tref.job,
            node: node_id,
            kind: tref.kind,
        });
        let job = self.jobs.get(tref.job);
        let finished = !job.failed && job.is_complete();
        if finished {
            self.jobs.mark_complete(tref.job, now);
            // Some by construction: mark_complete just set finish_time
            // lint: allow(unwrap-in-lib)
            let outcome = self.jobs.get(tref.job).outcome().unwrap();
            self.metrics.record_outcome(outcome);
        }
        // covers both fresh completions and killed jobs draining their
        // last attempt
        self.notify_if_drained(tref.job);
        self.reschedule(node_id, horizons);
    }

    fn on_task_fail(&mut self, node_id: NodeId, tref: TaskRef, generation: u32) {
        let Some(which) = self.current_attempt(&tref, node_id, generation) else {
            return;
        };
        let now = self.engine.now();
        self.cluster.node_mut(node_id).advance(now);
        let (_rec, horizons) = self.cluster.node_mut(node_id).remove_task(&tref, now);
        self.doom_remove(node_id, &tref);
        self.failures.record_failure(tref.job, node_id, now);
        self.metrics.task_failures += 1;
        self.audit.push(AuditEvent::Ended { task: tref, node: node_id });
        // the OOM-killed placement feeds back a Bad sample for the exact
        // feature row it was scored on — this is what gives the
        // failure-history bins their likelihood mass
        if let Some(feats) = self.feats_remove(node_id, &tref) {
            self.emit(SchedEvent::Feedback { feats, label: Label::Bad });
            self.metrics.record_feedback(Label::Bad);
        }
        self.jobs.get_mut(tref.job).task_mut(&tref).failed_attempts += 1;
        let attempt = self.jobs.get(tref.job).task(&tref).attempts;
        self.emit(SchedEvent::TaskFailed {
            job: tref.job,
            node: node_id,
            kind: tref.kind,
            attempt,
            reason: FailReason::Oom,
        });
        let other_alive = match which {
            Attempt::Backup => true, // the primary still runs by definition
            Attempt::Primary => {
                self.jobs.get(tref.job).task(&tref).speculative.is_some()
            }
        };
        if other_alive {
            // one copy died; the task lives on through the other — no
            // requeue, no kill check
            match which {
                Attempt::Backup => {
                    self.jobs.get_mut(tref.job).task_mut(&tref).cancel_speculative();
                }
                Attempt::Primary => {
                    self.jobs.get_mut(tref.job).task_mut(&tref).promote_speculative();
                }
            }
        } else {
            self.jobs.requeue_task(&tref);
            let job = self.jobs.get(tref.job);
            // Hadoop semantics: a task out of FAILED attempts kills the
            // whole job (speculative launches and node-loss kills do not
            // erode the budget).
            if job.task(&tref).failed_attempts >= self.cfg.max_task_attempts
                && job.finish_time.is_none()
            {
                self.jobs.mark_failed(tref.job, now);
                self.metrics.failed_jobs += 1;
            }
        }
        self.notify_if_drained(tref.job);
        self.reschedule(node_id, horizons);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::Fifo;
    use crate::workload::generator::{generate, Mix, WorkloadConfig};

    fn small_run(seed: u64) -> JobTracker {
        let cluster = Cluster::homogeneous(4, 2);
        let specs = generate(&WorkloadConfig {
            n_jobs: 10,
            arrival_rate: 1.0,
            mix: Mix::balanced(),
            n_users: 2,
            seed,
        });
        let mut jt = JobTracker::new(
            cluster,
            Box::new(Fifo::new()),
            specs,
            seed,
            TrackerConfig::default(),
        );
        jt.run();
        jt
    }

    #[test]
    fn all_jobs_complete() {
        let jt = small_run(1);
        assert!(jt.jobs.all_complete());
        assert_eq!(jt.metrics.completed_jobs(), 10);
        assert!(jt.metrics.makespan > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = small_run(7);
        let b = small_run(7);
        assert_eq!(a.metrics.makespan, b.metrics.makespan);
        assert_eq!(a.engine.processed(), b.engine.processed());
        assert_eq!(a.metrics.decisions, b.metrics.decisions);
        let la = a.metrics.latencies();
        let lb = b.metrics.latencies();
        assert_eq!(la, lb);
    }

    #[test]
    fn different_seeds_differ() {
        let a = small_run(1);
        let b = small_run(2);
        assert_ne!(a.metrics.makespan, b.metrics.makespan);
    }

    #[test]
    fn nodes_end_empty() {
        let jt = small_run(3);
        for n in &jt.cluster.nodes {
            assert!(n.running().is_empty(), "{} still busy", n.id);
            assert_eq!(n.used_slots(TaskKind::Map), 0);
        }
    }

    #[test]
    fn feedback_flows() {
        let jt = small_run(4);
        let total = jt.metrics.feedback[0] + jt.metrics.feedback[1];
        assert!(total > 0, "no overload feedback recorded");
    }

    #[test]
    fn locality_recorded_for_all_map_launches() {
        let jt = small_run(5);
        let total_maps: u64 = jt
            .jobs
            .iter()
            .map(|j| j.maps.iter().map(|t| t.attempts as u64).sum::<u64>())
            .sum();
        let recorded: u64 = jt.metrics.locality.values().sum();
        assert_eq!(recorded, total_maps);
    }

    #[test]
    fn failure_history_is_empty_after_clean_run() {
        // every job left the system, so its failure entry must be gone
        let jt = small_run(6);
        assert_eq!(jt.failures.tracked_jobs(), 0);
    }
}
