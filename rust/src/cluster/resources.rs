//! Multi-dimensional node resources (cpu / memory / io / network), the
//! vocabulary shared by task demands, node capacities and utilization
//! snapshots. This is the resource abstraction YARN calls a Container's
//! dimensions (paper §2.2) applied to MRv1 TaskTrackers.

use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A resource vector. Units are fractions of a *standard node* (1.0 cpu =
/// all cores of the reference machine busy), so heterogeneous nodes are
/// expressed with capacities != 1.0.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub cpu: f64,
    pub mem: f64,
    pub io: f64,
    pub net: f64,
}

impl Resources {
    pub const ZERO: Resources = Resources { cpu: 0.0, mem: 0.0, io: 0.0, net: 0.0 };

    pub fn new(cpu: f64, mem: f64, io: f64, net: f64) -> Resources {
        Resources { cpu, mem, io, net }
    }

    /// Uniform vector (capacity of a standard node = splat(1.0)).
    pub fn splat(v: f64) -> Resources {
        Resources { cpu: v, mem: v, io: v, net: v }
    }

    /// Component-wise utilization of `self` against `capacity`.
    pub fn frac_of(&self, capacity: &Resources) -> Resources {
        Resources {
            cpu: safe_div(self.cpu, capacity.cpu),
            mem: safe_div(self.mem, capacity.mem),
            io: safe_div(self.io, capacity.io),
            net: safe_div(self.net, capacity.net),
        }
    }

    /// Largest component — the bottleneck dimension.
    pub fn max_component(&self) -> f64 {
        self.cpu.max(self.mem).max(self.io).max(self.net)
    }

    /// Component-wise scale.
    pub fn scale(&self, k: f64) -> Resources {
        Resources {
            cpu: self.cpu * k,
            mem: self.mem * k,
            io: self.io * k,
            net: self.net * k,
        }
    }

    /// True when every component of `self` fits under `other`.
    pub fn fits_within(&self, other: &Resources) -> bool {
        self.cpu <= other.cpu
            && self.mem <= other.mem
            && self.io <= other.io
            && self.net <= other.net
    }

    /// Clamp all components to >= 0 (guards float drift in +=/-=).
    pub fn clamp_non_negative(&mut self) {
        self.cpu = self.cpu.max(0.0);
        self.mem = self.mem.max(0.0);
        self.io = self.io.max(0.0);
        self.net = self.net.max(0.0);
    }
}

fn safe_div(a: f64, b: f64) -> f64 {
    if b <= 0.0 {
        if a > 0.0 {
            f64::INFINITY
        } else {
            0.0
        }
    } else {
        a / b
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            cpu: self.cpu + o.cpu,
            mem: self.mem + o.mem,
            io: self.io + o.io,
            net: self.net + o.net,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        *self = *self + o;
    }
}

impl Sub for Resources {
    type Output = Resources;
    fn sub(self, o: Resources) -> Resources {
        Resources {
            cpu: self.cpu - o.cpu,
            mem: self.mem - o.mem,
            io: self.io - o.io,
            net: self.net - o.net,
        }
    }
}

impl SubAssign for Resources {
    fn sub_assign(&mut self, o: Resources) {
        *self = *self - o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Resources::new(1.0, 2.0, 3.0, 4.0);
        let b = Resources::splat(1.0);
        assert_eq!(a + b, Resources::new(2.0, 3.0, 4.0, 5.0));
        assert_eq!(a - b, Resources::new(0.0, 1.0, 2.0, 3.0));
        assert_eq!(a.scale(2.0), Resources::new(2.0, 4.0, 6.0, 8.0));
    }

    #[test]
    fn frac_of_handles_zero_capacity() {
        let load = Resources::new(0.5, 0.0, 0.0, 0.0);
        let cap = Resources::new(0.0, 1.0, 1.0, 1.0);
        let f = load.frac_of(&cap);
        assert!(f.cpu.is_infinite());
        assert_eq!(f.mem, 0.0);
    }

    #[test]
    fn max_component_finds_bottleneck() {
        assert_eq!(Resources::new(0.2, 0.9, 0.1, 0.3).max_component(), 0.9);
    }

    #[test]
    fn fits_within() {
        let small = Resources::splat(0.5);
        let big = Resources::splat(1.0);
        assert!(small.fits_within(&big));
        assert!(!big.fits_within(&small));
        let mixed = Resources::new(0.4, 1.1, 0.4, 0.4);
        assert!(!mixed.fits_within(&big));
    }

    #[test]
    fn clamp_non_negative() {
        let mut r = Resources::new(-1e-9, 0.5, -0.2, 0.0);
        r.clamp_non_negative();
        assert_eq!(r, Resources::new(0.0, 0.5, 0.0, 0.0));
    }
}
