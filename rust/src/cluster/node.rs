//! TaskTracker node: typed slots, multi-dimensional resources, and the
//! contention model that makes bad placements expensive.
//!
//! Contention model: every running task demands a resource vector. When the
//! summed demand oversubscribes any dimension, **all** tasks on the node
//! slow down by the bottleneck ratio (`slowdown = max(1, max_r demand_r /
//! capacity_r)`). Memory additionally has an OOM cliff: a placement that
//! pushes memory demand past `OOM_FACTOR`× capacity kills the placed task
//! (paper §2.1: "If two large memory consumption of the task to be
//! scheduled one, it is easy to appear OOM").
//!
//! Work accounting uses the standard DES trick for load-dependent service
//! rates: each task tracks `remaining` work-seconds; whenever node load
//! changes, `advance()` first drains elapsed×speed from every task, then
//! completion times are re-derived from the new speed (stale completion
//! events are invalidated by generation counters).

use crate::bayes::features::NodeFeatures;
use crate::bayes::overload::OverloadObservation;
use crate::job::task::{TaskKind, TaskRef};
use crate::sim::engine::Time;

use super::resources::Resources;

/// Node identifier, dense from 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "node_{:03}", self.0)
    }
}

/// Memory oversubscription factor that triggers an OOM kill of the
/// just-placed task.
pub const OOM_FACTOR: f64 = 1.2;

/// Convexity of the overload penalty. Oversubscription is NOT
/// work-conserving on real machines (thrashing, cache pollution, swap):
/// at bottleneck utilization `u > 1` the slowdown is
/// `u * (1 + OVERLOAD_PENALTY * (u - 1))`, so aggregate node throughput
/// *drops* below capacity — e.g. u = 1.6 ⇒ slowdown 3.04, efficiency 53%.
/// This is what makes overload avoidance worth learning (DESIGN.md D1).
pub const OVERLOAD_PENALTY: f64 = 1.5;

/// Hardware class of a node (E9 heterogeneity experiment).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeSpec {
    /// Resource capacities as fractions of the standard node.
    pub capacity: Resources,
    /// Base execution speed (1.0 = standard; 0.5 = half as fast).
    pub speed: f64,
    pub map_slots: u32,
    pub reduce_slots: u32,
}

impl Default for NodeSpec {
    fn default() -> Self {
        NodeSpec {
            capacity: Resources::splat(1.0),
            speed: 1.0,
            map_slots: 2,
            reduce_slots: 2,
        }
    }
}

/// A task currently executing on the node.
#[derive(Debug, Clone)]
pub struct RunningTask {
    pub task: TaskRef,
    pub demand: Resources,
    /// Work-seconds left at speed 1.0.
    pub remaining: f64,
    pub started: Time,
}

/// One simulated TaskTracker.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub spec: NodeSpec,
    running: Vec<RunningTask>,
    /// Time `running[*].remaining` was last drained.
    last_advance: Time,
    /// Cumulative overload-seconds (metrics).
    pub overload_seconds: f64,
    /// Count of OOM kills on this node (metrics).
    pub oom_kills: u32,
    /// False while the node is failed (no heartbeats, no placements).
    pub alive: bool,
}

impl Node {
    pub fn new(id: NodeId, spec: NodeSpec) -> Node {
        Node {
            id,
            spec,
            running: Vec::new(),
            last_advance: 0.0,
            overload_seconds: 0.0,
            oom_kills: 0,
            alive: true,
        }
    }

    /// Kill the node: drop every running task (they are lost — the caller
    /// re-queues them) and mark it dead.
    pub fn fail(&mut self, now: Time) -> Vec<RunningTask> {
        self.advance(now);
        self.alive = false;
        std::mem::take(&mut self.running)
    }

    /// Bring the node back (empty, fresh).
    pub fn recover(&mut self, now: Time) {
        debug_assert!(!self.alive);
        self.last_advance = now;
        self.alive = true;
    }

    // ------------------------------------------------------------ slots --

    pub fn used_slots(&self, kind: TaskKind) -> u32 {
        self.running.iter().filter(|r| r.task.kind == kind).count() as u32
    }

    pub fn free_slots(&self, kind: TaskKind) -> u32 {
        let cap = match kind {
            TaskKind::Map => self.spec.map_slots,
            TaskKind::Reduce => self.spec.reduce_slots,
        };
        cap.saturating_sub(self.used_slots(kind))
    }

    pub fn running(&self) -> &[RunningTask] {
        &self.running
    }

    // ------------------------------------------------------- contention --

    /// Total demand of running tasks.
    pub fn demand(&self) -> Resources {
        let mut d = Resources::ZERO;
        for r in &self.running {
            d += r.demand;
        }
        d
    }

    /// Component-wise utilization (can exceed 1.0 under oversubscription).
    pub fn utilization(&self) -> Resources {
        self.demand().frac_of(&self.spec.capacity)
    }

    /// Current slowdown factor (>= 1.0), convex above full utilization.
    pub fn slowdown(&self) -> f64 {
        let u = self.utilization().max_component();
        if u <= 1.0 {
            1.0
        } else {
            u * (1.0 + OVERLOAD_PENALTY * (u - 1.0))
        }
    }

    /// Effective execution speed for tasks on this node right now.
    pub fn effective_speed(&self) -> f64 {
        self.spec.speed / self.slowdown()
    }

    /// Would adding `demand` trip the OOM cliff?
    pub fn would_oom(&self, demand: &Resources) -> bool {
        let mem = self.demand().mem + demand.mem;
        mem > OOM_FACTOR * self.spec.capacity.mem
    }

    // -------------------------------------------------- work accounting --

    /// Drain elapsed work from all running tasks up to `now`. Must be
    /// called before any mutation (add/remove) and before reading
    /// completion times.
    pub fn advance(&mut self, now: Time) {
        debug_assert!(now >= self.last_advance);
        let dt = now - self.last_advance;
        if dt > 0.0 {
            let speed = self.effective_speed();
            for r in &mut self.running {
                r.remaining = (r.remaining - dt * speed).max(0.0);
            }
            if self.slowdown() > 1.0 {
                self.overload_seconds += dt;
            }
        }
        self.last_advance = now;
    }

    /// Place a task. Caller has checked slots and advanced the clock.
    /// Returns the new completion horizon for every running task:
    /// `(task, absolute_completion_time)`.
    pub fn add_task(
        &mut self,
        task: TaskRef,
        demand: Resources,
        work: f64,
        now: Time,
    ) -> Vec<(TaskRef, Time)> {
        debug_assert_eq!(self.last_advance, now, "advance() before add_task");
        debug_assert!(self.free_slots(task.kind) > 0, "no free {:?} slot", task.kind);
        self.running.push(RunningTask {
            task,
            demand,
            remaining: work,
            started: now,
        });
        self.completion_times(now)
    }

    /// Remove a task (completion or kill). Returns its record and the new
    /// completion horizon for the remaining tasks.
    pub fn remove_task(
        &mut self,
        task: &TaskRef,
        now: Time,
    ) -> (RunningTask, Vec<(TaskRef, Time)>) {
        debug_assert_eq!(self.last_advance, now, "advance() before remove_task");
        let idx = self
            .running
            .iter()
            .position(|r| &r.task == task)
            // callers only remove tasks they placed -- lint: allow(unwrap-in-lib)
            .expect("removing task not on node");
        let rec = self.running.swap_remove(idx);
        (rec, self.completion_times(now))
    }

    /// Absolute completion time of every running task at current speed.
    pub fn completion_times(&self, now: Time) -> Vec<(TaskRef, Time)> {
        let speed = self.effective_speed();
        self.running
            .iter()
            .map(|r| (r.task, now + r.remaining / speed.max(1e-9)))
            .collect()
    }

    // ------------------------------------------------------- heartbeats --

    /// Node features for the classifier (heartbeat payload). Utilization is
    /// clamped into [0, 1] by the discretizer.
    pub fn features(&self) -> NodeFeatures {
        let u = self.utilization();
        NodeFeatures {
            cpu_used: u.cpu,
            mem_used: u.mem,
            io_load: u.io,
            net_load: u.net,
        }
    }

    /// Observation for the overload rule (feedback labeling).
    pub fn observation(&self) -> OverloadObservation {
        let u = self.utilization();
        OverloadObservation {
            cpu_used: u.cpu,
            mem_used: u.mem,
            io_load: u.io,
            net_load: u.net,
            slowdown: self.slowdown(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobId;

    fn tref(i: u32) -> TaskRef {
        TaskRef { job: JobId::dense(0), kind: TaskKind::Map, index: i }
    }

    fn node() -> Node {
        Node::new(NodeId(0), NodeSpec::default())
    }

    #[test]
    fn slot_accounting() {
        let mut n = node();
        assert_eq!(n.free_slots(TaskKind::Map), 2);
        n.advance(0.0);
        n.add_task(tref(0), Resources::splat(0.1), 10.0, 0.0);
        assert_eq!(n.free_slots(TaskKind::Map), 1);
        assert_eq!(n.free_slots(TaskKind::Reduce), 2);
    }

    #[test]
    fn uncontended_task_runs_at_full_speed() {
        let mut n = node();
        n.advance(0.0);
        let times = n.add_task(tref(0), Resources::splat(0.3), 10.0, 0.0);
        assert_eq!(times, vec![(tref(0), 10.0)]);
    }

    #[test]
    fn oversubscription_slows_everyone_convexly() {
        let mut n = node();
        n.advance(0.0);
        n.add_task(tref(0), Resources::new(0.8, 0.1, 0.1, 0.1), 10.0, 0.0);
        let times = n.add_task(tref(1), Resources::new(0.8, 0.1, 0.1, 0.1), 10.0, 0.0);
        // cpu demand 1.6 -> slowdown 1.6 * (1 + 1.5*0.6) = 3.04
        let expect = 1.6 * (1.0 + OVERLOAD_PENALTY * 0.6);
        assert!((n.slowdown() - expect).abs() < 1e-12);
        for (_, t) in times {
            assert!((t - 10.0 * expect).abs() < 1e-9);
        }
        // convexity: aggregate throughput drops under overload
        assert!(2.0 / expect < 1.0 / 0.8 * 0.9);
    }

    #[test]
    fn advance_drains_work_at_current_speed() {
        let mut n = node();
        n.advance(0.0);
        n.add_task(tref(0), Resources::new(0.8, 0.1, 0.1, 0.1), 10.0, 0.0);
        n.add_task(tref(1), Resources::new(0.8, 0.1, 0.1, 0.1), 10.0, 0.0);
        let speed = 1.0 / n.slowdown();
        // run 8s at the contended speed
        n.advance(8.0);
        let (rec, times) = n.remove_task(&tref(1), 8.0);
        let drained = 8.0 * speed;
        assert!((rec.remaining - (10.0 - drained)).abs() < 1e-9);
        // remaining task now alone: rest of its work at speed 1.0
        assert_eq!(times.len(), 1);
        assert!((times[0].1 - (8.0 + (10.0 - drained))).abs() < 1e-9);
    }

    #[test]
    fn slower_node_scales_durations() {
        let spec = NodeSpec { speed: 0.5, ..NodeSpec::default() };
        let mut n = Node::new(NodeId(1), spec);
        n.advance(0.0);
        let times = n.add_task(tref(0), Resources::splat(0.2), 10.0, 0.0);
        assert!((times[0].1 - 20.0).abs() < 1e-9);
    }

    #[test]
    fn oom_detection() {
        let mut n = node();
        n.advance(0.0);
        n.add_task(tref(0), Resources::new(0.1, 0.8, 0.1, 0.1), 10.0, 0.0);
        assert!(!n.would_oom(&Resources::new(0.1, 0.3, 0.1, 0.1)));
        assert!(n.would_oom(&Resources::new(0.1, 0.5, 0.1, 0.1)));
    }

    #[test]
    fn overload_seconds_accumulate() {
        let mut n = node();
        n.advance(0.0);
        n.add_task(tref(0), Resources::new(1.5, 0.1, 0.1, 0.1), 30.0, 0.0);
        n.advance(10.0);
        assert_eq!(n.overload_seconds, 10.0);
        let (_, _) = n.remove_task(&tref(0), 10.0);
        n.advance(20.0);
        assert_eq!(n.overload_seconds, 10.0); // idle node, no overload
    }

    #[test]
    fn features_match_utilization() {
        let mut n = node();
        n.advance(0.0);
        n.add_task(tref(0), Resources::new(0.6, 0.4, 0.2, 0.1), 10.0, 0.0);
        let f = n.features();
        assert!((f.cpu_used - 0.6).abs() < 1e-12);
        assert!((f.mem_used - 0.4).abs() < 1e-12);
        let o = n.observation();
        assert_eq!(o.slowdown, 1.0);
    }

    #[test]
    #[should_panic]
    fn removing_absent_task_panics() {
        let mut n = node();
        n.advance(0.0);
        let _ = n.remove_task(&tref(9), 0.0);
    }
}
