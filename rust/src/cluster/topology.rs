//! Cluster topology: nodes arranged in racks. Rack membership drives both
//! HDFS replica placement and task data-locality classification.

use super::node::NodeId;

/// Rack identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RackId(pub u32);

/// Static topology: `n_nodes` spread round-robin over `n_racks`.
#[derive(Debug, Clone, Copy)]
pub struct Topology {
    pub n_nodes: u32,
    pub n_racks: u32,
}

impl Topology {
    pub fn new(n_nodes: u32, n_racks: u32) -> Topology {
        assert!(n_nodes > 0 && n_racks > 0);
        Topology { n_nodes, n_racks: n_racks.min(n_nodes) }
    }

    pub fn rack_of(&self, node: NodeId) -> RackId {
        RackId(node.0 % self.n_racks)
    }

    pub fn same_rack(&self, a: NodeId, b: NodeId) -> bool {
        self.rack_of(a) == self.rack_of(b)
    }

    /// Nodes in a rack, ascending id.
    pub fn nodes_in_rack(&self, rack: RackId) -> Vec<NodeId> {
        (0..self.n_nodes)
            .filter(|i| i % self.n_racks == rack.0)
            .map(NodeId)
            .collect()
    }

    pub fn all_nodes(&self) -> impl Iterator<Item = NodeId> {
        (0..self.n_nodes).map(NodeId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_racks() {
        let t = Topology::new(8, 3);
        assert_eq!(t.rack_of(NodeId(0)), RackId(0));
        assert_eq!(t.rack_of(NodeId(1)), RackId(1));
        assert_eq!(t.rack_of(NodeId(2)), RackId(2));
        assert_eq!(t.rack_of(NodeId(3)), RackId(0));
    }

    #[test]
    fn racks_capped_by_nodes() {
        let t = Topology::new(2, 8);
        assert_eq!(t.n_racks, 2);
    }

    #[test]
    fn nodes_in_rack_partition_everything() {
        let t = Topology::new(10, 4);
        let mut all: Vec<NodeId> = (0..4)
            .flat_map(|r| t.nodes_in_rack(RackId(r)))
            .collect();
        all.sort_by_key(|n| n.0);
        assert_eq!(all, t.all_nodes().collect::<Vec<_>>());
    }

    #[test]
    fn same_rack_reflexive() {
        let t = Topology::new(6, 2);
        for n in t.all_nodes() {
            assert!(t.same_rack(n, n));
        }
        assert!(t.same_rack(NodeId(0), NodeId(2)));
        assert!(!t.same_rack(NodeId(0), NodeId(1)));
    }
}
