//! Heartbeat scheduling: "TaskTracker needs sends the information through
//! the heartbeat JobTracker" (paper §1). Nodes heartbeat at a fixed
//! interval with a deterministic per-node phase offset so heartbeats spread
//! over the interval instead of stampeding.

use crate::sim::engine::Time;

use super::node::NodeId;

/// Heartbeat timing parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeartbeatConfig {
    /// Seconds between heartbeats of one node (Hadoop default: 3s).
    pub interval: f64,
}

impl Default for HeartbeatConfig {
    fn default() -> Self {
        HeartbeatConfig { interval: 3.0 }
    }
}

impl HeartbeatConfig {
    /// First heartbeat of `node`: phase-offset within one interval,
    /// deterministic in the node id (golden-ratio hashing for an even
    /// spread that is independent of cluster size).
    pub fn first_beat(&self, node: NodeId) -> Time {
        let phi = 0.618_033_988_749_894_9_f64;
        let frac = (node.0 as f64 * phi).fract();
        frac * self.interval
    }

    pub fn next_beat(&self, now: Time) -> Time {
        now + self.interval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_beats_spread_within_interval() {
        let hb = HeartbeatConfig { interval: 3.0 };
        for i in 0..100 {
            let t = hb.first_beat(NodeId(i));
            assert!((0.0..3.0).contains(&t));
        }
    }

    #[test]
    fn first_beats_are_distinct() {
        let hb = HeartbeatConfig::default();
        let mut beats: Vec<f64> = (0..50).map(|i| hb.first_beat(NodeId(i))).collect();
        beats.sort_by(f64::total_cmp);
        beats.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        assert_eq!(beats.len(), 50);
    }

    #[test]
    fn next_beat_advances_by_interval() {
        let hb = HeartbeatConfig { interval: 2.5 };
        assert_eq!(hb.next_beat(10.0), 12.5);
    }
}
