//! Cluster substrate: TaskTracker nodes with typed slots, multi-dimensional
//! resources, a contention/OOM model, racks, and heartbeat bookkeeping.

pub mod heartbeat;
pub mod node;
pub mod resources;
pub mod topology;

pub use heartbeat::HeartbeatConfig;
pub use node::{Node, NodeId, NodeSpec};
pub use resources::Resources;
pub use topology::{RackId, Topology};

use crate::sim::rng::Pcg;

/// The set of nodes plus topology.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    pub topology: Topology,
}

impl Cluster {
    /// Homogeneous cluster of `n` default nodes over `racks` racks.
    pub fn homogeneous(n: u32, racks: u32) -> Cluster {
        Self::with_specs((0..n).map(|_| NodeSpec::default()).collect(), racks)
    }

    /// Cluster from explicit per-node specs (heterogeneity experiments).
    pub fn with_specs(specs: Vec<NodeSpec>, racks: u32) -> Cluster {
        let n = specs.len() as u32;
        assert!(n > 0);
        let nodes = specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| Node::new(NodeId(i as u32), s))
            .collect();
        Cluster { nodes, topology: Topology::new(n, racks) }
    }

    /// Mixed-class cluster: `fractions` of (spec, weight) sampled
    /// deterministically by `seed` (E9).
    pub fn heterogeneous(
        n: u32,
        racks: u32,
        classes: &[(NodeSpec, f64)],
        seed: u64,
    ) -> Cluster {
        let mut rng = Pcg::new(seed, 0xC1A55);
        let weights: Vec<f64> = classes.iter().map(|(_, w)| *w).collect();
        let specs = (0..n)
            .map(|_| classes[rng.weighted(&weights)].0)
            .collect();
        Self::with_specs(specs, racks)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0 as usize]
    }

    /// Total map+reduce slot capacity.
    pub fn total_slots(&self) -> u32 {
        self.nodes
            .iter()
            .map(|n| n.spec.map_slots + n.spec.reduce_slots)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_construction() {
        let c = Cluster::homogeneous(8, 2);
        assert_eq!(c.len(), 8);
        assert_eq!(c.topology.n_racks, 2);
        assert_eq!(c.total_slots(), 8 * 4);
        assert_eq!(c.node(NodeId(3)).id, NodeId(3));
    }

    #[test]
    fn heterogeneous_uses_all_classes() {
        let fast = NodeSpec { speed: 2.0, ..NodeSpec::default() };
        let slow = NodeSpec { speed: 0.5, ..NodeSpec::default() };
        let c = Cluster::heterogeneous(40, 4, &[(fast, 0.5), (slow, 0.5)], 7);
        let fast_n = c.nodes.iter().filter(|n| n.spec.speed == 2.0).count();
        assert!(fast_n > 5 && fast_n < 35, "fast_n={fast_n}");
    }

    #[test]
    fn heterogeneous_is_deterministic() {
        let fast = NodeSpec { speed: 2.0, ..NodeSpec::default() };
        let slow = NodeSpec { speed: 0.5, ..NodeSpec::default() };
        let a = Cluster::heterogeneous(20, 2, &[(fast, 0.3), (slow, 0.7)], 11);
        let b = Cluster::heterogeneous(20, 2, &[(fast, 0.3), (slow, 0.7)], 11);
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.spec.speed, y.spec.speed);
        }
    }
}
