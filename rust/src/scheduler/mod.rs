//! Job schedulers: the paper's three baselines (§3), the Bayes contribution
//! (§4), and extra sanity baselines — all behind the unified, event-driven
//! [`Scheduler`] trait ([`api`]), which runs the same scheduler under both
//! the MRv1 JobTracker and the YARN ResourceManager drivers.

pub mod api;
pub mod baselines;
#[cfg(test)]
mod tests;
pub mod bayes;
pub mod capacity;
pub mod fair;
pub mod fifo;

pub use api::{
    Assignment, BatchState, Decision, FailReason, SchedEvent, SchedView,
    Scheduler, SlotBudget,
};
pub use baselines::{RandomSched, ThresholdFifo};
pub use bayes::{BayesScheduler, SpeculationConfig, StarvationPolicy};
pub use capacity::Capacity;
pub use fair::Fair;
pub use fifo::Fifo;

use crate::bayes::classifier::NaiveBayes;
use crate::bayes::features::N_FEATURES;

/// Feature mask zeroing the two failure-history bins: the ablation that
/// turns `bayes` into the failure-blind learner the paper described
/// (E10 measures the gap under failure injection).
pub const FAILURE_BLIND_MASK: [bool; N_FEATURES] =
    [true, true, true, true, true, true, true, true, false, false];

/// Construct a scheduler by name (CLI / config entry point).
/// `bayes` uses the pure-rust classifier; `bayes-xla` is built separately
/// by the coordinator builder because it needs the artifacts directory.
/// `bayes-blind` is `bayes` with the failure-history features masked off —
/// the control arm of the E10 failure sweep.
///
/// Invariant (guarded by a unit test): every [`ALL_NAMES`] entry constructs
/// here and reports a matching [`Scheduler::name`].
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn Scheduler>> {
    match name {
        "fifo" => Some(Box::new(Fifo::new())),
        "fair" => Some(Box::new(Fair::new())),
        "capacity" => Some(Box::new(Capacity::new())),
        "bayes" => Some(Box::new(BayesScheduler::new(NaiveBayes::new(1.0)))),
        "bayes-blind" => Some(Box::new(
            BayesScheduler::new(NaiveBayes::new(1.0))
                .with_feature_mask(FAILURE_BLIND_MASK)
                .with_name("bayes-blind"),
        )),
        "random" => Some(Box::new(RandomSched::new(seed))),
        "threshold-fifo" => Some(Box::new(ThresholdFifo::new(0.9))),
        _ => None,
    }
}

/// All scheduler names selectable by `by_name` (for CLI help / sweeps).
pub const ALL_NAMES: [&str; 7] = [
    "fifo",
    "fair",
    "capacity",
    "bayes",
    "bayes-blind",
    "random",
    "threshold-fifo",
];
