//! **The paper's contribution** (§4): job selection by online Naive Bayes
//! classification. On each heartbeat the queued jobs are scored **once**
//! against the heartbeating node's features — posteriors and utilities are
//! per-heartbeat quantities, amortized over every slot the batch fills.
//! Jobs classified *good* (won't overload this node) compete by expected
//! utility `E.U.(i) = P(good|J) · U(i)`; winners contribute tasks picked
//! locality-first until the [`SlotBudget`] or the queue runs dry. Overload
//! feedback flows back through `observe(SchedEvent::Feedback)` into the
//! classifier.

use crate::bayes::classifier::{Classifier, MAX_JOBS};
use crate::bayes::features::{feature_vec, FeatureVec};
use crate::bayes::utility::UtilityFn;
use crate::cluster::node::Node;
use crate::job::task::TaskKind;

use super::api::{
    Assignment, BatchState, Decision, SchedEvent, SchedView, Scheduler, SlotBudget,
};

fn apply_mask(
    mask: &[bool; crate::bayes::features::N_FEATURES],
    mut fv: FeatureVec,
) -> FeatureVec {
    for (b, keep) in fv.iter_mut().zip(mask) {
        if !keep {
            *b = 0;
        }
    }
    fv
}

/// What to do when *no* queued job classifies as good for this node
/// (the paper is silent — deviation D3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StarvationPolicy {
    /// Refuse the slot while the node is busy (let it drain — this is the
    /// throttling the good/bad gate exists for) but accept the
    /// max-posterior job on a completely idle node so the cluster can
    /// never deadlock. In a batch, "idle" means the node was empty at the
    /// heartbeat AND the batch has not placed anything yet — the same
    /// state the legacy per-slot loop saw on its second call. Default.
    WaitUnlessIdle,
    /// Always schedule the max-posterior job (keeps slots busy; reduces
    /// the algorithm to soft job ranking).
    LeastBad,
    /// Strict reading of the paper: leave the slot idle until some job
    /// classifies good, even on an idle node.
    Wait,
}

/// The Bayes scheduler. Generic over the classifier implementation so the
/// same policy code runs on [`crate::bayes::NaiveBayes`] (pure rust) or
/// [`crate::runtime::XlaClassifier`] (PJRT artifacts).
pub struct BayesScheduler<C: Classifier> {
    classifier: C,
    utility: UtilityFn,
    policy: StarvationPolicy,
    /// E8 ablation: features with `false` are collapsed to bin 0 both at
    /// classify and feedback time, removing their signal.
    feature_mask: [bool; crate::bayes::features::N_FEATURES],
    /// Reused per-heartbeat scratch (perf §Perf: zero allocation per batch
    /// apart from the candidate list).
    scratch_feats: Vec<FeatureVec>,
    scratch_utility: Vec<f32>,
    /// Scoring-window truncation count (metrics / diagnostics).
    pub truncated_windows: u64,
}

impl<C: Classifier> BayesScheduler<C> {
    pub fn new(classifier: C) -> Self {
        BayesScheduler {
            classifier,
            utility: UtilityFn::default(),
            policy: StarvationPolicy::WaitUnlessIdle,
            feature_mask: [true; crate::bayes::features::N_FEATURES],
            scratch_feats: Vec::with_capacity(MAX_JOBS),
            scratch_utility: Vec::with_capacity(MAX_JOBS),
            truncated_windows: 0,
        }
    }

    pub fn with_utility(mut self, utility: UtilityFn) -> Self {
        self.utility = utility;
        self
    }

    pub fn with_policy(mut self, policy: StarvationPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Restrict the classifier to a feature subset (E8 ablation). The
    /// first four entries are job features, the last four node features.
    pub fn with_feature_mask(
        mut self,
        mask: [bool; crate::bayes::features::N_FEATURES],
    ) -> Self {
        self.feature_mask = mask;
        self
    }

    fn apply_mask(&self, fv: FeatureVec) -> FeatureVec {
        apply_mask(&self.feature_mask, fv)
    }

    pub fn classifier(&self) -> &C {
        &self.classifier
    }

    pub fn classifier_mut(&mut self) -> &mut C {
        &mut self.classifier
    }
}

impl<C: Classifier> Scheduler for BayesScheduler<C> {
    fn name(&self) -> &'static str {
        "bayes"
    }

    fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        let mut out = Vec::new();
        if budget.total() == 0 || view.queue.is_empty() {
            return out;
        }
        // 1. score the whole queue ONCE for this heartbeat. Scoring window:
        // the artifact scores at most MAX_JOBS rows; if the queue is
        // longer, keep the oldest jobs (submission order = utility-age
        // order) — but reserve budget-proportional room for each requested
        // task kind, so e.g. 256 reduce-only jobs at the queue head cannot
        // evict every map-capable job from the window and idle map slots.
        let node_feats = node.features();
        let all: Vec<&crate::job::job::Job> =
            view.queue.iter().map(|id| view.jobs.get(*id)).collect();
        let cands: Vec<&crate::job::job::Job> = if all.len() <= MAX_JOBS {
            all
        } else {
            self.truncated_windows += 1;
            let empty = BatchState::new();
            let offers = |j: &crate::job::job::Job, kind: TaskKind| {
                empty.has_work(j, kind)
            };
            let quota_r = if budget.maps == 0 {
                MAX_JOBS
            } else if budget.reduces == 0 {
                0
            } else {
                (MAX_JOBS * budget.reduces as usize / budget.total() as usize)
                    .max(1)
            };
            let quota_m = MAX_JOBS - quota_r;
            let mut keep = std::collections::BTreeSet::new();
            let mut taken_m = 0usize;
            let mut taken_r = 0usize;
            for j in &all {
                if keep.len() == MAX_JOBS {
                    break;
                }
                let m = taken_m < quota_m && offers(j, TaskKind::Map);
                let r = taken_r < quota_r && offers(j, TaskKind::Reduce);
                if m || r {
                    keep.insert(j.id);
                    if m {
                        taken_m += 1;
                    }
                    if r {
                        taken_r += 1;
                    }
                }
            }
            // fill leftover quota with the oldest not-yet-kept jobs
            for j in &all {
                if keep.len() == MAX_JOBS {
                    break;
                }
                keep.insert(j.id);
            }
            all.into_iter().filter(|j| keep.contains(&j.id)).collect()
        };
        self.scratch_feats.clear();
        self.scratch_utility.clear();
        for j in &cands {
            self.scratch_feats.push(apply_mask(
                &self.feature_mask,
                feature_vec(&j.spec.profile, &node_feats),
            ));
            self.scratch_utility.push(
                self.utility
                    .eval(j.spec.priority, view.now - j.spec.submit_time)
                    as f32,
            );
        }
        let result = self
            .classifier
            .classify(&self.scratch_feats, &self.scratch_utility);
        // expected-utility order for the good jobs, computed once per
        // heartbeat; the posterior order for the starvation fallback is
        // built lazily, only if a slot actually falls through
        let mut by_score: Vec<usize> = (0..cands.len()).collect();
        by_score.sort_by(|&a, &b| result.score[b].total_cmp(&result.score[a]));
        let mut by_pgood: Option<Vec<usize>> = None;

        // 2. fill the budget from the per-heartbeat scores
        let mut batch = BatchState::new();
        let utilities = &self.scratch_utility;
        let place = |i: usize,
                     kind: TaskKind,
                     batch: &mut BatchState,
                     out: &mut Vec<Assignment>|
         -> bool {
            if !batch.has_work(cands[i], kind) {
                return false;
            }
            match batch.pick_task(cands[i], node, view.hdfs, kind) {
                Some((task, loc)) => {
                    batch.claim(task);
                    out.push(Assignment {
                        task,
                        decision: Decision {
                            job: cands[i].id,
                            kind,
                            posterior: Some(result.p_good[i]),
                            utility: Some(utilities[i]),
                            locality: loc,
                            candidates: cands.len() as u32,
                        },
                    });
                    true
                }
                None => false,
            }
        };
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            for _ in 0..budget.of(kind) {
                // paper: among good jobs, max E.U.
                let mut placed = by_score
                    .iter()
                    .filter(|&&i| result.is_good(i))
                    .any(|&i| place(i, kind, &mut batch, &mut out));
                // nothing classified good: starvation policy (D3)
                if !placed {
                    let fallback = match self.policy {
                        StarvationPolicy::LeastBad => true,
                        StarvationPolicy::WaitUnlessIdle => {
                            node.running().is_empty() && batch.is_empty()
                        }
                        StarvationPolicy::Wait => false,
                    };
                    if fallback {
                        let order = by_pgood.get_or_insert_with(|| {
                            let mut v: Vec<usize> = (0..cands.len()).collect();
                            v.sort_by(|&a, &b| {
                                result.p_good[b].total_cmp(&result.p_good[a])
                            });
                            v
                        });
                        placed = order
                            .iter()
                            .any(|&i| place(i, kind, &mut batch, &mut out));
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        out
    }

    fn observe(&mut self, ev: &SchedEvent) {
        if let SchedEvent::Feedback { feats, label } = ev {
            let masked = self.apply_mask(*feats);
            self.classifier.observe(masked, *label);
        }
    }

    fn export_model(&self) -> Option<crate::config::json::Json> {
        let (counts, class_counts, alpha) = self.classifier.export_state();
        let nb = crate::bayes::classifier::NaiveBayes::from_state(
            counts,
            class_counts,
            alpha,
        );
        Some(crate::bayes::persist::to_json(&nb))
    }
}
