//! **The paper's contribution** (§4): job selection by online Naive Bayes
//! classification. Queued jobs are scored against the heartbeating node's
//! current features; jobs classified *good* (won't overload this node)
//! compete by expected utility `E.U.(i) = P(good|J) · U(i)`; the winner
//! contributes a task picked locality-first. Overload-rule feedback flows
//! back through [`Scheduler::feedback`] into the classifier.

use crate::bayes::classifier::{Classifier, Label, MAX_JOBS};
use crate::bayes::features::{feature_vec, FeatureVec};
use crate::bayes::utility::UtilityFn;
use crate::cluster::node::Node;
use crate::job::task::{TaskKind, TaskRef};

use super::api::{has_work, pick_task, SchedView, Scheduler};

fn apply_mask(
    mask: &[bool; crate::bayes::features::N_FEATURES],
    mut fv: FeatureVec,
) -> FeatureVec {
    for (b, keep) in fv.iter_mut().zip(mask) {
        if !keep {
            *b = 0;
        }
    }
    fv
}

/// What to do when *no* queued job classifies as good for this node
/// (the paper is silent — deviation D3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StarvationPolicy {
    /// Refuse the slot while the node is busy (let it drain — this is the
    /// throttling the good/bad gate exists for) but accept the
    /// max-posterior job on a completely idle node so the cluster can
    /// never deadlock. Default.
    WaitUnlessIdle,
    /// Always schedule the max-posterior job (keeps slots busy; reduces
    /// the algorithm to soft job ranking).
    LeastBad,
    /// Strict reading of the paper: leave the slot idle until some job
    /// classifies good, even on an idle node.
    Wait,
}

/// The Bayes scheduler. Generic over the classifier implementation so the
/// same policy code runs on [`crate::bayes::NaiveBayes`] (pure rust) or
/// [`crate::runtime::XlaClassifier`] (PJRT artifacts).
pub struct BayesScheduler<C: Classifier> {
    classifier: C,
    utility: UtilityFn,
    policy: StarvationPolicy,
    /// E8 ablation: features with `false` are collapsed to bin 0 both at
    /// classify and feedback time, removing their signal.
    feature_mask: [bool; crate::bayes::features::N_FEATURES],
    /// Reused per-select scratch (perf §Perf: zero allocation per decision
    /// apart from the candidate list).
    scratch_feats: Vec<FeatureVec>,
    scratch_utility: Vec<f32>,
    /// Scoring-window truncation count (metrics / diagnostics).
    pub truncated_windows: u64,
}

impl<C: Classifier> BayesScheduler<C> {
    pub fn new(classifier: C) -> Self {
        BayesScheduler {
            classifier,
            utility: UtilityFn::default(),
            policy: StarvationPolicy::WaitUnlessIdle,
            feature_mask: [true; crate::bayes::features::N_FEATURES],
            scratch_feats: Vec::with_capacity(MAX_JOBS),
            scratch_utility: Vec::with_capacity(MAX_JOBS),
            truncated_windows: 0,
        }
    }

    pub fn with_utility(mut self, utility: UtilityFn) -> Self {
        self.utility = utility;
        self
    }

    pub fn with_policy(mut self, policy: StarvationPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Restrict the classifier to a feature subset (E8 ablation). The
    /// first four entries are job features, the last four node features.
    pub fn with_feature_mask(
        mut self,
        mask: [bool; crate::bayes::features::N_FEATURES],
    ) -> Self {
        self.feature_mask = mask;
        self
    }

    fn apply_mask(&self, fv: FeatureVec) -> FeatureVec {
        apply_mask(&self.feature_mask, fv)
    }

    pub fn classifier(&self) -> &C {
        &self.classifier
    }

    pub fn classifier_mut(&mut self) -> &mut C {
        &mut self.classifier
    }
}

impl<C: Classifier> Scheduler for BayesScheduler<C> {
    fn name(&self) -> &'static str {
        "bayes"
    }

    fn select(
        &mut self,
        view: &SchedView,
        node: &Node,
        kind: TaskKind,
    ) -> Option<TaskRef> {
        // 1. candidate jobs with work for this slot kind
        let node_feats = node.features();
        let mut cands: Vec<&crate::job::job::Job> = view
            .queue
            .iter()
            .map(|id| view.jobs.get(*id))
            .filter(|j| has_work(j, kind))
            .collect();
        if cands.is_empty() {
            return None;
        }
        // scoring window: the artifact scores at most MAX_JOBS rows; if the
        // queue is longer, score the oldest MAX_JOBS (submission order =
        // utility-age order, so the truncation drops the youngest jobs).
        if cands.len() > MAX_JOBS {
            self.truncated_windows += 1;
            cands.truncate(MAX_JOBS);
        }
        // 2. feature rows + utilities (scratch buffers, reused per call)
        self.scratch_feats.clear();
        self.scratch_utility.clear();
        for j in &cands {
            self.scratch_feats
                .push(apply_mask(&self.feature_mask, feature_vec(&j.spec.profile, &node_feats)));
            self.scratch_utility.push(
                self.utility
                    .eval(j.spec.priority, view.now - j.spec.submit_time) as f32,
            );
        }
        // 3. classify + select (paper: among good jobs, max E.U.)
        let result = self
            .classifier
            .classify(&self.scratch_feats, &self.scratch_utility);
        let good_best = (0..cands.len())
            .filter(|&i| result.is_good(i))
            .max_by(|&a, &b| result.score[a].total_cmp(&result.score[b]));
        let least_bad = || {
            (0..cands.len())
                .max_by(|&a, &b| result.p_good[a].total_cmp(&result.p_good[b]))
        };
        let chosen = match good_best {
            Some(i) => i,
            None => match self.policy {
                StarvationPolicy::LeastBad => least_bad()?,
                StarvationPolicy::WaitUnlessIdle => {
                    if node.running().is_empty() {
                        least_bad()?
                    } else {
                        return None;
                    }
                }
                StarvationPolicy::Wait => return None,
            },
        };
        // 4. locality-first task pick within the chosen job; if the chosen
        // job yields no task (racy reduce gating), fall through remaining
        // good jobs by score.
        if let Some(t) = pick_task(cands[chosen], node, view.hdfs, kind) {
            return Some(t);
        }
        let mut order: Vec<usize> = (0..cands.len()).filter(|&i| i != chosen).collect();
        order.sort_by(|&a, &b| result.score[b].total_cmp(&result.score[a]));
        for i in order {
            if let Some(t) = pick_task(cands[i], node, view.hdfs, kind) {
                return Some(t);
            }
        }
        None
    }

    fn feedback(&mut self, feats: FeatureVec, label: Label) {
        self.classifier.observe(self.apply_mask(feats), label);
    }

    fn export_model(&self) -> Option<crate::config::json::Json> {
        let (counts, class_counts, alpha) = self.classifier.export_state();
        let nb = crate::bayes::classifier::NaiveBayes::from_state(
            counts,
            class_counts,
            alpha,
        );
        Some(crate::bayes::persist::to_json(&nb))
    }
}
