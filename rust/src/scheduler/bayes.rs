//! **The paper's contribution** (§4): job selection by online Naive Bayes
//! classification. On each heartbeat the queued jobs are scored **once**
//! against the heartbeating node's features — posteriors and utilities are
//! per-heartbeat quantities, amortized over every slot the batch fills.
//! Jobs classified *good* (won't overload this node) compete by expected
//! utility `E.U.(i) = P(good|J) · U(i)`; winners contribute tasks picked
//! locality-first until the [`SlotBudget`] or the queue runs dry. Overload
//! feedback flows back through `observe(SchedEvent::Feedback)` into the
//! classifier.
//!
//! Failure awareness (ATLAS-style, 1511.01446): every scored row includes
//! the two failure-history bins from [`SchedView::failures`], so the
//! posterior conditions on "this job keeps failing" / "this node keeps
//! killing tasks" — the drivers label OOM-killed placements `Bad`, which
//! gives those bins likelihood mass.
//!
//! Straggler path (deviation D6): when slot budget remains after the
//! regular pass, `assign` scans the *active* jobs (not just the pending
//! queue) for tasks running far past the median elapsed time of their
//! job's running tasks and proposes speculative backup copies — but only
//! when the classifier calls this (job, node) pair good, so speculation
//! never floods a node the model already distrusts.

use crate::bayes::classifier::{Classifier, MAX_JOBS};
use crate::bayes::features::{feature_vec, FeatureVec};
use crate::bayes::utility::UtilityFn;
use crate::cluster::node::Node;
use crate::job::task::{TaskKind, TaskRef, TaskState};
use crate::obs::{Counter, Histogram, Registry, SchedObs, Stopwatch};

use super::api::{
    Assignment, BatchState, Decision, SchedEvent, SchedView, Scheduler, SlotBudget,
};

/// Bayes-pipeline obs handles: `None` until
/// [`Scheduler::install_obs`], so the scoring hot path pays one branch
/// per site when obs is off.
#[derive(Debug, Default)]
struct BayesObs {
    classify_nanos: Option<Histogram>,
    feature_nanos: Option<Histogram>,
    train_nanos: Option<Histogram>,
    margin_milli: Option<Histogram>,
    speculative: Option<Counter>,
}

impl BayesObs {
    fn install(&mut self, registry: &Registry) {
        self.classify_nanos = Some(registry.histogram("bayes_classify_nanos"));
        self.feature_nanos = Some(registry.histogram("bayes_feature_nanos"));
        self.train_nanos = Some(registry.histogram("bayes_train_nanos"));
        self.margin_milli =
            Some(registry.histogram("bayes_posterior_margin_milli"));
        self.speculative =
            Some(registry.counter("bayes_speculative_launches_total"));
    }

    /// A running stopwatch when installed, `None` otherwise.
    fn sw(&self) -> Option<Stopwatch> {
        self.classify_nanos.is_some().then(Stopwatch::start)
    }

    fn record(hist: &Option<Histogram>, sw: Option<Stopwatch>) {
        if let (Some(h), Some(sw)) = (hist, sw) {
            h.record(sw.elapsed_nanos());
        }
    }

    /// Posterior decisiveness per scored row: `|p_good − 0.5| × 2000`, so
    /// 0 = coin flip and 1000 = certain. A margin distribution collapsing
    /// toward 0 is the first sign the classifier stopped separating good
    /// placements from bad ones.
    fn record_margins(&self, p_good: &[f32]) {
        if let Some(h) = &self.margin_milli {
            for &p in p_good {
                h.record(((p - 0.5).abs() * 2000.0) as u64);
            }
        }
    }
}

fn apply_mask(
    mask: &[bool; crate::bayes::features::N_FEATURES],
    mut fv: FeatureVec,
) -> FeatureVec {
    for (b, keep) in fv.iter_mut().zip(mask) {
        if !keep {
            *b = 0;
        }
    }
    fv
}

/// What to do when *no* queued job classifies as good for this node
/// (the paper is silent — deviation D3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StarvationPolicy {
    /// Refuse the slot while the node is busy (let it drain — this is the
    /// throttling the good/bad gate exists for) but accept the
    /// max-posterior job on a completely idle node so the cluster can
    /// never deadlock. In a batch, "idle" means the node was empty at the
    /// heartbeat AND the batch has not placed anything yet — the same
    /// state the legacy per-slot loop saw on its second call. Default.
    WaitUnlessIdle,
    /// Always schedule the max-posterior job (keeps slots busy; reduces
    /// the algorithm to soft job ranking).
    LeastBad,
    /// Strict reading of the paper: leave the slot idle until some job
    /// classifies good, even on an idle node.
    Wait,
}

/// Straggler / speculative-execution knobs (deviation D6). A backup copy
/// of a running task is proposed when the task's elapsed time exceeds
/// `slowdown_factor ×` the median elapsed time of its job's running tasks
/// of the same kind, with the guardrails below. Elapsed time stands in for
/// progress (the simulator does not model progress reports).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeculationConfig {
    pub enabled: bool,
    /// A task is a straggler when `elapsed > slowdown_factor * median`.
    pub slowdown_factor: f64,
    /// Never speculate a task younger than this (seconds) — short tasks
    /// finish before the backup would help.
    pub min_elapsed: f64,
    /// Median needs at least this many running peers to mean anything.
    pub min_running: usize,
    /// Backup copies proposed per heartbeat at most (Hadoop similarly caps
    /// speculative tasks so duplicates cannot flood the cluster).
    pub max_per_heartbeat: u32,
}

impl Default for SpeculationConfig {
    fn default() -> Self {
        SpeculationConfig {
            enabled: true,
            slowdown_factor: 2.0,
            min_elapsed: 25.0,
            min_running: 3,
            max_per_heartbeat: 1,
        }
    }
}

/// The Bayes scheduler. Generic over the classifier implementation so the
/// same policy code runs on [`crate::bayes::NaiveBayes`] (pure rust) or
/// [`crate::runtime::XlaClassifier`] (PJRT artifacts).
pub struct BayesScheduler<C: Classifier> {
    classifier: C,
    name: &'static str,
    utility: UtilityFn,
    policy: StarvationPolicy,
    speculation: SpeculationConfig,
    /// E8 ablation: features with `false` are collapsed to bin 0 both at
    /// classify and feedback time, removing their signal.
    feature_mask: [bool; crate::bayes::features::N_FEATURES],
    /// Reused per-heartbeat scratch (perf §Perf: zero allocation per batch
    /// apart from the candidate list).
    scratch_feats: Vec<FeatureVec>,
    scratch_utility: Vec<f32>,
    /// Scoring-window truncation count (metrics / diagnostics).
    pub truncated_windows: u64,
    obs: SchedObs,
    bobs: BayesObs,
}

impl<C: Classifier> BayesScheduler<C> {
    pub fn new(classifier: C) -> Self {
        BayesScheduler {
            classifier,
            name: "bayes",
            utility: UtilityFn::default(),
            policy: StarvationPolicy::WaitUnlessIdle,
            speculation: SpeculationConfig::default(),
            feature_mask: [true; crate::bayes::features::N_FEATURES],
            scratch_feats: Vec::with_capacity(MAX_JOBS),
            scratch_utility: Vec::with_capacity(MAX_JOBS),
            truncated_windows: 0,
            obs: SchedObs::default(),
            bobs: BayesObs::default(),
        }
    }

    pub fn with_utility(mut self, utility: UtilityFn) -> Self {
        self.utility = utility;
        self
    }

    /// Override the reported scheduler name (named `by_name` variants like
    /// `bayes-blind` keep the name/constructor drift guard honest).
    pub fn with_name(mut self, name: &'static str) -> Self {
        self.name = name;
        self
    }

    pub fn with_policy(mut self, policy: StarvationPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_speculation(mut self, speculation: SpeculationConfig) -> Self {
        self.speculation = speculation;
        self
    }

    /// Restrict the classifier to a feature subset (E8 ablation / the
    /// failure-blind baseline). Layout: 4 job features, 4 node features,
    /// 2 failure-history features.
    pub fn with_feature_mask(
        mut self,
        mask: [bool; crate::bayes::features::N_FEATURES],
    ) -> Self {
        self.feature_mask = mask;
        self
    }

    fn apply_mask(&self, fv: FeatureVec) -> FeatureVec {
        apply_mask(&self.feature_mask, fv)
    }

    pub fn classifier(&self) -> &C {
        &self.classifier
    }

    pub fn classifier_mut(&mut self) -> &mut C {
        &mut self.classifier
    }

    /// Straggler scan (module docs): propose backup copies for tasks far
    /// behind their job's running-task median, gated on the classifier
    /// calling this (job, node) pair good. Consumes whatever per-kind
    /// budget the regular pass left.
    fn speculate(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
        out: &mut Vec<Assignment>,
    ) {
        let used = |k: TaskKind, out: &[Assignment]| {
            out.iter().filter(|a| a.task.kind == k).count() as u32
        };
        let mut left_maps = budget.maps.saturating_sub(used(TaskKind::Map, out));
        let mut left_reduces =
            budget.reduces.saturating_sub(used(TaskKind::Reduce, out));
        if left_maps == 0 && left_reduces == 0 {
            return;
        }
        let cfg = self.speculation;
        // 1. gather stragglers across ALL active jobs (a job with every
        // task running is not in the pending queue — that tail is exactly
        // where stragglers live)
        let mut cands: Vec<(TaskRef, f64)> = Vec::new();
        for id in view.jobs.active_ids() {
            let job = view.jobs.get(id);
            if job.finish_time.is_some() {
                continue;
            }
            for tasks in [&job.maps, &job.reduces] {
                let kind_left = match tasks.first().map(|t| t.kind) {
                    Some(TaskKind::Map) => left_maps,
                    Some(TaskKind::Reduce) => left_reduces,
                    None => 0,
                };
                if kind_left == 0 {
                    continue;
                }
                let mut elapsed: Vec<f64> = tasks
                    .iter()
                    .filter_map(|t| match t.state {
                        TaskState::Running { start, .. } => Some(view.now - start),
                        _ => None,
                    })
                    .collect();
                if elapsed.len() < cfg.min_running {
                    continue;
                }
                elapsed.sort_by(f64::total_cmp);
                let median = elapsed[elapsed.len() / 2];
                if median <= 0.0 {
                    continue;
                }
                for t in tasks.iter() {
                    let TaskState::Running { node: pnode, start } = t.state else {
                        continue;
                    };
                    if t.speculative.is_some() || pnode == node.id {
                        continue;
                    }
                    let el = view.now - start;
                    if el >= cfg.min_elapsed && el > cfg.slowdown_factor * median {
                        let tref = TaskRef { job: id, kind: t.kind, index: t.index };
                        cands.push((tref, el / median));
                    }
                }
            }
        }
        if cands.is_empty() {
            return;
        }
        // most-behind first; fully deterministic tie-break
        cands.sort_by(|a, b| {
            let key = |t: &TaskRef| {
                (t.job.serial, matches!(t.kind, TaskKind::Reduce) as u8, t.index)
            };
            b.1.total_cmp(&a.1).then_with(|| key(&a.0).cmp(&key(&b.0)))
        });
        cands.truncate(MAX_JOBS);
        // 2. score the straggler rows against this node, failure bins in
        let node_feats = node.features();
        let fsw = self.bobs.sw();
        let mut rows = Vec::with_capacity(cands.len());
        let mut utils = Vec::with_capacity(cands.len());
        let mut fails = Vec::with_capacity(cands.len());
        for (tref, _) in &cands {
            let job = view.jobs.get(tref.job);
            let fail = view.failures.feats_for(tref.job, node.id, view.now);
            fails.push(fail);
            rows.push(apply_mask(
                &self.feature_mask,
                feature_vec(&job.spec.profile, &node_feats, fail),
            ));
            utils.push(
                self.utility
                    .eval(job.spec.priority, view.now - job.spec.submit_time)
                    as f32,
            );
        }
        BayesObs::record(&self.bobs.feature_nanos, fsw);
        let csw = self.bobs.sw();
        let result = self.classifier.classify(&rows, &utils);
        BayesObs::record(&self.bobs.classify_nanos, csw);
        self.bobs.record_margins(&result.p_good);
        let total = cands.len() as u32;
        let mut proposed = 0u32;
        for (i, (tref, _)) in cands.iter().enumerate() {
            if proposed >= cfg.max_per_heartbeat {
                break;
            }
            let left = match tref.kind {
                TaskKind::Map => &mut left_maps,
                TaskKind::Reduce => &mut left_reduces,
            };
            if *left == 0 || !result.is_good(i) {
                continue;
            }
            let job = view.jobs.get(tref.job);
            let locality = match tref.kind {
                TaskKind::Map => Some(view.hdfs.locality(
                    // every map has a block -- lint: allow(unwrap-in-lib)
                    job.task(tref).block.expect("map without block"),
                    node.id,
                )),
                TaskKind::Reduce => None,
            };
            out.push(Assignment {
                task: *tref,
                decision: Decision {
                    job: tref.job,
                    kind: tref.kind,
                    posterior: Some(result.p_good[i]),
                    utility: Some(utils[i]),
                    locality,
                    // the exact bins the scored row was built from
                    fail: Some(fails[i]),
                    candidates: total,
                    speculative: true,
                },
            });
            *left -= 1;
            proposed += 1;
        }
        if let Some(c) = &self.bobs.speculative {
            c.add(u64::from(proposed));
        }
    }
}

impl<C: Classifier> Scheduler for BayesScheduler<C> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.obs.install(registry, self.name());
        self.bobs.install(registry);
    }

    fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        let sw = self.obs.start();
        let mut out = Vec::new();
        if budget.total() == 0 {
            self.obs.finish(sw, 0);
            return out;
        }
        if !view.queue.is_empty() {
            self.assign_queued(view, node, budget, &mut out);
        }
        if self.speculation.enabled {
            self.speculate(view, node, budget, &mut out);
        }
        self.obs.finish(sw, out.len());
        out
    }

    fn observe(&mut self, ev: &SchedEvent) {
        if let SchedEvent::Feedback { feats, label } = ev {
            let masked = self.apply_mask(*feats);
            let sw = self.bobs.sw();
            self.classifier.observe(masked, *label);
            BayesObs::record(&self.bobs.train_nanos, sw);
        }
    }

    fn export_model(&self) -> Option<crate::config::json::Json> {
        let (counts, class_counts, alpha) = self.classifier.export_state();
        let nb = crate::bayes::classifier::NaiveBayes::from_state(
            counts,
            class_counts,
            alpha,
        );
        Some(crate::bayes::persist::to_json(&nb))
    }
}

impl<C: Classifier> BayesScheduler<C> {
    /// The regular pass: score the pending queue once, fill the budget in
    /// expected-utility order (paper §4).
    fn assign_queued(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
        out: &mut Vec<Assignment>,
    ) {
        // 1. score the whole queue ONCE for this heartbeat. Scoring window:
        // the artifact scores at most MAX_JOBS rows; if the queue is
        // longer, keep the oldest jobs (submission order = utility-age
        // order) — but reserve budget-proportional room for each requested
        // task kind, so e.g. 256 reduce-only jobs at the queue head cannot
        // evict every map-capable job from the window and idle map slots.
        let node_feats = node.features();
        let all: Vec<&crate::job::job::Job> =
            view.queue.iter().map(|id| view.jobs.get(*id)).collect();
        let cands: Vec<&crate::job::job::Job> = if all.len() <= MAX_JOBS {
            all
        } else {
            self.truncated_windows += 1;
            let empty = BatchState::new();
            let offers = |j: &crate::job::job::Job, kind: TaskKind| {
                empty.has_work(j, kind)
            };
            let quota_r = if budget.maps == 0 {
                MAX_JOBS
            } else if budget.reduces == 0 {
                0
            } else {
                (MAX_JOBS * budget.reduces as usize / budget.total() as usize)
                    .max(1)
            };
            let quota_m = MAX_JOBS - quota_r;
            let mut keep = std::collections::BTreeSet::new();
            let mut taken_m = 0usize;
            let mut taken_r = 0usize;
            for j in &all {
                if keep.len() == MAX_JOBS {
                    break;
                }
                let m = taken_m < quota_m && offers(j, TaskKind::Map);
                let r = taken_r < quota_r && offers(j, TaskKind::Reduce);
                if m || r {
                    keep.insert(j.id);
                    if m {
                        taken_m += 1;
                    }
                    if r {
                        taken_r += 1;
                    }
                }
            }
            // fill leftover quota with the oldest not-yet-kept jobs
            for j in &all {
                if keep.len() == MAX_JOBS {
                    break;
                }
                keep.insert(j.id);
            }
            all.into_iter().filter(|j| keep.contains(&j.id)).collect()
        };
        let fsw = self.bobs.sw();
        self.scratch_feats.clear();
        self.scratch_utility.clear();
        for j in &cands {
            let fail = view.failures.feats_for(j.id, node.id, view.now);
            self.scratch_feats.push(apply_mask(
                &self.feature_mask,
                feature_vec(&j.spec.profile, &node_feats, fail),
            ));
            self.scratch_utility.push(
                self.utility
                    .eval(j.spec.priority, view.now - j.spec.submit_time)
                    as f32,
            );
        }
        BayesObs::record(&self.bobs.feature_nanos, fsw);
        let csw = self.bobs.sw();
        let result = self
            .classifier
            .classify(&self.scratch_feats, &self.scratch_utility);
        BayesObs::record(&self.bobs.classify_nanos, csw);
        self.bobs.record_margins(&result.p_good);
        // expected-utility order for the good jobs, computed once per
        // heartbeat; the posterior order for the starvation fallback is
        // built lazily, only if a slot actually falls through
        let mut by_score: Vec<usize> = (0..cands.len()).collect();
        by_score.sort_by(|&a, &b| result.score[b].total_cmp(&result.score[a]));
        let mut by_pgood: Option<Vec<usize>> = None;

        // 2. fill the budget from the per-heartbeat scores
        let mut batch = BatchState::new();
        let utilities = &self.scratch_utility;
        let failures = view.failures;
        let place = |i: usize,
                     kind: TaskKind,
                     batch: &mut BatchState,
                     out: &mut Vec<Assignment>|
         -> bool {
            if !batch.has_work(cands[i], kind) {
                return false;
            }
            match batch.pick_task(cands[i], node, view.hdfs, kind) {
                Some((task, loc)) => {
                    batch.claim(task);
                    out.push(Assignment {
                        task,
                        decision: Decision {
                            job: cands[i].id,
                            kind,
                            posterior: Some(result.p_good[i]),
                            utility: Some(utilities[i]),
                            locality: loc,
                            fail: Some(failures.feats_for(
                                cands[i].id,
                                node.id,
                                view.now,
                            )),
                            candidates: cands.len() as u32,
                            speculative: false,
                        },
                    });
                    true
                }
                None => false,
            }
        };
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            for _ in 0..budget.of(kind) {
                // paper: among good jobs, max E.U.
                let mut placed = by_score
                    .iter()
                    .filter(|&&i| result.is_good(i))
                    .any(|&i| place(i, kind, &mut batch, &mut *out));
                // nothing classified good: starvation policy (D3)
                if !placed {
                    let fallback = match self.policy {
                        StarvationPolicy::LeastBad => true,
                        StarvationPolicy::WaitUnlessIdle => {
                            node.running().is_empty() && batch.is_empty()
                        }
                        StarvationPolicy::Wait => false,
                    };
                    if fallback {
                        let order = by_pgood.get_or_insert_with(|| {
                            let mut v: Vec<usize> = (0..cands.len()).collect();
                            v.sort_by(|&a, &b| {
                                result.p_good[b].total_cmp(&result.p_good[a])
                            });
                            v
                        });
                        placed = order
                            .iter()
                            .any(|&i| place(i, kind, &mut batch, &mut *out));
                    }
                }
                if !placed {
                    break;
                }
            }
        }
    }
}
