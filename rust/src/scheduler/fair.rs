//! The Fair scheduler (paper §3.2): one pool per user, each pool
//! guaranteed a minimum share of task slots; free slots go to the pool
//! furthest below its fair share ("as long as the current release of an
//! empty slot task will be assigned to the immediately job pool"); FIFO
//! within a pool. No preemption, like the paper's description.

use std::collections::BTreeMap;

use crate::cluster::node::Node;
use crate::job::task::TaskKind;
use crate::job::JobId;
use crate::obs::SchedObs;
use crate::sim::arena::SlotMap;

use super::api::{
    Assignment, BatchState, Decision, SchedEvent, SchedView, Scheduler, SlotBudget,
};

#[derive(Debug, Default, Clone)]
struct Pool {
    running: u32,
    min_share: u32,
    weight: f64,
}

/// Fair scheduler over per-user pools.
///
/// Per-job state (`job_pool`) lives in a slot-indexed [`SlotMap`] keyed by
/// the job's arena handle and is dropped on `JobCompleted` — the drivers
/// guarantee that event arrives only after the job's last attempt ended,
/// so long simulations cannot leak one entry per job; and even if an entry
/// lingered, the serial stamp keeps it invisible to the slot's next
/// occupant.
#[derive(Debug, Default)]
pub struct Fair {
    pools: BTreeMap<String, Pool>,
    job_pool: SlotMap<String>,
    /// Default min share granted to a pool on first sight.
    pub default_min_share: u32,
    obs: SchedObs,
}

impl Fair {
    pub fn new() -> Fair {
        Fair { default_min_share: 2, ..Default::default() }
    }

    /// Configure a pool explicitly (min share + weight).
    pub fn set_pool(&mut self, name: &str, min_share: u32, weight: f64) {
        let p = self.pools.entry(name.to_string()).or_default();
        p.min_share = min_share;
        p.weight = weight.max(0.01);
    }

    fn pool_of(&mut self, job: JobId, pool_name: &str) -> String {
        self.job_pool.insert(job, pool_name.to_string());
        if !self.pools.contains_key(pool_name) {
            self.pools.insert(
                pool_name.to_string(),
                Pool { running: 0, min_share: self.default_min_share, weight: 1.0 },
            );
        }
        pool_name.to_string()
    }

    /// Pool ordering key: below-min-share pools first (most deficit), then
    /// lowest running/weight (classic fair-share deficit). `extra` counts
    /// tasks this heartbeat's batch already gave the pool, so one batch
    /// spreads slots fairly instead of dumping them on one pool.
    fn hunger(&self, name: &str, extra: u32) -> (i64, f64) {
        let p = &self.pools[name];
        let running = p.running + extra;
        let deficit = p.min_share as i64 - running as i64;
        let load = running as f64 / p.weight;
        (-deficit, load)
    }
}

impl Scheduler for Fair {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.obs.install(registry, self.name());
    }

    fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        let sw = self.obs.start();
        let mut batch = BatchState::new();
        let mut out = Vec::new();
        // tasks the batch granted per pool (both kinds count toward a
        // pool's running share, exactly like the observe() bookkeeping)
        let mut granted: BTreeMap<String, u32> = BTreeMap::new();
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            // group schedulable jobs by pool (registers pools on first sight)
            let mut by_pool: BTreeMap<String, Vec<JobId>> = BTreeMap::new();
            for id in view.queue {
                let job = view.jobs.get(*id);
                if !batch.has_work(job, kind) {
                    continue;
                }
                let pool = self.pool_of(*id, &job.spec.pool);
                by_pool.entry(pool).or_default().push(*id);
            }
            let candidates: u32 = by_pool.values().map(|v| v.len() as u32).sum();
            for _ in 0..budget.of(kind) {
                // hungriest pool first, re-ranked after every grant
                let mut pools: Vec<&String> = by_pool.keys().collect();
                pools.sort_by(|a, b| {
                    let extra = |p: &str| *granted.get(p).unwrap_or(&0);
                    let (da, la) = self.hunger(a, extra(a));
                    let (db, lb) = self.hunger(b, extra(b));
                    da.cmp(&db).then(la.total_cmp(&lb)).then(a.cmp(b))
                });
                let mut placed = false;
                'pools: for pool in pools {
                    // FIFO within the pool (second level, paper §3.2)
                    for id in &by_pool[pool] {
                        let job = view.jobs.get(*id);
                        if !batch.has_work(job, kind) {
                            continue;
                        }
                        if let Some((task, loc)) =
                            batch.pick_task(job, node, view.hdfs, kind)
                        {
                            batch.claim(task);
                            *granted.entry(pool.clone()).or_insert(0) += 1;
                            out.push(Assignment {
                                task,
                                decision: Decision::unscored(*id, kind, loc, candidates),
                            });
                            placed = true;
                            break 'pools;
                        }
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        self.obs.finish(sw, out.len());
        out
    }

    fn observe(&mut self, ev: &SchedEvent) {
        match ev {
            SchedEvent::TaskStarted { job, .. } => {
                if let Some(p) =
                    self.job_pool.get(*job).and_then(|pool| self.pools.get_mut(pool))
                {
                    p.running += 1;
                }
            }
            // both attempt-end flavours release the pool's slot
            SchedEvent::TaskFinished { job, .. }
            | SchedEvent::TaskFailed { job, .. } => {
                if let Some(p) =
                    self.job_pool.get(*job).and_then(|pool| self.pools.get_mut(pool))
                {
                    p.running = p.running.saturating_sub(1);
                }
            }
            // the job left the system with all attempts drained: forget it
            SchedEvent::JobCompleted { job } => {
                self.job_pool.remove(*job);
            }
            _ => {}
        }
    }
}

impl Fair {
    /// Jobs with live per-job state (regression guard: must be 0 after a
    /// full run — see `tests/integration_schedulers.rs`).
    pub fn tracked_jobs(&self) -> usize {
        self.job_pool.len()
    }
}
