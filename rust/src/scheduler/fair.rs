//! The Fair scheduler (paper §3.2): one pool per user, each pool
//! guaranteed a minimum share of task slots; free slots go to the pool
//! furthest below its fair share ("as long as the current release of an
//! empty slot task will be assigned to the immediately job pool"); FIFO
//! within a pool. No preemption, like the paper's description.

use std::collections::BTreeMap;

use crate::cluster::node::Node;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;

use super::api::{has_work, pick_task, SchedView, Scheduler};

#[derive(Debug, Default, Clone)]
struct Pool {
    running: u32,
    min_share: u32,
    weight: f64,
}

/// Fair scheduler over per-user pools.
#[derive(Debug, Default)]
pub struct Fair {
    pools: BTreeMap<String, Pool>,
    job_pool: BTreeMap<JobId, String>,
    /// Default min share granted to a pool on first sight.
    pub default_min_share: u32,
}

impl Fair {
    pub fn new() -> Fair {
        Fair { default_min_share: 2, ..Default::default() }
    }

    /// Configure a pool explicitly (min share + weight).
    pub fn set_pool(&mut self, name: &str, min_share: u32, weight: f64) {
        let p = self.pools.entry(name.to_string()).or_default();
        p.min_share = min_share;
        p.weight = weight.max(0.01);
    }

    fn pool_of(&mut self, job: JobId, pool_name: &str) -> String {
        self.job_pool.insert(job, pool_name.to_string());
        if !self.pools.contains_key(pool_name) {
            self.pools.insert(
                pool_name.to_string(),
                Pool { running: 0, min_share: self.default_min_share, weight: 1.0 },
            );
        }
        pool_name.to_string()
    }

    /// Pool ordering key: below-min-share pools first (most deficit), then
    /// lowest running/weight (classic fair-share deficit).
    fn hunger(&self, name: &str) -> (i64, f64) {
        let p = &self.pools[name];
        let deficit = p.min_share as i64 - p.running as i64;
        let load = p.running as f64 / p.weight;
        (-deficit, load)
    }
}

impl Scheduler for Fair {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn select(
        &mut self,
        view: &SchedView,
        node: &Node,
        kind: TaskKind,
    ) -> Option<TaskRef> {
        // group schedulable jobs by pool
        let mut by_pool: BTreeMap<String, Vec<JobId>> = BTreeMap::new();
        for id in view.queue {
            let job = view.jobs.get(*id);
            if !has_work(job, kind) {
                continue;
            }
            let pool = self.pool_of(*id, &job.spec.pool);
            by_pool.entry(pool).or_default().push(*id);
        }
        // hungriest pool first
        let mut pools: Vec<String> = by_pool.keys().cloned().collect();
        pools.sort_by(|a, b| {
            let (da, la) = self.hunger(a);
            let (db, lb) = self.hunger(b);
            da.cmp(&db).then(la.total_cmp(&lb)).then(a.cmp(b))
        });
        for pool in pools {
            // FIFO within the pool (second level, paper §3.2)
            for id in &by_pool[&pool] {
                let job = view.jobs.get(*id);
                if let Some(t) = pick_task(job, node, view.hdfs, kind) {
                    return Some(t);
                }
            }
        }
        None
    }

    fn on_task_started(&mut self, job: JobId) {
        if let Some(pool) = self.job_pool.get(&job) {
            self.pools.get_mut(pool).unwrap().running += 1;
        }
    }

    fn on_task_finished(&mut self, job: JobId) {
        if let Some(pool) = self.job_pool.get(&job) {
            let p = self.pools.get_mut(pool).unwrap();
            p.running = p.running.saturating_sub(1);
        }
    }
}
