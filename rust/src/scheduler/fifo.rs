//! Hadoop's default FIFO scheduler (paper §3.1): "It chooses the homework
//! to execute by the priority of the homework and the turns of arriving.
//! First come, and first go."

use crate::cluster::node::Node;
use crate::job::task::{TaskKind, TaskRef};

use super::api::{has_work, pick_task, SchedView, Scheduler};

/// Priority-then-submission-order FIFO.
#[derive(Debug, Default)]
pub struct Fifo;

impl Fifo {
    pub fn new() -> Fifo {
        Fifo
    }
}

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &mut self,
        view: &SchedView,
        node: &Node,
        kind: TaskKind,
    ) -> Option<TaskRef> {
        // queue is submission-ordered; a stable sort by descending priority
        // gives Hadoop's priority-FIFO order.
        let mut order: Vec<_> = view
            .queue
            .iter()
            .map(|id| view.jobs.get(*id))
            .filter(|j| has_work(j, kind))
            .collect();
        order.sort_by_key(|j| std::cmp::Reverse(j.spec.priority));
        for job in order {
            if let Some(t) = pick_task(job, node, view.hdfs, kind) {
                return Some(t);
            }
        }
        None
    }
}
