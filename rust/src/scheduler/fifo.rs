//! Hadoop's default FIFO scheduler (paper §3.1): "It chooses the homework
//! to execute by the priority of the homework and the turns of arriving.
//! First come, and first go."

use crate::cluster::node::Node;
use crate::job::task::TaskKind;
use crate::obs::SchedObs;

use super::api::{Assignment, BatchState, Decision, SchedView, Scheduler, SlotBudget};

/// Priority-then-submission-order FIFO.
#[derive(Debug, Default)]
pub struct Fifo {
    obs: SchedObs,
}

impl Fifo {
    pub fn new() -> Fifo {
        Fifo::default()
    }
}

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.obs.install(registry, self.name());
    }

    fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        let sw = self.obs.start();
        let mut batch = BatchState::new();
        let mut out = Vec::new();
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            // queue is submission-ordered; a stable sort by descending
            // priority gives Hadoop's priority-FIFO order, computed once
            // per heartbeat.
            let mut order: Vec<_> = view
                .queue
                .iter()
                .map(|id| view.jobs.get(*id))
                .filter(|j| batch.has_work(j, kind))
                .collect();
            order.sort_by_key(|j| std::cmp::Reverse(j.spec.priority));
            let candidates = order.len() as u32;
            for _ in 0..budget.of(kind) {
                let mut placed = false;
                for job in &order {
                    if !batch.has_work(job, kind) {
                        continue;
                    }
                    if let Some((task, loc)) =
                        batch.pick_task(job, node, view.hdfs, kind)
                    {
                        batch.claim(task);
                        out.push(Assignment {
                            task,
                            decision: Decision::unscored(job.id, kind, loc, candidates),
                        });
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        self.obs.finish(sw, out.len());
        out
    }
}
