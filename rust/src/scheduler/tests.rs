//! Unit tests for scheduler selection logic on small, fully-controlled
//! fixtures (integration tests cover whole-simulation behaviour).

use crate::bayes::classifier::{Classifier, Label, NaiveBayes};
use crate::bayes::features::{FailureHistory, N_FEATURES};
use crate::bayes::utility::Priority;
use crate::cluster::node::{Node, NodeId, NodeSpec};
use crate::cluster::resources::Resources;
use crate::hdfs::Namespace;
use crate::job::job::JobSpec;
use crate::job::profile::JobClass;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;

use super::api::{BatchState, SchedEvent, SchedView, Scheduler, SlotBudget};
use super::bayes::{BayesScheduler, StarvationPolicy};
use super::capacity::Capacity;
use super::fair::Fair;
use super::fifo::Fifo;

/// Empty failure history for fixture views.
fn no_failures() -> FailureHistory {
    FailureHistory::new()
}

/// Fixture: a job table with customizable specs on a 4-node namespace.
struct Fixture {
    jobs: JobTable,
    hdfs: Namespace,
}

fn spec(name: &str, user: &str, class: JobClass, priority: Priority) -> JobSpec {
    JobSpec {
        name: name.into(),
        user: user.into(),
        pool: user.into(),
        queue: format!("q_{user}"),
        class,
        priority,
        profile: class.base_features(),
        map_works: vec![10.0; 3],
        reduce_works: vec![15.0],
        submit_time: 0.0,
    }
}

fn fixture(specs: Vec<JobSpec>) -> Fixture {
    let mut hdfs = Namespace::new(4, 2, 9);
    let mut jobs = JobTable::new();
    for s in specs {
        jobs.submit(s, &mut hdfs);
    }
    Fixture { jobs, hdfs }
}

fn idle_node() -> Node {
    Node::new(NodeId(0), NodeSpec::default())
}

/// One-map-slot assignment (the old per-slot `select` shape, expressed as
/// a batch of budget 1).
fn select(f: &Fixture, sched: &mut dyn Scheduler, node: &Node) -> Option<TaskRef> {
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 10.0,
    };
    sched
        .assign(&view, node, SlotBudget { maps: 1, reduces: 0 })
        .first()
        .map(|a| a.task)
}

fn started(sched: &mut dyn Scheduler, job: JobId) {
    sched.observe(&SchedEvent::TaskStarted {
        job,
        node: NodeId(0),
        kind: TaskKind::Map,
    });
}

// ------------------------------------------------------------- pick_task --

#[test]
fn pick_task_prefers_node_local() {
    let f = fixture(vec![spec("a", "u0", JobClass::Small, Priority::Normal)]);
    let job = f.jobs.get(JobId::dense(0));
    // find a node holding a replica of some map's block
    let block = job.maps[1].block.unwrap();
    let local = f.hdfs.replicas(block)[0];
    let node = Node::new(local, NodeSpec::default());
    let batch = BatchState::new();
    let (picked, loc) = batch.pick_task(job, &node, &f.hdfs, TaskKind::Map).unwrap();
    let picked_block = job.task(&picked).block.unwrap();
    assert_eq!(
        f.hdfs.locality(picked_block, local),
        crate::hdfs::Locality::NodeLocal
    );
    assert_eq!(loc, Some(crate::hdfs::Locality::NodeLocal));
}

#[test]
fn pick_task_gates_reduces_on_map_phase() {
    let f = fixture(vec![spec("a", "u0", JobClass::Small, Priority::Normal)]);
    let job = f.jobs.get(JobId::dense(0));
    let batch = BatchState::new();
    assert_eq!(batch.pick_task(job, &idle_node(), &f.hdfs, TaskKind::Reduce), None);
}

#[test]
fn pick_task_skips_claimed_tasks() {
    let f = fixture(vec![spec("a", "u0", JobClass::Small, Priority::Normal)]);
    let job = f.jobs.get(JobId::dense(0));
    let node = idle_node();
    let mut batch = BatchState::new();
    let mut seen = std::collections::HashSet::new();
    for _ in 0..3 {
        let (t, _) = batch.pick_task(job, &node, &f.hdfs, TaskKind::Map).unwrap();
        assert!(seen.insert(t), "task {t} picked twice");
        batch.claim(t);
    }
    // all three maps claimed: nothing left
    assert!(batch.pick_task(job, &node, &f.hdfs, TaskKind::Map).is_none());
    assert!(!batch.has_work(job, TaskKind::Map));
}

// ------------------------------------------------------------ drift guard --

#[test]
fn all_names_construct_via_by_name_with_matching_name() {
    for name in super::ALL_NAMES {
        let s = super::by_name(name, 1).unwrap_or_else(|| {
            panic!("ALL_NAMES entry '{name}' is not constructible via by_name")
        });
        assert_eq!(s.name(), name, "scheduler name drift for '{name}'");
    }
}

#[test]
fn by_name_rejects_unknown_names() {
    assert!(super::by_name("nope", 1).is_none());
    assert!(super::by_name("", 1).is_none());
}

// ------------------------------------------------------------------ fifo --

#[test]
fn fifo_picks_highest_priority_first() {
    let f = fixture(vec![
        spec("low", "u0", JobClass::Small, Priority::Low),
        spec("high", "u1", JobClass::Small, Priority::VeryHigh),
        spec("normal", "u2", JobClass::Small, Priority::Normal),
    ]);
    let t = select(&f, &mut Fifo::new(), &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(1));
}

#[test]
fn fifo_breaks_priority_ties_by_submission() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let t = select(&f, &mut Fifo::new(), &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(0));
}

#[test]
fn fifo_returns_none_on_empty_queue() {
    let f = fixture(vec![]);
    assert_eq!(select(&f, &mut Fifo::new(), &idle_node()), None);
}

#[test]
fn fifo_batch_fills_whole_budget_without_duplicates() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 10.0,
    };
    let out = Fifo::new().assign(
        &view,
        &idle_node(),
        SlotBudget { maps: 6, reduces: 6 },
    );
    // 2 jobs x 3 pending maps = 6 maps; reduces all gated on map phase
    assert_eq!(out.len(), 6);
    let mut tasks: Vec<_> = out.iter().map(|a| a.task).collect();
    tasks.sort_by_key(|t| (t.job.serial, t.index));
    tasks.dedup();
    assert_eq!(tasks.len(), 6, "duplicate task in batch");
    assert!(out.iter().all(|a| a.task.kind == TaskKind::Map));
}

// ------------------------------------------------------------------ fair --

#[test]
fn fair_prefers_pool_with_fewest_running() {
    let f = fixture(vec![
        spec("a1", "alice", JobClass::Small, Priority::Normal),
        spec("a2", "alice", JobClass::Small, Priority::Normal),
        spec("b1", "bob", JobClass::Small, Priority::Normal),
    ]);
    let mut fair = Fair::new();
    // alice's pool already has 3 running tasks; bob has none
    let first = select(&f, &mut fair, &idle_node()).unwrap();
    for _ in 0..3 {
        started(&mut fair, JobId::dense(0));
    }
    let t = select(&f, &mut fair, &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(2), "bob's pool should win after alice loads up");
    let _ = first;
}

#[test]
fn fair_min_share_prioritizes_starved_pool() {
    let f = fixture(vec![
        spec("a", "alice", JobClass::Small, Priority::Normal),
        spec("b", "bob", JobClass::Small, Priority::Normal),
    ]);
    let mut fair = Fair::new();
    fair.set_pool("bob", 4, 1.0); // bob promised 4 slots
    fair.set_pool("alice", 0, 1.0);
    started(&mut fair, JobId::dense(0)); // prime pool registration indirectly
    let t = select(&f, &mut fair, &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(1), "below-min-share pool must win");
}

#[test]
fn fair_spreads_one_batch_across_pools() {
    let f = fixture(vec![
        spec("a", "alice", JobClass::Small, Priority::Normal),
        spec("b", "bob", JobClass::Small, Priority::Normal),
    ]);
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 10.0,
    };
    let out = Fair::new().assign(
        &view,
        &idle_node(),
        SlotBudget { maps: 4, reduces: 0 },
    );
    assert_eq!(out.len(), 4);
    let alice = out.iter().filter(|a| a.task.job == JobId::dense(0)).count();
    let bob = out.iter().filter(|a| a.task.job == JobId::dense(1)).count();
    assert_eq!((alice, bob), (2, 2), "batch must alternate between pools");
}

// -------------------------------------------------------------- capacity --

#[test]
fn capacity_picks_hungriest_queue() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut cap = Capacity::new();
    cap.observe(&SchedEvent::ClusterInfo { total_slots: 16 });
    // make u0's queue busy
    let first = select(&f, &mut cap, &idle_node()).unwrap();
    assert_eq!(first.job, JobId::dense(0)); // BTreeMap order tie-break
    for _ in 0..4 {
        started(&mut cap, JobId::dense(0));
    }
    let t = select(&f, &mut cap, &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(1), "hungrier queue must win");
}

#[test]
fn capacity_user_limit_blocks_hog() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut cap = Capacity::new();
    cap.observe(&SchedEvent::ClusterInfo { total_slots: 4 }); // tiny cluster
    cap.user_limit = 0.5;
    // u0 user already runs 2 tasks in its queue (promise = 4*0.5 = 2)
    let _ = select(&f, &mut cap, &idle_node());
    started(&mut cap, JobId::dense(0));
    started(&mut cap, JobId::dense(0));
    let t = select(&f, &mut cap, &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(1), "user over limit must be skipped");
}

// ----------------------------------------------------------------- bayes --

fn trained_bayes(policy: StarvationPolicy) -> BayesScheduler<NaiveBayes> {
    let mut nb = NaiveBayes::new(1.0);
    // teach it: cpu-heavy job features (high bin on feature 0) => bad,
    // light jobs => good, regardless of node state
    for _ in 0..200 {
        nb.observe([8, 3, 2, 1, 5, 3, 2, 1, 0, 0], Label::Bad);
        nb.observe([1, 1, 1, 1, 5, 3, 2, 1, 0, 0], Label::Good);
    }
    nb.flush();
    BayesScheduler::new(nb).with_policy(policy)
}

#[test]
fn bayes_prefers_job_classified_good() {
    let f = fixture(vec![
        spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal),
        spec("light", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut sched = trained_bayes(StarvationPolicy::LeastBad);
    let t = select(&f, &mut sched, &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(1), "light job should classify good and win");
}

#[test]
fn bayes_wait_policy_idles_loaded_node_when_all_bad() {
    let f = fixture(vec![spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal)]);
    let mut sched = trained_bayes(StarvationPolicy::Wait);
    // Wait policy refuses even idle nodes when everything is bad
    assert_eq!(select(&f, &mut sched, &idle_node()), None);
}

#[test]
fn bayes_wait_unless_idle_accepts_on_idle_node() {
    let f = fixture(vec![spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal)]);
    let mut sched = trained_bayes(StarvationPolicy::WaitUnlessIdle);
    // idle node: least-bad fallback fires
    assert!(select(&f, &mut sched, &idle_node()).is_some());
    // loaded node: refuse
    let mut busy = idle_node();
    busy.advance(0.0);
    busy.add_task(
        TaskRef { job: JobId::dense(9), kind: TaskKind::Map, index: 0 },
        Resources::splat(0.4),
        100.0,
        0.0,
    );
    assert_eq!(select(&f, &mut sched, &busy), None);
}

#[test]
fn bayes_wait_unless_idle_places_at_most_one_bad_task_per_batch() {
    // everything classifies bad: the idle-node fallback must fire for the
    // first slot only — the rest of the batch leaves the node draining,
    // matching the legacy per-slot loop (its second call saw a busy node)
    let f = fixture(vec![spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal)]);
    let mut sched = trained_bayes(StarvationPolicy::WaitUnlessIdle);
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 10.0,
    };
    let out = sched.assign(&view, &idle_node(), SlotBudget { maps: 3, reduces: 0 });
    assert_eq!(out.len(), 1, "fallback must not flood the node");
    let d = out[0].decision;
    assert!(d.posterior.unwrap() < 0.5);
    assert_eq!(d.job, JobId::dense(0));
}

#[test]
fn bayes_decision_records_carry_scores() {
    let f = fixture(vec![
        spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal),
        spec("light", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut sched = trained_bayes(StarvationPolicy::LeastBad);
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 10.0,
    };
    let out = sched.assign(&view, &idle_node(), SlotBudget { maps: 1, reduces: 0 });
    let d = out[0].decision;
    assert_eq!(d.job, JobId::dense(1));
    assert_eq!(d.kind, TaskKind::Map);
    assert_eq!(d.candidates, 2);
    assert!(d.posterior.unwrap() > 0.5);
    assert!(d.utility.unwrap() > 0.0);
    assert!(d.locality.is_some());
}

#[test]
fn bayes_feature_mask_removes_signal() {
    let f = fixture(vec![
        spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal),
        spec("light", "u1", JobClass::Small, Priority::Normal),
    ]);
    // mask out ALL job features: the trained distinction disappears and
    // selection falls back to utility order (equal => first wins)
    let mut nb = NaiveBayes::new(1.0);
    for _ in 0..200 {
        nb.observe([0, 0, 0, 0, 5, 3, 2, 1, 0, 0], Label::Bad);
        nb.observe([0, 0, 0, 0, 5, 3, 2, 1, 0, 0], Label::Good);
    }
    nb.flush();
    let mut sched = BayesScheduler::new(nb)
        .with_policy(StarvationPolicy::LeastBad)
        .with_feature_mask([false; N_FEATURES]);
    let t = select(&f, &mut sched, &idle_node()).unwrap();
    // with everything masked to bin 0 and balanced labels, posterior = 0.5
    // for both; equal scores keep the sort stable, so the first candidate
    // (submission order) wins deterministically
    assert_eq!(t.job, JobId::dense(0));
}

#[test]
fn bayes_feedback_reaches_classifier() {
    let mut sched = BayesScheduler::new(NaiveBayes::new(1.0));
    for _ in 0..50 {
        sched.observe(&SchedEvent::Feedback {
            feats: [9; N_FEATURES],
            label: Label::Bad,
        });
    }
    sched.classifier_mut().flush();
    assert_eq!(sched.classifier().class_counts(), [0.0, 50.0]);
}

// ------------------------------------------------- per-job state hygiene --

#[test]
fn fair_drops_job_state_on_job_completed() {
    let f = fixture(vec![
        spec("a", "alice", JobClass::Small, Priority::Normal),
        spec("b", "bob", JobClass::Small, Priority::Normal),
    ]);
    let mut fair = Fair::new();
    let _ = select(&f, &mut fair, &idle_node()); // registers jobs in pools
    assert!(fair.tracked_jobs() > 0, "fixture registered no jobs");
    fair.observe(&SchedEvent::JobCompleted { job: JobId::dense(0) });
    fair.observe(&SchedEvent::JobCompleted { job: JobId::dense(1) });
    assert_eq!(fair.tracked_jobs(), 0, "job_pool leaked after JobCompleted");
}

#[test]
fn capacity_drops_job_state_on_job_completed() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut cap = Capacity::new();
    cap.observe(&SchedEvent::ClusterInfo { total_slots: 8 });
    let _ = select(&f, &mut cap, &idle_node());
    assert!(cap.tracked_jobs() > 0, "fixture registered no jobs");
    cap.observe(&SchedEvent::JobCompleted { job: JobId::dense(0) });
    cap.observe(&SchedEvent::JobCompleted { job: JobId::dense(1) });
    assert_eq!(cap.tracked_jobs(), 0, "job_queue leaked after JobCompleted");
}

#[test]
fn fair_releases_slot_on_task_failed() {
    // a failed attempt must release the pool's running slot exactly like a
    // finished one — otherwise churn starves the pool forever
    let f = fixture(vec![
        spec("a", "alice", JobClass::Small, Priority::Normal),
        spec("b", "bob", JobClass::Small, Priority::Normal),
    ]);
    let mut fair = Fair::new();
    let _ = select(&f, &mut fair, &idle_node());
    for _ in 0..3 {
        started(&mut fair, JobId::dense(0));
    }
    for _ in 0..3 {
        fair.observe(&SchedEvent::TaskFailed {
            job: JobId::dense(0),
            node: NodeId(0),
            kind: TaskKind::Map,
            attempt: 1,
            reason: super::api::FailReason::Oom,
        });
    }
    // alice's pool drained back to 0 running: FIFO order (alice first)
    // decides again, not a phantom load imbalance
    let t = select(&f, &mut fair, &idle_node()).unwrap();
    assert_eq!(t.job, JobId::dense(0));
}

// ----------------------------------------------------------- speculation --

/// Fixture with one job whose maps all run: task 0 started long ago on
/// node 0 (the straggler), tasks 1-2 recently.
fn straggler_fixture() -> Fixture {
    let f = fixture(vec![spec("slow", "u0", JobClass::Small, Priority::Normal)]);
    let mut f = f;
    let start = |jobs: &mut JobTable, index: u32, node: u32, at: f64| {
        let t = TaskRef { job: JobId::dense(0), kind: TaskKind::Map, index };
        jobs.start_task(&t, NodeId(node), at);
    };
    start(&mut f.jobs, 0, 0, 0.0); // 60s elapsed at now=60
    start(&mut f.jobs, 1, 0, 40.0); // 20s elapsed
    start(&mut f.jobs, 2, 0, 40.0); // 20s elapsed
    f
}

#[test]
fn bayes_speculates_on_stragglers_from_another_node() {
    let f = straggler_fixture();
    let queue = f.jobs.schedulable();
    assert!(queue.is_empty(), "all tasks running: nothing schedulable");
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 60.0,
    };
    let mut sched = BayesScheduler::new(NaiveBayes::new(1.0));
    let node = Node::new(NodeId(1), NodeSpec::default());
    let out = sched.assign(&view, &node, SlotBudget { maps: 2, reduces: 2 });
    assert_eq!(out.len(), 1, "exactly the one straggler gets a backup");
    let a = &out[0];
    assert!(a.decision.speculative);
    assert_eq!(a.task, TaskRef { job: JobId::dense(0), kind: TaskKind::Map, index: 0 });
    assert!(a.decision.posterior.is_some());
    assert!(a.decision.fail.is_some());
}

#[test]
fn bayes_never_speculates_onto_the_primarys_node() {
    let f = straggler_fixture();
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 60.0,
    };
    let mut sched = BayesScheduler::new(NaiveBayes::new(1.0));
    // heartbeat from node 0, where the straggler already runs
    let node = Node::new(NodeId(0), NodeSpec::default());
    let out = sched.assign(&view, &node, SlotBudget { maps: 2, reduces: 2 });
    assert!(out.is_empty(), "backup proposed on the primary's own node");
}

#[test]
fn bayes_speculation_can_be_disabled() {
    let f = straggler_fixture();
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 60.0,
    };
    let mut sched = BayesScheduler::new(NaiveBayes::new(1.0)).with_speculation(
        super::bayes::SpeculationConfig { enabled: false, ..Default::default() },
    );
    let node = Node::new(NodeId(1), NodeSpec::default());
    let out = sched.assign(&view, &node, SlotBudget { maps: 2, reduces: 2 });
    assert!(out.is_empty());
}

#[test]
fn bayes_speculation_respects_classifier_verdict() {
    // train the model that this job class overloads nodes like ours: the
    // straggler must NOT get a backup copy onto a node the model distrusts
    let f = straggler_fixture();
    let queue = f.jobs.schedulable();
    let fails = no_failures();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 60.0,
    };
    let mut nb = NaiveBayes::new(1.0);
    let row = {
        // the exact row the scheduler will score: job profile bins + idle
        // node bins + zero failure bins
        let job = f.jobs.get(JobId::dense(0));
        let node = Node::new(NodeId(1), NodeSpec::default());
        crate::bayes::features::feature_vec(
            &job.spec.profile,
            &node.features(),
            crate::bayes::features::FailureFeats::default(),
        )
    };
    for _ in 0..200 {
        nb.observe(row, Label::Bad);
    }
    nb.flush();
    let mut sched = BayesScheduler::new(nb);
    let node = Node::new(NodeId(1), NodeSpec::default());
    let out = sched.assign(&view, &node, SlotBudget { maps: 2, reduces: 2 });
    assert!(out.is_empty(), "speculated onto a node classified bad");
}

// ------------------------------------------------- slot recycling safety --

/// Regression: arena slots recycle, ids do not. A job id whose slot was
/// reused must never observe (or mutate) the previous occupant's scheduler
/// or failure-history state — the serial stamp gates every lookup.
#[test]
fn recycled_slot_does_not_alias_scheduler_or_failure_state() {
    // failure history: job A accumulated failures on slot 3
    let a = JobId { slot: 3, serial: 0 };
    let b = JobId { slot: 3, serial: 8 }; // later job recycling slot 3
    let mut hist = FailureHistory::new();
    hist.record_failure(a, NodeId(1), 10.0);
    assert_eq!(hist.job_failures(a), 1);
    // B starts clean even though A's entry was never forgotten
    assert_eq!(hist.job_failures(b), 0);
    // recording for B evicts the stale entry instead of merging counts
    hist.record_failure(b, NodeId(1), 20.0);
    assert_eq!(hist.job_failures(b), 1);
    assert_eq!(hist.tracked_jobs(), 1, "stale entry must be evicted");
    // and forgetting via the stale id is inert for the new occupant
    hist.forget_job(a);
    assert_eq!(hist.job_failures(b), 1);

    // fair scheduler: pool membership is keyed by the full id, not the slot
    let f = fixture(vec![spec("a", "u0", JobClass::Small, Priority::Normal)]);
    let mut fair = Fair::new();
    let picked = select(&f, &mut fair, &idle_node());
    assert!(picked.is_some());
    assert_eq!(fair.tracked_jobs(), 1); // job 0 (slot 0) entered pool "u0"
    let recycled = JobId { slot: 0, serial: 9 };
    // events for a future occupant of slot 0 must miss, not misattribute:
    started(&mut fair, recycled);
    fair.observe(&SchedEvent::TaskFinished {
        job: recycled,
        node: NodeId(0),
        kind: TaskKind::Map,
    });
    fair.observe(&SchedEvent::JobCompleted { job: recycled });
    // the original job's pool entry survives the stray remove untouched
    assert_eq!(fair.tracked_jobs(), 1);
}
