//! Unit tests for scheduler selection logic on small, fully-controlled
//! fixtures (integration tests cover whole-simulation behaviour).

use crate::bayes::classifier::{Classifier, Label, NaiveBayes};
use crate::bayes::features::N_FEATURES;
use crate::bayes::utility::Priority;
use crate::cluster::node::{Node, NodeId, NodeSpec};
use crate::cluster::resources::Resources;
use crate::hdfs::Namespace;
use crate::job::job::JobSpec;
use crate::job::profile::JobClass;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;

use super::api::{pick_task, SchedView, Scheduler};
use super::bayes::{BayesScheduler, StarvationPolicy};
use super::capacity::Capacity;
use super::fair::Fair;
use super::fifo::Fifo;

/// Fixture: a job table with customizable specs on a 4-node namespace.
struct Fixture {
    jobs: JobTable,
    hdfs: Namespace,
}

fn spec(name: &str, user: &str, class: JobClass, priority: Priority) -> JobSpec {
    JobSpec {
        name: name.into(),
        user: user.into(),
        pool: user.into(),
        queue: format!("q_{user}"),
        class,
        priority,
        profile: class.base_features(),
        map_works: vec![10.0; 3],
        reduce_works: vec![15.0],
        submit_time: 0.0,
    }
}

fn fixture(specs: Vec<JobSpec>) -> Fixture {
    let mut hdfs = Namespace::new(4, 2, 9);
    let mut jobs = JobTable::new();
    for s in specs {
        jobs.submit(s, &mut hdfs);
    }
    Fixture { jobs, hdfs }
}

fn idle_node() -> Node {
    Node::new(NodeId(0), NodeSpec::default())
}

fn select(f: &Fixture, sched: &mut dyn Scheduler, node: &Node) -> Option<TaskRef> {
    let queue = f.jobs.schedulable();
    let view = SchedView { jobs: &f.jobs, hdfs: &f.hdfs, queue: &queue, now: 10.0 };
    sched.select(&view, node, TaskKind::Map)
}

// ------------------------------------------------------------- pick_task --

#[test]
fn pick_task_prefers_node_local() {
    let f = fixture(vec![spec("a", "u0", JobClass::Small, Priority::Normal)]);
    let job = f.jobs.get(JobId(0));
    // find a node holding a replica of some map's block
    let block = job.maps[1].block.unwrap();
    let local = f.hdfs.replicas(block)[0];
    let node = Node::new(local, NodeSpec::default());
    let picked = pick_task(job, &node, &f.hdfs, TaskKind::Map).unwrap();
    let picked_block = job.task(&picked).block.unwrap();
    assert_eq!(
        f.hdfs.locality(picked_block, local),
        crate::hdfs::Locality::NodeLocal
    );
}

#[test]
fn pick_task_gates_reduces_on_map_phase() {
    let f = fixture(vec![spec("a", "u0", JobClass::Small, Priority::Normal)]);
    let job = f.jobs.get(JobId(0));
    assert_eq!(pick_task(job, &idle_node(), &f.hdfs, TaskKind::Reduce), None);
}

// ------------------------------------------------------------------ fifo --

#[test]
fn fifo_picks_highest_priority_first() {
    let f = fixture(vec![
        spec("low", "u0", JobClass::Small, Priority::Low),
        spec("high", "u1", JobClass::Small, Priority::VeryHigh),
        spec("normal", "u2", JobClass::Small, Priority::Normal),
    ]);
    let t = select(&f, &mut Fifo::new(), &idle_node()).unwrap();
    assert_eq!(t.job, JobId(1));
}

#[test]
fn fifo_breaks_priority_ties_by_submission() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let t = select(&f, &mut Fifo::new(), &idle_node()).unwrap();
    assert_eq!(t.job, JobId(0));
}

#[test]
fn fifo_returns_none_on_empty_queue() {
    let f = fixture(vec![]);
    assert_eq!(select(&f, &mut Fifo::new(), &idle_node()), None);
}

// ------------------------------------------------------------------ fair --

#[test]
fn fair_prefers_pool_with_fewest_running() {
    let f = fixture(vec![
        spec("a1", "alice", JobClass::Small, Priority::Normal),
        spec("a2", "alice", JobClass::Small, Priority::Normal),
        spec("b1", "bob", JobClass::Small, Priority::Normal),
    ]);
    let mut fair = Fair::new();
    // alice's pool already has 3 running tasks; bob has none
    let first = select(&f, &mut fair, &idle_node()).unwrap();
    for _ in 0..3 {
        fair.on_task_started(JobId(0));
    }
    let t = select(&f, &mut fair, &idle_node()).unwrap();
    assert_eq!(t.job, JobId(2), "bob's pool should win after alice loads up");
    let _ = first;
}

#[test]
fn fair_min_share_prioritizes_starved_pool() {
    let f = fixture(vec![
        spec("a", "alice", JobClass::Small, Priority::Normal),
        spec("b", "bob", JobClass::Small, Priority::Normal),
    ]);
    let mut fair = Fair::new();
    fair.set_pool("bob", 4, 1.0); // bob promised 4 slots
    fair.set_pool("alice", 0, 1.0);
    fair.on_task_started(JobId(0)); // prime pool registration indirectly
    let t = select(&f, &mut fair, &idle_node()).unwrap();
    assert_eq!(t.job, JobId(1), "below-min-share pool must win");
}

// -------------------------------------------------------------- capacity --

#[test]
fn capacity_picks_hungriest_queue() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut cap = Capacity::new();
    cap.on_cluster_info(16);
    // make u0's queue busy
    let first = select(&f, &mut cap, &idle_node()).unwrap();
    assert_eq!(first.job, JobId(0)); // BTreeMap order tie-break
    for _ in 0..4 {
        cap.on_task_started(JobId(0));
    }
    let t = select(&f, &mut cap, &idle_node()).unwrap();
    assert_eq!(t.job, JobId(1), "hungrier queue must win");
}

#[test]
fn capacity_user_limit_blocks_hog() {
    let f = fixture(vec![
        spec("a", "u0", JobClass::Small, Priority::Normal),
        spec("b", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut cap = Capacity::new();
    cap.on_cluster_info(4); // tiny cluster: promises are small
    cap.user_limit = 0.5;
    // u0 user already runs 2 tasks in its queue (promise = 4*0.5 = 2)
    select(&f, &mut cap, &idle_node());
    cap.on_task_started(JobId(0));
    cap.on_task_started(JobId(0));
    let t = select(&f, &mut cap, &idle_node()).unwrap();
    assert_eq!(t.job, JobId(1), "user over limit must be skipped");
}

// ----------------------------------------------------------------- bayes --

fn trained_bayes(policy: StarvationPolicy) -> BayesScheduler<NaiveBayes> {
    let mut nb = NaiveBayes::new(1.0);
    // teach it: cpu-heavy job features (high bin on feature 0) => bad,
    // light jobs => good, regardless of node state
    for _ in 0..200 {
        nb.observe([8, 3, 2, 1, 5, 3, 2, 1], Label::Bad);
        nb.observe([1, 1, 1, 1, 5, 3, 2, 1], Label::Good);
    }
    nb.flush();
    BayesScheduler::new(nb).with_policy(policy)
}

#[test]
fn bayes_prefers_job_classified_good() {
    let f = fixture(vec![
        spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal),
        spec("light", "u1", JobClass::Small, Priority::Normal),
    ]);
    let mut sched = trained_bayes(StarvationPolicy::LeastBad);
    let t = select(&f, &mut sched, &idle_node()).unwrap();
    assert_eq!(t.job, JobId(1), "light job should classify good and win");
}

#[test]
fn bayes_wait_policy_idles_loaded_node_when_all_bad() {
    let f = fixture(vec![spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal)]);
    let mut sched = trained_bayes(StarvationPolicy::Wait);
    // Wait policy refuses even idle nodes when everything is bad
    assert_eq!(select(&f, &mut sched, &idle_node()), None);
}

#[test]
fn bayes_wait_unless_idle_accepts_on_idle_node() {
    let f = fixture(vec![spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal)]);
    let mut sched = trained_bayes(StarvationPolicy::WaitUnlessIdle);
    // idle node: least-bad fallback fires
    assert!(select(&f, &mut sched, &idle_node()).is_some());
    // loaded node: refuse
    let mut busy = idle_node();
    busy.advance(0.0);
    busy.add_task(
        TaskRef { job: JobId(9), kind: TaskKind::Map, index: 0 },
        Resources::splat(0.4),
        100.0,
        0.0,
    );
    assert_eq!(select(&f, &mut sched, &busy), None);
}

#[test]
fn bayes_feature_mask_removes_signal() {
    let f = fixture(vec![
        spec("heavy", "u0", JobClass::CpuHeavy, Priority::Normal),
        spec("light", "u1", JobClass::Small, Priority::Normal),
    ]);
    // mask out ALL job features: the trained distinction disappears and
    // selection falls back to utility order (equal => first wins)
    let mut nb = NaiveBayes::new(1.0);
    for _ in 0..200 {
        nb.observe([0, 0, 0, 0, 5, 3, 2, 1], Label::Bad);
        nb.observe([0, 0, 0, 0, 5, 3, 2, 1], Label::Good);
    }
    nb.flush();
    let mut sched = BayesScheduler::new(nb)
        .with_policy(StarvationPolicy::LeastBad)
        .with_feature_mask([false; N_FEATURES]);
    let t = select(&f, &mut sched, &idle_node()).unwrap();
    // with everything masked to bin 0 and balanced labels, posterior = 0.5
    // for both: the heavy job is no longer avoided (max_by keeps the last
    // of equal scores, so the tie goes to job 1 deterministically)
    assert_eq!(t.job, JobId(1));
}

#[test]
fn bayes_feedback_reaches_classifier() {
    let mut sched = BayesScheduler::new(NaiveBayes::new(1.0));
    for _ in 0..50 {
        sched.feedback([9; N_FEATURES], Label::Bad);
    }
    sched.classifier_mut().flush();
    assert_eq!(sched.classifier().class_counts(), [0.0, 50.0]);
}
