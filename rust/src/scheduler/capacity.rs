//! The Capacity scheduler (paper §3.3): named queues each promised a
//! fraction of the cluster; a free slot goes to the *hungriest* queue
//! ("judged by the result of the amount of executing tasks and the
//! computing resources. The lower, the more hungry"); priority-FIFO inside
//! a queue, no preemption; per-user limits within a queue ("if the user
//! does not do certain restrictions, is likely to occur serious phenomenon
//! of unfair between multiple users").

use std::collections::BTreeMap;

use crate::cluster::node::Node;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;

use super::api::{has_work, pick_task, SchedView, Scheduler};

#[derive(Debug, Clone)]
struct CapQueue {
    /// Promised fraction of cluster slots (normalized across queues).
    capacity: f64,
    running: u32,
    per_user_running: BTreeMap<String, u32>,
}

/// Capacity scheduler.
#[derive(Debug)]
pub struct Capacity {
    queues: BTreeMap<String, CapQueue>,
    /// Queues auto-created from job specs (share capacity equally unless
    /// explicitly configured via `set_queue`).
    auto_queues: Vec<String>,
    job_queue: BTreeMap<JobId, (String, String)>, // job -> (queue, user)
    /// Max fraction of a queue's *promised* slots one user may hold
    /// (Hadoop's user-limit-factor semantics; 1.0 = a user may fill the
    /// queue's whole promise but not poach other queues' shares).
    pub user_limit: f64,
    /// Total slots in the cluster (set by the coordinator at startup).
    pub total_slots: u32,
}

impl Capacity {
    pub fn new() -> Capacity {
        Capacity {
            queues: BTreeMap::new(),
            auto_queues: Vec::new(),
            job_queue: BTreeMap::new(),
            user_limit: 1.0,
            total_slots: 0,
        }
    }

    pub fn set_queue(&mut self, name: &str, capacity: f64) {
        self.queues
            .entry(name.to_string())
            .or_insert(CapQueue {
                capacity: 0.0,
                running: 0,
                per_user_running: BTreeMap::new(),
            })
            .capacity = capacity;
        self.auto_queues.retain(|q| q != name);
    }

    fn ensure_queue(&mut self, name: &str) {
        if !self.queues.contains_key(name) {
            self.queues.insert(
                name.to_string(),
                CapQueue {
                    capacity: 0.0,
                    running: 0,
                    per_user_running: BTreeMap::new(),
                },
            );
            self.auto_queues.push(name.to_string());
            // auto-created queues share capacity equally
            let share = 1.0 / self.auto_queues.len() as f64;
            for q in &self.auto_queues {
                self.queues.get_mut(q).unwrap().capacity = share;
            }
        }
    }

    /// Hunger = running / promised slots; lower is hungrier (paper §3.3).
    fn hunger(&self, name: &str) -> f64 {
        let q = &self.queues[name];
        let promised = (q.capacity * self.total_slots as f64).max(1e-9);
        q.running as f64 / promised
    }

    /// Would scheduling a task of `user` exceed the user limit in `queue`?
    fn user_over_limit(&self, queue: &str, user: &str) -> bool {
        if self.total_slots == 0 {
            return false; // cluster info not wired (unit tests) — no limit
        }
        let q = &self.queues[queue];
        let user_running = *q.per_user_running.get(user).unwrap_or(&0);
        // allow every user at least one running task
        if user_running == 0 {
            return false;
        }
        let promised = (q.capacity * self.total_slots as f64).max(1.0);
        (user_running as f64 + 1.0) > self.user_limit * promised.max(2.0)
    }
}

impl Default for Capacity {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Capacity {
    fn name(&self) -> &'static str {
        "capacity"
    }

    fn on_cluster_info(&mut self, total_slots: u32) {
        self.total_slots = total_slots;
    }

    fn select(
        &mut self,
        view: &SchedView,
        node: &Node,
        kind: TaskKind,
    ) -> Option<TaskRef> {
        let mut by_queue: BTreeMap<String, Vec<JobId>> = BTreeMap::new();
        for id in view.queue {
            let job = view.jobs.get(*id);
            if !has_work(job, kind) {
                continue;
            }
            self.ensure_queue(&job.spec.queue);
            self.job_queue
                .insert(*id, (job.spec.queue.clone(), job.spec.user.clone()));
            by_queue.entry(job.spec.queue.clone()).or_default().push(*id);
        }
        let mut queues: Vec<String> = by_queue.keys().cloned().collect();
        queues.sort_by(|a, b| {
            self.hunger(a).total_cmp(&self.hunger(b)).then(a.cmp(b))
        });
        for qname in queues {
            // priority-FIFO within the queue
            let mut jobs: Vec<_> =
                by_queue[&qname].iter().map(|id| view.jobs.get(*id)).collect();
            jobs.sort_by_key(|j| std::cmp::Reverse(j.spec.priority));
            for job in jobs {
                if self.user_over_limit(&qname, &job.spec.user) {
                    continue; // paper: "the job will not be selected"
                }
                if let Some(t) = pick_task(job, node, view.hdfs, kind) {
                    return Some(t);
                }
            }
        }
        None
    }

    fn on_task_started(&mut self, job: JobId) {
        if let Some((q, u)) = self.job_queue.get(&job).cloned() {
            let queue = self.queues.get_mut(&q).unwrap();
            queue.running += 1;
            *queue.per_user_running.entry(u).or_insert(0) += 1;
        }
    }

    fn on_task_finished(&mut self, job: JobId) {
        if let Some((q, u)) = self.job_queue.get(&job).cloned() {
            let queue = self.queues.get_mut(&q).unwrap();
            queue.running = queue.running.saturating_sub(1);
            if let Some(c) = queue.per_user_running.get_mut(&u) {
                *c = c.saturating_sub(1);
            }
        }
    }
}
