//! The Capacity scheduler (paper §3.3): named queues each promised a
//! fraction of the cluster; a free slot goes to the *hungriest* queue
//! ("judged by the result of the amount of executing tasks and the
//! computing resources. The lower, the more hungry"); priority-FIFO inside
//! a queue, no preemption; per-user limits within a queue ("if the user
//! does not do certain restrictions, is likely to occur serious phenomenon
//! of unfair between multiple users").

use std::collections::BTreeMap;

use crate::cluster::node::Node;
use crate::job::task::TaskKind;
use crate::job::JobId;
use crate::obs::SchedObs;
use crate::sim::arena::SlotMap;

use super::api::{
    Assignment, BatchState, Decision, SchedEvent, SchedView, Scheduler, SlotBudget,
};

#[derive(Debug, Clone)]
struct CapQueue {
    /// Promised fraction of cluster slots (normalized across queues).
    capacity: f64,
    running: u32,
    per_user_running: BTreeMap<String, u32>,
}

/// Capacity scheduler.
#[derive(Debug)]
pub struct Capacity {
    queues: BTreeMap<String, CapQueue>,
    /// Queues auto-created from job specs (share capacity equally unless
    /// explicitly configured via `set_queue`).
    auto_queues: Vec<String>,
    /// job -> (queue, user), slot-indexed by the job's arena handle.
    job_queue: SlotMap<(String, String)>,
    /// Max fraction of a queue's *promised* slots one user may hold
    /// (Hadoop's user-limit-factor semantics; 1.0 = a user may fill the
    /// queue's whole promise but not poach other queues' shares).
    pub user_limit: f64,
    /// Total slots in the cluster (from `SchedEvent::ClusterInfo`).
    pub total_slots: u32,
    obs: SchedObs,
}

impl Capacity {
    pub fn new() -> Capacity {
        Capacity {
            queues: BTreeMap::new(),
            auto_queues: Vec::new(),
            job_queue: SlotMap::new(),
            user_limit: 1.0,
            total_slots: 0,
            obs: SchedObs::default(),
        }
    }

    pub fn set_queue(&mut self, name: &str, capacity: f64) {
        self.queues
            .entry(name.to_string())
            .or_insert(CapQueue {
                capacity: 0.0,
                running: 0,
                per_user_running: BTreeMap::new(),
            })
            .capacity = capacity;
        self.auto_queues.retain(|q| q != name);
    }

    fn ensure_queue(&mut self, name: &str) {
        if !self.queues.contains_key(name) {
            self.queues.insert(
                name.to_string(),
                CapQueue {
                    capacity: 0.0,
                    running: 0,
                    per_user_running: BTreeMap::new(),
                },
            );
            self.auto_queues.push(name.to_string());
            // auto-created queues share capacity equally
            let share = 1.0 / self.auto_queues.len() as f64;
            for q in &self.auto_queues {
                if let Some(queue) = self.queues.get_mut(q) {
                    queue.capacity = share;
                }
            }
        }
    }

    /// Hunger = running / promised slots; lower is hungrier (paper §3.3).
    /// `extra` counts tasks this heartbeat's batch already granted.
    fn hunger(&self, name: &str, extra: u32) -> f64 {
        let q = &self.queues[name];
        let promised = (q.capacity * self.total_slots as f64).max(1e-9);
        (q.running + extra) as f64 / promised
    }

    /// Would scheduling a task of `user` exceed the user limit in `queue`,
    /// counting `extra_user` tasks this batch already granted the user?
    fn user_over_limit(&self, queue: &str, user: &str, extra_user: u32) -> bool {
        if self.total_slots == 0 {
            return false; // cluster info not wired (unit tests) — no limit
        }
        let q = &self.queues[queue];
        let user_running =
            *q.per_user_running.get(user).unwrap_or(&0) + extra_user;
        // allow every user at least one running task
        if user_running == 0 {
            return false;
        }
        let promised = (q.capacity * self.total_slots as f64).max(1.0);
        (user_running as f64 + 1.0) > self.user_limit * promised.max(2.0)
    }
}

impl Default for Capacity {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Capacity {
    fn name(&self) -> &'static str {
        "capacity"
    }

    fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.obs.install(registry, self.name());
    }

    fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        let sw = self.obs.start();
        let mut batch = BatchState::new();
        let mut out = Vec::new();
        // batch grants per queue and per (queue, user)
        let mut granted_q: BTreeMap<String, u32> = BTreeMap::new();
        let mut granted_u: BTreeMap<(String, String), u32> = BTreeMap::new();
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            let mut by_queue: BTreeMap<String, Vec<JobId>> = BTreeMap::new();
            for id in view.queue {
                let job = view.jobs.get(*id);
                if !batch.has_work(job, kind) {
                    continue;
                }
                self.ensure_queue(&job.spec.queue);
                self.job_queue
                    .insert(*id, (job.spec.queue.clone(), job.spec.user.clone()));
                by_queue.entry(job.spec.queue.clone()).or_default().push(*id);
            }
            // priority-FIFO order within each queue is fixed for the whole
            // batch: sort once per kind, not once per slot
            for jobs in by_queue.values_mut() {
                jobs.sort_by_key(|id| {
                    std::cmp::Reverse(view.jobs.get(*id).spec.priority)
                });
            }
            let candidates: u32 = by_queue.values().map(|v| v.len() as u32).sum();
            for _ in 0..budget.of(kind) {
                let mut queues: Vec<&String> = by_queue.keys().collect();
                queues.sort_by(|a, b| {
                    let extra = |q: &str| *granted_q.get(q).unwrap_or(&0);
                    self.hunger(a, extra(a))
                        .total_cmp(&self.hunger(b, extra(b)))
                        .then(a.cmp(b))
                });
                let mut placed = false;
                'queues: for qname in queues {
                    for job in by_queue[qname].iter().map(|id| view.jobs.get(*id)) {
                        let extra_u = *granted_u
                            .get(&(qname.clone(), job.spec.user.clone()))
                            .unwrap_or(&0);
                        if self.user_over_limit(qname, &job.spec.user, extra_u) {
                            continue; // paper: "the job will not be selected"
                        }
                        if !batch.has_work(job, kind) {
                            continue;
                        }
                        if let Some((task, loc)) =
                            batch.pick_task(job, node, view.hdfs, kind)
                        {
                            batch.claim(task);
                            *granted_q.entry(qname.clone()).or_insert(0) += 1;
                            *granted_u
                                .entry((qname.clone(), job.spec.user.clone()))
                                .or_insert(0) += 1;
                            out.push(Assignment {
                                task,
                                decision: Decision::unscored(
                                    job.id, kind, loc, candidates,
                                ),
                            });
                            placed = true;
                            break 'queues;
                        }
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        self.obs.finish(sw, out.len());
        out
    }

    fn observe(&mut self, ev: &SchedEvent) {
        match ev {
            SchedEvent::ClusterInfo { total_slots } => {
                self.total_slots = *total_slots;
            }
            SchedEvent::TaskStarted { job, .. } => {
                if let Some((q, u)) = self.job_queue.get(*job).cloned() {
                    let Some(queue) = self.queues.get_mut(&q) else { return };
                    queue.running += 1;
                    *queue.per_user_running.entry(u).or_insert(0) += 1;
                }
            }
            // both attempt-end flavours release the queue's slot
            SchedEvent::TaskFinished { job, .. }
            | SchedEvent::TaskFailed { job, .. } => {
                if let Some((q, u)) = self.job_queue.get(*job).cloned() {
                    let Some(queue) = self.queues.get_mut(&q) else { return };
                    queue.running = queue.running.saturating_sub(1);
                    if let Some(c) = queue.per_user_running.get_mut(&u) {
                        *c = c.saturating_sub(1);
                    }
                }
            }
            // same leak pattern Fair had: drop the per-job entry when the
            // job leaves the system fully drained
            SchedEvent::JobCompleted { job } => {
                self.job_queue.remove(*job);
            }
            _ => {}
        }
    }
}

impl Capacity {
    /// Jobs with live per-job state (leak regression guard).
    pub fn tracked_jobs(&self) -> usize {
        self.job_queue.len()
    }
}
