//! The unified, event-driven scheduler interface: "when JobTracker gets
//! task request, it will select a good job from job queue … then the
//! execution result will feedback to the JobTracker" (paper §3).
//!
//! A [`Scheduler`] interacts with a driver (the MRv1 JobTracker *or* the
//! YARN ResourceManager — both run the same trait) through exactly two
//! methods:
//!
//! * [`Scheduler::assign`] — called once per TaskTracker/NodeManager
//!   heartbeat with a [`SlotBudget`] covering **all** free slots. The
//!   scheduler scores the job queue once and returns an ordered batch of
//!   [`Assignment`]s, mirroring Hadoop's real `TaskScheduler.assignTasks`
//!   batch semantics. Learned schedulers compute posteriors and utilities
//!   per heartbeat, not per slot.
//! * [`Scheduler::observe`] — the single feedback channel: every driver
//!   notification (cluster info, overload-rule feedback, task lifecycle)
//!   arrives as one [`SchedEvent`]. Schedulers must tolerate events in any
//!   driver interleaving, including events for jobs they have never seen.
//!
//! ## The event stream
//!
//! Every driver notification is one [`SchedEvent`]. The lifecycle events
//! carry full attempt detail (node, kind, attempt number, failure cause) so
//! failure-aware schedulers can condition on outcome history instead of
//! seeing every ending as an undifferentiated "task left a node":
//!
//! | event                     | when the driver sends it                            |
//! |---------------------------|-----------------------------------------------------|
//! | `ClusterInfo { .. }`      | once at startup (slot totals)                       |
//! | `Feedback { .. }`         | overload-rule verdict for an earlier placement; also an extra `Bad` sample when a placement ends in an OOM kill |
//! | `TaskStarted { .. }`      | every attempt launch (regular or speculative)       |
//! | `TaskFinished { .. }`     | an attempt ended **without a failure signal**: it completed, or it was a speculation loser cancelled because the other copy won |
//! | `TaskFailed { .. }`       | an attempt ended in failure: OOM kill (`FailReason::Oom`) or its node died (`FailReason::NodeLost`) |
//! | `JobCompleted { .. }`     | the job left the system — succeeded or was killed — and **all** of its attempts have drained from the cluster |
//! | `NodeFailed { .. }`       | a TaskTracker died (after the per-task `TaskFailed`s) |
//! | `NodeRecovered { .. }`    | a failed TaskTracker rejoined                       |
//!
//! Pairing invariant: every `TaskStarted` is eventually matched by exactly
//! one `TaskFinished` *or* `TaskFailed` for that attempt, and
//! `JobCompleted` arrives only after the job's last attempt ended — so
//! per-job bookkeeping (e.g. the Fair scheduler's pool counters) can be
//! dropped on `JobCompleted` without leaking.
//!
//! ## Normative lifecycle rules (R1–R8)
//!
//! The table below is the **contract**: both drivers must emit streams
//! satisfying every rule, and any scheduler may rely on them. The
//! [`crate::analysis::protocol::ProtocolAuditor`] enforces the table — as
//! a debug-build shadow audit inside both drivers, over recorded traces
//! (`repro lint --trace`), and in the churn conformance sweep
//! (`analysis::audit_all_schedulers`). Rule ids match
//! [`crate::analysis::protocol::Rule`].
//!
//! | rule | name                   | invariant                                                 |
//! |------|------------------------|-----------------------------------------------------------|
//! | R1   | start-before-arrival   | no task event before its job arrived, none after its `JobCompleted` |
//! | R2   | slot-overcommit        | per `(node, kind)`, live attempts never exceed the node's slot capacity |
//! | R3   | double-assign          | a task never has two live attempts in the same role; a regular launch requires no live attempt at all |
//! | R4   | bad-speculation        | a speculative launch requires a live primary on a *different* node and no live backup; a backup is promoted at most once per launch |
//! | R5   | completed-before-drain | `JobCompleted` only after every attempt of the job has ended |
//! | R6   | dead-node-event        | no event touches a failed node until its `NodeRecovered`; fail/recover strictly alternate per node |
//! | R7   | end-without-start      | every attempt end pairs with exactly one live attempt (no stale or duplicate ends) |
//! | R8   | train-serve-skew       | every `Feedback` row is bit-identical to a row some placement was scored on at decision time |
//!
//! ### Lifecycle events in the obs layer
//!
//! With observability enabled (`repro run --obs-dump/--obs-trace/
//! --obs-jsonl`, see `OBSERVABILITY.md`), every `SchedEvent` a driver
//! emits increments one registry counter and stamps one unsampled
//! chrome-trace instant, both named by [`SchedEvent::obs_name`]:
//!
//! | event           | obs counter / instant     | rules it witnesses |
//! |-----------------|---------------------------|--------------------|
//! | `ClusterInfo`   | `sched_ev_cluster_info`   | —                  |
//! | `Feedback`      | `sched_ev_feedback`       | R8                 |
//! | `TaskStarted`   | `sched_ev_task_started`   | R1, R2, R3, R4     |
//! | `TaskFinished`  | `sched_ev_task_finished`  | R7                 |
//! | `TaskFailed`    | `sched_ev_task_failed`    | R7                 |
//! | `JobCompleted`  | `sched_ev_job_completed`  | R5                 |
//! | `NodeFailed`    | `sched_ev_node_failed`    | R6                 |
//! | `NodeRecovered` | `sched_ev_node_recovered` | R6                 |
//!
//! Because instants are exempt from `--obs-sample`, the per-name instant
//! counts in a chrome trace equal the run's `SchedEvent` totals exactly —
//! the protocol auditor sees the same stream the trace shows.
//!
//! The driver-side event order around failures is also normative: when a
//! node dies, the per-task `TaskFailed { reason: NodeLost }` events come
//! *first* and `NodeFailed` last, so by the time a scheduler sees
//! `NodeFailed` there is nothing left running on the node. When a
//! speculation race resolves, the loser's end is reported before the
//! winner's `TaskFinished`.
//!
//! Each [`Assignment`] carries a [`Decision`] record (chosen job,
//! posterior, utility, locality, failure bins, candidates considered,
//! speculative flag) that drivers thread into metrics and the
//! `repro run --explain` trace.
//!
//! ## Speculative execution (deviation D6)
//!
//! The paper does not discuss stragglers; Hadoop does (speculative
//! execution). A scheduler may return an [`Assignment`] with
//! `Decision::speculative == true` proposing a **backup copy** of a task
//! that is already running elsewhere. Contract: the task's primary attempt
//! is `Running` on a *different* node, the task has no live backup yet, and
//! the proposal consumes slot budget like any other assignment. The driver
//! launches the copy; whichever attempt finishes first wins, the loser is
//! cancelled through the per-attempt generation mechanism and reported as a
//! `TaskFinished` (a cancelled loser is not a failure signal). If the
//! primary's node dies while a backup runs, the backup is promoted in place
//! and the job loses no work. Only `BayesScheduler` currently speculates
//! (when a task runs far past the median elapsed time of its job's running
//! tasks, and only toward nodes the classifier calls good).
//!
//! ## Batch contract
//!
//! Within one `assign` call the returned batch must (a) never assign the
//! same task twice, (b) never exceed the per-kind budget, and (c) never
//! propose a reduce for a job whose map phase is incomplete. [`BatchState`]
//! implements the shared bookkeeping: it tracks what the batch has already
//! claimed so later picks see an up-to-date view without mutating the job
//! table. Drivers validate each assignment before launching (YARN re-checks
//! the declared-resource fit, both drivers re-check slot/pending state) and
//! may drop proposals that fail — scheduler-internal state stays consistent
//! because it is only updated through `observe` events for tasks that
//! actually launched.

use crate::bayes::classifier::Label;
use crate::bayes::features::{FailureFeats, FailureHistory, FeatureVec};
use crate::cluster::node::{Node, NodeId};
use crate::hdfs::locality::Locality;
use crate::hdfs::Namespace;
use crate::job::job::Job;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;
use crate::sim::engine::Time;

/// Read-only view handed to the scheduler on each heartbeat.
pub struct SchedView<'a> {
    pub jobs: &'a JobTable,
    pub hdfs: &'a Namespace,
    /// Schedulable jobs (have a pending task), submission order. The ids
    /// are generational arena handles (`JobId { slot, serial }`) valid for
    /// dense O(1) lookups in `jobs` and in any `sim::arena::SlotMap` side
    /// table a scheduler keeps. Drivers may cap this view to a prefix of
    /// the backlog (`TrackerConfig::queue_cap`) at large scale.
    pub queue: &'a [JobId],
    /// Failure history the driver maintains from the lifecycle events —
    /// the same state used to build feedback rows, so decision-time and
    /// feedback-time feature rows agree.
    pub failures: &'a FailureHistory,
    pub now: Time,
}

/// Free capacity offered to one `assign` call: every free slot of the
/// heartbeating node, by kind. Drivers with an orthogonal cap (YARN's
/// per-node container limit) may truncate the returned batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotBudget {
    pub maps: u32,
    pub reduces: u32,
}

impl SlotBudget {
    pub fn of(&self, kind: TaskKind) -> u32 {
        match kind {
            TaskKind::Map => self.maps,
            TaskKind::Reduce => self.reduces,
        }
    }

    pub fn total(&self) -> u32 {
        self.maps + self.reduces
    }
}

/// Why a task was chosen: the per-assignment explanation record threaded
/// into metrics and the `--explain` trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// The job the winning task belongs to.
    pub job: JobId,
    pub kind: TaskKind,
    /// P(good | job, node) — learned schedulers only.
    pub posterior: Option<f32>,
    /// U(i), the utility that weighted the posterior — learned schedulers
    /// only.
    pub utility: Option<f32>,
    /// Input locality of the picked task (maps only).
    pub locality: Option<Locality>,
    /// Failure-history bins the decision conditioned on (failure-aware
    /// schedulers only).
    pub fail: Option<FailureFeats>,
    /// Queue candidates considered for this slot.
    pub candidates: u32,
    /// True when this assignment proposes a speculative backup copy of a
    /// task already running elsewhere (module docs, D6).
    pub speculative: bool,
}

impl Decision {
    /// A decision record with no learned scores (heuristic schedulers).
    pub fn unscored(job: JobId, kind: TaskKind, locality: Option<Locality>, candidates: u32) -> Decision {
        Decision {
            job,
            kind,
            posterior: None,
            utility: None,
            locality,
            fail: None,
            candidates,
            speculative: false,
        }
    }
}

impl std::fmt::Display for Decision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.kind {
            TaskKind::Map => "map",
            TaskKind::Reduce => "reduce",
        };
        write!(f, "{} [{kind}]", self.job)?;
        if self.speculative {
            write!(f, " SPECULATIVE")?;
        }
        if let Some(p) = self.posterior {
            write!(f, " posterior={p:.3}")?;
        }
        if let Some(u) = self.utility {
            write!(f, " utility={u:.3}")?;
        }
        if let Some(l) = self.locality {
            write!(f, " locality={}", l.name())?;
        }
        if let Some(fb) = self.fail {
            write!(f, " fail_bins=j{}/n{}", fb.job_bin, fb.node_bin)?;
        }
        write!(f, " candidates={}", self.candidates)
    }
}

/// One proposed task launch in a heartbeat batch.
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    pub task: TaskRef,
    pub decision: Decision,
}

/// Why a task attempt failed (carried on [`SchedEvent::TaskFailed`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// The attempt was OOM-killed (memory oversubscription on its node).
    Oom,
    /// The attempt's node died (crash / partition); the work is lost.
    NodeLost,
}

/// The single event stream drivers feed back into a scheduler. See the
/// module docs for the event table and the started/ended pairing invariant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedEvent {
    /// Cluster-level facts, sent once at startup (the Capacity scheduler
    /// sizes queue promises from the slot total).
    ClusterInfo { total_slots: u32 },
    /// Overload-rule verdict for an earlier placement (the Bayes learner's
    /// training signal; the baselines ignore it — that is the paper's
    /// point). Placements that end in an OOM kill additionally feed back a
    /// `Bad`-labelled sample, so failure-history features earn likelihood
    /// mass in the classifier.
    Feedback { feats: FeatureVec, label: Label },
    /// A task attempt of `job` started on `node` (regular launch or
    /// speculative backup copy).
    TaskStarted { job: JobId, node: NodeId, kind: TaskKind },
    /// A task attempt of `job` ended on `node` without a failure signal:
    /// it completed, or it was a speculation loser cancelled because the
    /// other copy won.
    TaskFinished { job: JobId, node: NodeId, kind: TaskKind },
    /// A task attempt of `job` ended on `node` in failure. `attempt` is
    /// the per-task attempt count after this failure.
    TaskFailed {
        job: JobId,
        node: NodeId,
        kind: TaskKind,
        attempt: u32,
        reason: FailReason,
    },
    /// `job` left the system (succeeded, or was killed after exhausting a
    /// task's attempt budget) and all of its attempts have drained.
    /// Schedulers can drop per-job state here.
    JobCompleted { job: JobId },
    /// A TaskTracker died. Sent after the per-task `TaskFailed` events for
    /// the attempts it was running.
    NodeFailed { node: NodeId },
    /// A failed TaskTracker rejoined the cluster (empty, fresh).
    NodeRecovered { node: NodeId },
}

/// Obs counter/instant names, indexed by [`SchedEvent::obs_index`] —
/// what drivers pass to `obs::DriverObs::enable` (the obs layer itself
/// is scheduler-agnostic). See the module docs table mapping each name
/// to the lifecycle rules it witnesses.
pub const OBS_EVENT_NAMES: [&str; 8] = [
    "sched_ev_cluster_info",
    "sched_ev_feedback",
    "sched_ev_task_started",
    "sched_ev_task_finished",
    "sched_ev_task_failed",
    "sched_ev_job_completed",
    "sched_ev_node_failed",
    "sched_ev_node_recovered",
];

impl SchedEvent {
    /// Stable per-variant index into [`OBS_EVENT_NAMES`].
    pub fn obs_index(&self) -> usize {
        match self {
            SchedEvent::ClusterInfo { .. } => 0,
            SchedEvent::Feedback { .. } => 1,
            SchedEvent::TaskStarted { .. } => 2,
            SchedEvent::TaskFinished { .. } => 3,
            SchedEvent::TaskFailed { .. } => 4,
            SchedEvent::JobCompleted { .. } => 5,
            SchedEvent::NodeFailed { .. } => 6,
            SchedEvent::NodeRecovered { .. } => 7,
        }
    }

    /// The obs counter/instant name for this event.
    pub fn obs_name(&self) -> &'static str {
        OBS_EVENT_NAMES[self.obs_index()]
    }
}

/// A job scheduler (FIFO / Fair / Capacity / Bayes / ...), batched and
/// event-driven. Runs unchanged under both the MRv1 JobTracker and the
/// YARN ResourceManager drivers.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Fill the heartbeat's free slots in one call. See the module docs for
    /// the batch contract.
    fn assign(&mut self, view: &SchedView, node: &Node, budget: SlotBudget) -> Vec<Assignment>;

    /// Absorb one driver notification. Default: ignore everything.
    fn observe(&mut self, _ev: &SchedEvent) {}

    /// Export the learned model as JSON, if this scheduler has one
    /// (`repro run --save-model`).
    fn export_model(&self) -> Option<crate::config::json::Json> {
        None
    }

    /// Register this scheduler's instruments (phase timings, speculative
    /// counters, ...) with an obs registry. Called by drivers when
    /// observability is enabled; the default is no instrumentation.
    fn install_obs(&mut self, _registry: &crate::obs::Registry) {}
}

/// Within-batch bookkeeping shared by every scheduler: which tasks this
/// heartbeat's batch has already claimed, so later picks in the same batch
/// never double-assign (the job table is not mutated until the driver
/// launches the batch).
/// A batch spans one node's free slots (a handful of entries), so the
/// per-job tallies are flat vectors scanned linearly — cheaper than any
/// tree/hash map at this size and allocation-free once warm.
#[derive(Debug, Default)]
pub struct BatchState {
    taken: Vec<TaskRef>,
    maps_taken: Vec<(JobId, u32)>,
    reduces_taken: Vec<(JobId, u32)>,
}

impl BatchState {
    pub fn new() -> BatchState {
        BatchState::default()
    }

    /// Record that the batch assigned `task`.
    pub fn claim(&mut self, task: TaskRef) {
        debug_assert!(!self.taken.contains(&task), "double-claimed {task}");
        self.taken.push(task);
        let tally = match task.kind {
            TaskKind::Map => &mut self.maps_taken,
            TaskKind::Reduce => &mut self.reduces_taken,
        };
        match tally.iter_mut().find(|(j, _)| *j == task.job) {
            Some((_, n)) => *n += 1,
            None => tally.push((task.job, 1)),
        }
    }

    /// Tasks of `kind` the batch already claimed from `job`.
    pub fn claimed(&self, job: JobId, kind: TaskKind) -> u32 {
        let tally = match kind {
            TaskKind::Map => &self.maps_taken,
            TaskKind::Reduce => &self.reduces_taken,
        };
        match tally.iter().find(|(j, _)| *j == job) {
            Some(&(_, n)) => n,
            None => 0,
        }
    }

    pub fn len(&self) -> usize {
        self.taken.len()
    }

    pub fn is_empty(&self) -> bool {
        self.taken.is_empty()
    }

    /// Does `job` still have a task a `kind` slot could run, net of what
    /// this batch already claimed? Reduces stay gated on the map phase
    /// (maps claimed in this batch are not complete, so they cannot unlock
    /// reduces within the batch).
    pub fn has_work(&self, job: &Job, kind: TaskKind) -> bool {
        match kind {
            TaskKind::Map => {
                job.pending_maps() > self.claimed(job.id, TaskKind::Map) as usize
            }
            TaskKind::Reduce => {
                job.maps_complete()
                    && job.pending_reduces()
                        > self.claimed(job.id, TaskKind::Reduce) as usize
            }
        }
    }

    /// Locality-aware task pick *within* a chosen job (paper §4.2: "select
    /// the required data in the job to schedule the tasks on the
    /// TaskTracker firstly. If there does not exist such kind of tasks, we
    /// will select the tasks whose data are not local"). Shared by every
    /// scheduler, so baselines differ only in *job* selection — exactly the
    /// paper's framing. Skips tasks this batch already claimed; returns the
    /// pick plus its locality (maps only) for the [`Decision`] record.
    pub fn pick_task(
        &self,
        job: &Job,
        node: &Node,
        hdfs: &Namespace,
        kind: TaskKind,
    ) -> Option<(TaskRef, Option<Locality>)> {
        match kind {
            TaskKind::Map => {
                let mut best: Option<(Locality, u32)> = None;
                for t in job.maps.iter().filter(|t| t.is_pending()) {
                    let tref =
                        TaskRef { job: job.id, kind: TaskKind::Map, index: t.index };
                    if self.taken.contains(&tref) {
                        continue;
                    }
                    let block =
                        // every map has a block -- lint: allow(unwrap-in-lib)
                        t.block.expect("map without block");
                    let loc = hdfs.locality(block, node.id);
                    let rank = |l: Locality| match l {
                        Locality::NodeLocal => 0,
                        Locality::RackLocal => 1,
                        Locality::Remote => 2,
                    };
                    match best {
                        Some((b, _)) if rank(b) <= rank(loc) => {}
                        _ => best = Some((loc, t.index)),
                    }
                    if rank(loc) == 0 {
                        break; // cannot do better than node-local
                    }
                }
                best.map(|(loc, index)| {
                    (
                        TaskRef { job: job.id, kind: TaskKind::Map, index },
                        Some(loc),
                    )
                })
            }
            TaskKind::Reduce => {
                if !job.maps_complete() {
                    return None; // reduces gated on the map phase
                }
                job.reduces
                    .iter()
                    .filter(|t| t.is_pending())
                    .map(|t| TaskRef {
                        job: job.id,
                        kind: TaskKind::Reduce,
                        index: t.index,
                    })
                    .find(|tref| !self.taken.contains(tref))
                    .map(|tref| (tref, None))
            }
        }
    }
}
