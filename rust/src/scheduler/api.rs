//! The unified, event-driven scheduler interface: "when JobTracker gets
//! task request, it will select a good job from job queue … then the
//! execution result will feedback to the JobTracker" (paper §3).
//!
//! A [`Scheduler`] interacts with a driver (the MRv1 JobTracker *or* the
//! YARN ResourceManager — both run the same trait) through exactly two
//! methods:
//!
//! * [`Scheduler::assign`] — called once per TaskTracker/NodeManager
//!   heartbeat with a [`SlotBudget`] covering **all** free slots. The
//!   scheduler scores the job queue once and returns an ordered batch of
//!   [`Assignment`]s, mirroring Hadoop's real `TaskScheduler.assignTasks`
//!   batch semantics. Learned schedulers compute posteriors and utilities
//!   per heartbeat, not per slot.
//! * [`Scheduler::observe`] — the single feedback channel: every driver
//!   notification (cluster info, overload-rule feedback, task lifecycle)
//!   arrives as one [`SchedEvent`]. Schedulers must tolerate events in any
//!   driver interleaving, including events for jobs they have never seen.
//!
//! ## Migration from the legacy per-slot API
//!
//! | old (per-slot)                         | new (batched / event-driven)              |
//! |----------------------------------------|-------------------------------------------|
//! | `select(view, node, kind) -> TaskRef`  | `assign(view, node, budget) -> Vec<Assignment>` |
//! | `on_cluster_info(total_slots)`         | `observe(SchedEvent::ClusterInfo { .. })` |
//! | `feedback(feats, label)`               | `observe(SchedEvent::Feedback { .. })`    |
//! | `on_task_started(job)`                 | `observe(SchedEvent::TaskStarted { .. })` |
//! | `on_task_finished(job)`                | `observe(SchedEvent::TaskFinished { .. })`|
//! | `on_job_completed(job)`                | `observe(SchedEvent::JobCompleted { .. })`|
//!
//! Each [`Assignment`] carries a [`Decision`] record (chosen job,
//! posterior, utility, locality, candidates considered) that drivers thread
//! into metrics and the `repro run --explain` trace.
//!
//! ## Batch contract
//!
//! Within one `assign` call the returned batch must (a) never assign the
//! same task twice, (b) never exceed the per-kind budget, and (c) never
//! propose a reduce for a job whose map phase is incomplete. [`BatchState`]
//! implements the shared bookkeeping: it tracks what the batch has already
//! claimed so later picks see an up-to-date view without mutating the job
//! table. Drivers validate each assignment before launching (YARN re-checks
//! the declared-resource fit, both drivers re-check slot/pending state) and
//! may drop proposals that fail — scheduler-internal state stays consistent
//! because it is only updated through `observe` events for tasks that
//! actually launched.

use std::collections::BTreeMap;

use crate::bayes::classifier::Label;
use crate::bayes::features::FeatureVec;
use crate::cluster::node::Node;
use crate::hdfs::locality::Locality;
use crate::hdfs::Namespace;
use crate::job::job::Job;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;
use crate::sim::engine::Time;

/// Read-only view handed to the scheduler on each heartbeat.
pub struct SchedView<'a> {
    pub jobs: &'a JobTable,
    pub hdfs: &'a Namespace,
    /// Schedulable jobs (have a pending task), submission order.
    pub queue: &'a [JobId],
    pub now: Time,
}

/// Free capacity offered to one `assign` call: every free slot of the
/// heartbeating node, by kind. Drivers with an orthogonal cap (YARN's
/// per-node container limit) may truncate the returned batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotBudget {
    pub maps: u32,
    pub reduces: u32,
}

impl SlotBudget {
    pub fn of(&self, kind: TaskKind) -> u32 {
        match kind {
            TaskKind::Map => self.maps,
            TaskKind::Reduce => self.reduces,
        }
    }

    pub fn total(&self) -> u32 {
        self.maps + self.reduces
    }
}

/// Why a task was chosen: the per-assignment explanation record threaded
/// into metrics and the `--explain` trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// The job the winning task belongs to.
    pub job: JobId,
    pub kind: TaskKind,
    /// P(good | job, node) — learned schedulers only.
    pub posterior: Option<f32>,
    /// U(i), the utility that weighted the posterior — learned schedulers
    /// only.
    pub utility: Option<f32>,
    /// Input locality of the picked task (maps only).
    pub locality: Option<Locality>,
    /// Queue candidates considered for this slot.
    pub candidates: u32,
}

impl Decision {
    /// A decision record with no learned scores (heuristic schedulers).
    pub fn unscored(job: JobId, kind: TaskKind, locality: Option<Locality>, candidates: u32) -> Decision {
        Decision { job, kind, posterior: None, utility: None, locality, candidates }
    }
}

impl std::fmt::Display for Decision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.kind {
            TaskKind::Map => "map",
            TaskKind::Reduce => "reduce",
        };
        write!(f, "{} [{kind}]", self.job)?;
        if let Some(p) = self.posterior {
            write!(f, " posterior={p:.3}")?;
        }
        if let Some(u) = self.utility {
            write!(f, " utility={u:.3}")?;
        }
        if let Some(l) = self.locality {
            write!(f, " locality={}", l.name())?;
        }
        write!(f, " candidates={}", self.candidates)
    }
}

/// One proposed task launch in a heartbeat batch.
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    pub task: TaskRef,
    pub decision: Decision,
}

/// The single event stream drivers feed back into a scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedEvent {
    /// Cluster-level facts, sent once at startup (the Capacity scheduler
    /// sizes queue promises from the slot total).
    ClusterInfo { total_slots: u32 },
    /// Overload-rule verdict for an earlier placement (the Bayes learner's
    /// training signal; the baselines ignore it — that is the paper's
    /// point).
    Feedback { feats: FeatureVec, label: Label },
    /// A task of `job` started on some node.
    TaskStarted { job: JobId },
    /// A task of `job` left a node (completed, failed, or lost).
    TaskFinished { job: JobId },
    /// `job` finished entirely.
    JobCompleted { job: JobId },
}

/// A job scheduler (FIFO / Fair / Capacity / Bayes / ...), batched and
/// event-driven. Runs unchanged under both the MRv1 JobTracker and the
/// YARN ResourceManager drivers.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Fill the heartbeat's free slots in one call. See the module docs for
    /// the batch contract.
    fn assign(&mut self, view: &SchedView, node: &Node, budget: SlotBudget) -> Vec<Assignment>;

    /// Absorb one driver notification. Default: ignore everything.
    fn observe(&mut self, _ev: &SchedEvent) {}

    /// Export the learned model as JSON, if this scheduler has one
    /// (`repro run --save-model`).
    fn export_model(&self) -> Option<crate::config::json::Json> {
        None
    }
}

/// Within-batch bookkeeping shared by every scheduler: which tasks this
/// heartbeat's batch has already claimed, so later picks in the same batch
/// never double-assign (the job table is not mutated until the driver
/// launches the batch).
#[derive(Debug, Default)]
pub struct BatchState {
    taken: Vec<TaskRef>,
    maps_taken: BTreeMap<JobId, u32>,
    reduces_taken: BTreeMap<JobId, u32>,
}

impl BatchState {
    pub fn new() -> BatchState {
        BatchState::default()
    }

    /// Record that the batch assigned `task`.
    pub fn claim(&mut self, task: TaskRef) {
        debug_assert!(!self.taken.contains(&task), "double-claimed {task}");
        self.taken.push(task);
        let tally = match task.kind {
            TaskKind::Map => &mut self.maps_taken,
            TaskKind::Reduce => &mut self.reduces_taken,
        };
        *tally.entry(task.job).or_insert(0) += 1;
    }

    /// Tasks of `kind` the batch already claimed from `job`.
    pub fn claimed(&self, job: JobId, kind: TaskKind) -> u32 {
        let tally = match kind {
            TaskKind::Map => &self.maps_taken,
            TaskKind::Reduce => &self.reduces_taken,
        };
        *tally.get(&job).unwrap_or(&0)
    }

    pub fn len(&self) -> usize {
        self.taken.len()
    }

    pub fn is_empty(&self) -> bool {
        self.taken.is_empty()
    }

    /// Does `job` still have a task a `kind` slot could run, net of what
    /// this batch already claimed? Reduces stay gated on the map phase
    /// (maps claimed in this batch are not complete, so they cannot unlock
    /// reduces within the batch).
    pub fn has_work(&self, job: &Job, kind: TaskKind) -> bool {
        match kind {
            TaskKind::Map => {
                job.pending_maps() > self.claimed(job.id, TaskKind::Map) as usize
            }
            TaskKind::Reduce => {
                job.maps_complete()
                    && job.pending_reduces()
                        > self.claimed(job.id, TaskKind::Reduce) as usize
            }
        }
    }

    /// Locality-aware task pick *within* a chosen job (paper §4.2: "select
    /// the required data in the job to schedule the tasks on the
    /// TaskTracker firstly. If there does not exist such kind of tasks, we
    /// will select the tasks whose data are not local"). Shared by every
    /// scheduler, so baselines differ only in *job* selection — exactly the
    /// paper's framing. Skips tasks this batch already claimed; returns the
    /// pick plus its locality (maps only) for the [`Decision`] record.
    pub fn pick_task(
        &self,
        job: &Job,
        node: &Node,
        hdfs: &Namespace,
        kind: TaskKind,
    ) -> Option<(TaskRef, Option<Locality>)> {
        match kind {
            TaskKind::Map => {
                let mut best: Option<(Locality, u32)> = None;
                for t in job.maps.iter().filter(|t| t.is_pending()) {
                    let tref =
                        TaskRef { job: job.id, kind: TaskKind::Map, index: t.index };
                    if self.taken.contains(&tref) {
                        continue;
                    }
                    let loc =
                        hdfs.locality(t.block.expect("map without block"), node.id);
                    let rank = |l: Locality| match l {
                        Locality::NodeLocal => 0,
                        Locality::RackLocal => 1,
                        Locality::Remote => 2,
                    };
                    match best {
                        Some((b, _)) if rank(b) <= rank(loc) => {}
                        _ => best = Some((loc, t.index)),
                    }
                    if rank(loc) == 0 {
                        break; // cannot do better than node-local
                    }
                }
                best.map(|(loc, index)| {
                    (
                        TaskRef { job: job.id, kind: TaskKind::Map, index },
                        Some(loc),
                    )
                })
            }
            TaskKind::Reduce => {
                if !job.maps_complete() {
                    return None; // reduces gated on the map phase
                }
                job.reduces
                    .iter()
                    .filter(|t| t.is_pending())
                    .map(|t| TaskRef {
                        job: job.id,
                        kind: TaskKind::Reduce,
                        index: t.index,
                    })
                    .find(|tref| !self.taken.contains(tref))
                    .map(|tref| (tref, None))
            }
        }
    }
}
