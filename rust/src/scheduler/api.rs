//! The scheduler interface: "the schedule of homework is to assign the
//! proper tasks to proper servers. There are two steps to go. Firstly, you
//! should select the homework, then in the homework you should choose the
//! right task." (paper §3)
//!
//! Schedulers are consulted on every TaskTracker heartbeat, once per free
//! slot, exactly like Hadoop MRv1's `TaskScheduler.assignTasks`.

use crate::bayes::classifier::Label;
use crate::bayes::features::FeatureVec;
use crate::cluster::node::Node;
use crate::hdfs::locality::Locality;
use crate::hdfs::Namespace;
use crate::job::job::Job;
use crate::job::queue::JobTable;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;
use crate::sim::engine::Time;

/// Read-only view handed to the scheduler on each decision.
pub struct SchedView<'a> {
    pub jobs: &'a JobTable,
    pub hdfs: &'a Namespace,
    /// Schedulable jobs (have a pending task), submission order.
    pub queue: &'a [JobId],
    pub now: Time,
}

/// A job scheduler (FIFO / Fair / Capacity / Bayes / ...).
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Called once at startup with cluster-level facts (the Capacity
    /// scheduler sizes queue promises from the slot total).
    fn on_cluster_info(&mut self, _total_slots: u32) {}

    /// Pick the next task for one free `kind` slot on `node`, or None to
    /// leave the slot idle this heartbeat.
    fn select(&mut self, view: &SchedView, node: &Node, kind: TaskKind)
        -> Option<TaskRef>;

    /// Overload-rule feedback for an earlier placement (Bayes only; the
    /// baselines ignore it — that is the paper's point).
    fn feedback(&mut self, _feats: FeatureVec, _label: Label) {}

    /// Export the learned model as JSON, if this scheduler has one
    /// (`repro run --save-model`).
    fn export_model(&self) -> Option<crate::config::json::Json> {
        None
    }

    /// Bookkeeping notifications.
    fn on_task_started(&mut self, _job: JobId) {}
    fn on_task_finished(&mut self, _job: JobId) {}
    fn on_job_completed(&mut self, _job: JobId) {}
}

/// Locality-aware task pick *within* a chosen job (paper §4.2: "select the
/// required data in the job to schedule the tasks on the TaskTracker
/// firstly. If there does not exist such kind of tasks, we will select the
/// tasks whose data are not local"). Shared by every scheduler, so
/// baselines differ only in *job* selection — exactly the paper's framing.
pub fn pick_task(
    job: &Job,
    node: &Node,
    hdfs: &Namespace,
    kind: TaskKind,
) -> Option<TaskRef> {
    match kind {
        TaskKind::Map => {
            let mut best: Option<(Locality, u32)> = None;
            for t in job.maps.iter().filter(|t| t.is_pending()) {
                let loc = hdfs.locality(t.block.expect("map without block"), node.id);
                let rank = |l: Locality| match l {
                    Locality::NodeLocal => 0,
                    Locality::RackLocal => 1,
                    Locality::Remote => 2,
                };
                match best {
                    Some((b, _)) if rank(b) <= rank(loc) => {}
                    _ => best = Some((loc, t.index)),
                }
                if rank(loc) == 0 {
                    break; // cannot do better than node-local
                }
            }
            best.map(|(_, index)| TaskRef { job: job.id, kind: TaskKind::Map, index })
        }
        TaskKind::Reduce => {
            if !job.maps_complete() {
                return None; // reduces gated on the map phase
            }
            job.reduces
                .iter()
                .find(|t| t.is_pending())
                .map(|t| TaskRef { job: job.id, kind: TaskKind::Reduce, index: t.index })
        }
    }
}

/// Does `job` have any task a `kind` slot could run right now?
pub fn has_work(job: &Job, kind: TaskKind) -> bool {
    match kind {
        TaskKind::Map => job.pending_maps() > 0,
        TaskKind::Reduce => job.maps_complete() && job.pending_reduces() > 0,
    }
}
