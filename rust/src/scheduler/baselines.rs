//! Extra sanity baselines beyond the paper's three: random job pick and
//! least-loaded-aware FIFO. Used in ablations to separate "any load
//! awareness helps" from "learned classification helps".

use crate::cluster::node::Node;
use crate::cluster::resources::Resources;
use crate::job::task::TaskKind;
use crate::obs::SchedObs;
use crate::sim::rng::Pcg;

use super::api::{Assignment, BatchState, Decision, SchedView, Scheduler, SlotBudget};

/// Uniform-random job selection (lower bound).
pub struct RandomSched {
    rng: Pcg,
    obs: SchedObs,
}

impl RandomSched {
    pub fn new(seed: u64) -> RandomSched {
        RandomSched { rng: Pcg::new(seed, 0x5EED), obs: SchedObs::default() }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> &'static str {
        "random"
    }

    fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.obs.install(registry, self.name());
    }

    fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        let sw = self.obs.start();
        let mut batch = BatchState::new();
        let mut out = Vec::new();
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            for _ in 0..budget.of(kind) {
                let cands: Vec<_> = view
                    .queue
                    .iter()
                    .map(|id| view.jobs.get(*id))
                    .filter(|j| batch.has_work(j, kind))
                    .collect();
                if cands.is_empty() {
                    break;
                }
                let start = self.rng.index(cands.len());
                // random start, linear probe so a pick always lands if any
                // job has an assignable task
                let mut placed = false;
                for k in 0..cands.len() {
                    let job = cands[(start + k) % cands.len()];
                    if let Some((task, loc)) =
                        batch.pick_task(job, node, view.hdfs, kind)
                    {
                        batch.claim(task);
                        out.push(Assignment {
                            task,
                            decision: Decision::unscored(
                                job.id,
                                kind,
                                loc,
                                cands.len() as u32,
                            ),
                        });
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        self.obs.finish(sw, out.len());
        out
    }
}

/// FIFO that refuses placements which would oversubscribe the node's
/// bottleneck resource — a hand-written (non-learning) overload avoider.
/// The gap between this and Bayes isolates the value of *learning* the
/// rule vs hard-coding it.
pub struct ThresholdFifo {
    /// Refuse placement when predicted bottleneck utilization exceeds this.
    pub max_util: f64,
    obs: SchedObs,
}

impl ThresholdFifo {
    pub fn new(max_util: f64) -> ThresholdFifo {
        ThresholdFifo { max_util, obs: SchedObs::default() }
    }
}

impl Scheduler for ThresholdFifo {
    fn name(&self) -> &'static str {
        "threshold-fifo"
    }

    fn install_obs(&mut self, registry: &crate::obs::Registry) {
        self.obs.install(registry, self.name());
    }

    fn assign(
        &mut self,
        view: &SchedView,
        node: &Node,
        budget: SlotBudget,
    ) -> Vec<Assignment> {
        let sw = self.obs.start();
        let mut batch = BatchState::new();
        let mut out = Vec::new();
        // demand the batch has already committed to this node, so the
        // threshold check stays honest across the whole heartbeat
        let mut committed = Resources::ZERO;
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            // candidates = jobs with assignable work of this kind, like
            // every other scheduler's Decision record
            let candidates = view
                .queue
                .iter()
                .filter(|id| batch.has_work(view.jobs.get(**id), kind))
                .count() as u32;
            for _ in 0..budget.of(kind) {
                let demand_now = node.demand() + committed;
                let mut placed = false;
                for id in view.queue {
                    let job = view.jobs.get(*id);
                    if !batch.has_work(job, kind) {
                        continue;
                    }
                    let predicted =
                        (demand_now + job.demand).frac_of(&node.spec.capacity);
                    if predicted.max_component() > self.max_util {
                        continue;
                    }
                    if let Some((task, loc)) =
                        batch.pick_task(job, node, view.hdfs, kind)
                    {
                        batch.claim(task);
                        committed += job.demand;
                        out.push(Assignment {
                            task,
                            decision: Decision::unscored(*id, kind, loc, candidates),
                        });
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        self.obs.finish(sw, out.len());
        out
    }
}
