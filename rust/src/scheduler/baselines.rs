//! Extra sanity baselines beyond the paper's three: random job pick and
//! least-loaded-aware FIFO. Used in ablations to separate "any load
//! awareness helps" from "learned classification helps".

use crate::cluster::node::Node;
use crate::job::task::{TaskKind, TaskRef};
use crate::sim::rng::Pcg;

use super::api::{has_work, pick_task, SchedView, Scheduler};

/// Uniform-random job selection (lower bound).
pub struct RandomSched {
    rng: Pcg,
}

impl RandomSched {
    pub fn new(seed: u64) -> RandomSched {
        RandomSched { rng: Pcg::new(seed, 0x5EED) }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(
        &mut self,
        view: &SchedView,
        node: &Node,
        kind: TaskKind,
    ) -> Option<TaskRef> {
        let cands: Vec<_> = view
            .queue
            .iter()
            .map(|id| view.jobs.get(*id))
            .filter(|j| has_work(j, kind))
            .collect();
        if cands.is_empty() {
            return None;
        }
        let start = self.rng.index(cands.len());
        // random start, linear probe so a pick always lands if any job has
        // an assignable task
        for k in 0..cands.len() {
            let job = cands[(start + k) % cands.len()];
            if let Some(t) = pick_task(job, node, view.hdfs, kind) {
                return Some(t);
            }
        }
        None
    }
}

/// FIFO that refuses placements which would oversubscribe the node's
/// bottleneck resource — a hand-written (non-learning) overload avoider.
/// The gap between this and Bayes isolates the value of *learning* the
/// rule vs hard-coding it.
pub struct ThresholdFifo {
    /// Refuse placement when predicted bottleneck utilization exceeds this.
    pub max_util: f64,
}

impl ThresholdFifo {
    pub fn new(max_util: f64) -> ThresholdFifo {
        ThresholdFifo { max_util }
    }
}

impl Scheduler for ThresholdFifo {
    fn name(&self) -> &'static str {
        "threshold-fifo"
    }

    fn select(
        &mut self,
        view: &SchedView,
        node: &Node,
        kind: TaskKind,
    ) -> Option<TaskRef> {
        let demand_now = node.demand();
        for id in view.queue {
            let job = view.jobs.get(*id);
            if !has_work(job, kind) {
                continue;
            }
            let predicted = (demand_now + job.demand).frac_of(&node.spec.capacity);
            if predicted.max_component() > self.max_util {
                continue;
            }
            if let Some(t) = pick_task(job, node, view.hdfs, kind) {
                return Some(t);
            }
        }
        None
    }
}
