//! Configuration: hand-rolled JSON + TOML-subset parsers (the offline crate
//! cache has no serde/toml) and the typed experiment/cluster config structs.

pub mod json;
pub mod toml;
pub mod types;

pub use json::Json;
pub use toml::{TomlDoc, TomlValue};
pub use types::{load_run_config, run_config_from_toml};
