//! Minimal JSON parser/writer (serde_json substitute — the offline crate
//! cache has no serde facade).
//!
//! Supports the full JSON grammar; `\u` escapes are validated (surrogate
//! pairs decode to their scalar, lone surrogates are rejected). Numbers
//! parse to f64 (adequate for manifests, traces and metric dumps).
//!
//! [`Json::parse`] is a thin tree-building wrapper over the streaming
//! [`pull`] tokenizer — one iterative loop, no recursion, nesting capped
//! at [`pull::MAX_DEPTH`]. The original recursive parser survives in
//! [`reference`] as a differential oracle (`tests/json_differential.rs`
//! pins that both accept/reject and value identically).

pub(crate) mod escape;
pub mod pull;
pub mod reference;

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text by driving the pull tokenizer.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = pull::PullParser::from_slice(text.as_bytes());
        let v = build_from(&mut p)?;
        // the parser is in its end-of-document state: this errors on
        // trailing characters and returns None at clean EOF
        p.next()?;
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            // strict upper bound: `u64::MAX as f64` rounds UP to 2^64,
            // so admitting equality would saturate `f as u64` for values
            // one ulp past the true max. integrality test is exact by
            // design -- lint: allow(float-eq)
            if f >= 0.0 && f.fract() == 0.0 && f < u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Field lookup on an object; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (k, (key, v)) in o.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Build an owned tree from a pull stream positioned at a value —
/// iterative (explicit frame stack), one token at a time.
fn build_from<R: std::io::Read>(p: &mut pull::PullParser<R>) -> Result<Json, JsonError> {
    enum Frame {
        Arr(Vec<Json>),
        Obj(BTreeMap<String, Json>, Option<String>),
    }
    let mut stack: Vec<Frame> = Vec::new();
    loop {
        let completed: Json = {
            let tok = match p.next()? {
                Some(t) => t,
                None => {
                    return Err(JsonError {
                        offset: p.offset(),
                        msg: "unexpected end of input".into(),
                    })
                }
            };
            match tok {
                pull::Token::BeginArr => {
                    stack.push(Frame::Arr(Vec::new()));
                    continue;
                }
                pull::Token::BeginObj => {
                    stack.push(Frame::Obj(BTreeMap::new(), None));
                    continue;
                }
                pull::Token::Key(k) => {
                    let k = k.to_string();
                    if let Some(Frame::Obj(_, pending)) = stack.last_mut() {
                        *pending = Some(k);
                    }
                    continue;
                }
                pull::Token::Null => Json::Null,
                pull::Token::Bool(b) => Json::Bool(b),
                pull::Token::Num(n) => Json::Num(n),
                pull::Token::Str(s) => Json::Str(s.to_string()),
                pull::Token::EndArr => match stack.pop() {
                    Some(Frame::Arr(a)) => Json::Arr(a),
                    _ => unreachable!("pull parser balances arrays"),
                },
                pull::Token::EndObj => match stack.pop() {
                    Some(Frame::Obj(m, _)) => Json::Obj(m),
                    _ => unreachable!("pull parser balances objects"),
                },
            }
        };
        match stack.last_mut() {
            None => return Ok(completed),
            Some(Frame::Arr(a)) => a.push(completed),
            Some(Frame::Obj(m, pending)) => match pending.take() {
                Some(key) => {
                    m.insert(key, completed);
                }
                None => unreachable!("pull parser emits a key before each member"),
            },
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Write one f64 the canonical way: integers below 1e15 print without a
/// trailing `.0`. Shared with the streaming trace writer.
pub(crate) fn write_num(out: &mut String, n: f64) {
    // integers print without '.0' -- lint: allow(float-eq)
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

/// Write one string with JSON escaping. Shared with the streaming trace
/// writer.
pub(crate) fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
        assert_eq!(
            Json::parse(&v.to_string_compact()).unwrap().as_str().unwrap(),
            "héllo → 世界"
        );
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn as_u64_rejects_two_to_the_64() {
        // u64::MAX as f64 rounds UP to 2^64 — the old `<=` bound admitted
        // it and the cast saturated to u64::MAX. Values >= 2^64 must be
        // rejected.
        let two_64 = 18446744073709551616.0; // 2^64 == u64::MAX as f64
        assert_eq!(Json::Num(two_64).as_u64(), None);
        assert_eq!(Json::Num(two_64 * 2.0).as_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        // the largest f64 strictly below 2^64 is fine
        let below = 18446744073709549568.0; // 2^64 - 2048
        assert_eq!(Json::Num(below).as_u64(), Some(18446744073709549568));
        assert_eq!(Json::Num(9.007199254740992e15).as_u64(), Some(1 << 53));
    }

    #[test]
    fn surrogate_escapes_validate_in_both_parsers() {
        for text in [
            r#""\ud83d\ude00""#, // valid pair -> 😀
            r#""\ud83d""#,       // lone high
            r#""\ude00""#,       // lone low
            r#""\ud83dx""#,      // high followed by raw char
            r#""\ud83d\n""#,     // high followed by a different escape
            r#""A""#,       // plain scalar
        ] {
            let a = Json::parse(text);
            let b = reference::parse(text);
            assert_eq!(a.is_ok(), b.is_ok(), "disagree on {text}");
            if let (Ok(a), Ok(b)) = (&a, &b) {
                assert_eq!(a, b, "values disagree on {text}");
            }
        }
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap().as_str().unwrap(),
            "\u{1F600}"
        );
    }

    #[test]
    fn deep_documents_error_instead_of_overflowing() {
        let deep = "[".repeat(pull::MAX_DEPTH + 1) + &"]".repeat(pull::MAX_DEPTH + 1);
        assert!(Json::parse(&deep).is_err());
        assert!(reference::parse(&deep).is_err());
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}
