//! Minimal JSON parser/writer (serde_json substitute — the offline crate
//! cache has no serde facade).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Numbers parse to f64 (adequate for manifests,
//! traces and metric dumps).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            // integrality test is exact by design -- lint: allow(float-eq)
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Field lookup on an object; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // integers print without '.0' -- lint: allow(float-eq)
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (k, (key, v)) in o.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unterminated string"))?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
        assert_eq!(
            Json::parse(&v.to_string_compact()).unwrap().as_str().unwrap(),
            "héllo → 世界"
        );
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}
