//! Minimal TOML-subset parser (toml-crate substitute).
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` with strings,
//! integers, floats, booleans, and flat arrays; `#` comments. Unsupported
//! (and rejected loudly): inline tables, array-of-tables, multi-line
//! strings, datetimes. The experiment configs only need the subset.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric coercion (int or float).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: `section.key` -> value. Root-level keys use `key`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(TomlValue::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(TomlValue::as_i64).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(TomlValue::as_bool).unwrap_or(default)
    }

    /// Keys under a section prefix (e.g. `workload.`).
    pub fn section_keys(&self, prefix: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|k| k.as_str())
            .collect()
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            if line.starts_with("[[") {
                return Err(err("array-of-tables is not supported"));
            }
            let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(err("empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let value = parse_value(value.trim()).map_err(|m| err(&m))?;
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(path, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but correct for our subset: '#' inside quoted strings guarded
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("escaped quotes not supported".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let parts = split_top_level(inner)?;
        let vals: Result<Vec<_>, _> =
            parts.iter().map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(vals?));
    }
    if s.starts_with('{') {
        return Err("inline tables not supported".into());
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split an array body on commas not nested in strings/brackets.
fn split_top_level(s: &str) -> Result<Vec<String>, String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or("unbalanced brackets")?;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_document() {
        let doc = parse("a = 1\nb = \"two\"\nc = 3.5\nd = true\n").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("b").unwrap().as_str(), Some("two"));
        assert_eq!(doc.get("c").unwrap().as_f64(), Some(3.5));
        assert_eq!(doc.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn sections_prefix_keys() {
        let doc = parse("[workload]\nn_jobs = 200\n[cluster.hw]\nnodes = 40\n").unwrap();
        assert_eq!(doc.i64_or("workload.n_jobs", 0), 200);
        assert_eq!(doc.i64_or("cluster.hw.nodes", 0), 40);
    }

    #[test]
    fn comments_and_blanks() {
        let doc = parse("# header\n\na = 1 # trailing\ns = \"with # inside\"\n").unwrap();
        assert_eq!(doc.i64_or("a", 0), 1);
        assert_eq!(doc.str_or("s", ""), "with # inside");
    }

    #[test]
    fn arrays() {
        let doc = parse("mix = [0.3, 0.25, 0.45]\nnames = [\"a\", \"b\"]\nempty = []\n")
            .unwrap();
        let mix = doc.get("mix").unwrap().as_arr().unwrap();
        assert_eq!(mix.len(), 3);
        assert_eq!(mix[2].as_f64(), Some(0.45));
        let names = doc.get("names").unwrap().as_arr().unwrap();
        assert_eq!(names[1].as_str(), Some("b"));
        assert_eq!(doc.get("empty").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse("big = 1_000_000\n").unwrap();
        assert_eq!(doc.i64_or("big", 0), 1_000_000);
    }

    #[test]
    fn rejects_unsupported() {
        assert!(parse("[[jobs]]\nx = 1\n").is_err());
        assert!(parse("x = {a = 1}\n").is_err());
        assert!(parse("x = \"unterminated\n").is_err());
        assert!(parse("x 1\n").is_err());
        assert!(parse("[bad\n").is_err());
    }

    #[test]
    fn defaults_api() {
        let doc = parse("a = 1\n").unwrap();
        assert_eq!(doc.f64_or("missing", 9.5), 9.5);
        assert_eq!(doc.str_or("missing", "d"), "d");
        assert!(!doc.bool_or("missing", false));
    }
}
