//! The original recursive tree parser, kept as a differential oracle
//! for the pull tokenizer ([`super::pull`]) — `Json::parse` itself now
//! drives the pull parser, and `tests/json_differential.rs` asserts
//! both paths agree on accept/reject and values for adversarial
//! documents.
//!
//! To keep the two comparable on hostile input, this oracle shares the
//! `\u` escape decoder ([`super::escape`]: surrogate pairs combine,
//! lone surrogates reject) and enforces the same nesting cap
//! ([`super::pull::MAX_DEPTH`]) so deep documents error identically
//! instead of overflowing the call stack here.

use std::collections::BTreeMap;

use super::escape::{classify, combine, hex4, UnitClass};
use super::pull::MAX_DEPTH;
use super::{Json, JsonError};

/// Parse a JSON document with the recursive oracle.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("document too deep"));
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.enter()?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.enter()?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    /// Consume `\uXXXX` hex with `self.i` on the `u`; leaves `self.i` on
    /// the last hex digit (the caller's `+= 1` steps past it).
    fn hex_unit(&mut self) -> Result<u16, JsonError> {
        if self.i + 4 >= self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let h = [
            self.b[self.i + 1],
            self.b[self.i + 2],
            self.b[self.i + 3],
            self.b[self.i + 4],
        ];
        let unit = hex4(h).ok_or_else(|| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(unit)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => match classify(self.hex_unit()?) {
                            UnitClass::Scalar(c) => s.push(c),
                            UnitClass::Low(_) => {
                                return Err(self.err("lone low surrogate in \\u escape"))
                            }
                            UnitClass::High(hi) => {
                                // the low half must follow immediately
                                if self.b.get(self.i + 1) != Some(&b'\\')
                                    || self.b.get(self.i + 2) != Some(&b'u')
                                {
                                    return Err(
                                        self.err("unpaired surrogate in \\u escape")
                                    );
                                }
                                self.i += 2;
                                match classify(self.hex_unit()?) {
                                    UnitClass::Low(lo) => s.push(combine(hi, lo)),
                                    _ => {
                                        return Err(
                                            self.err("unpaired surrogate in \\u escape")
                                        )
                                    }
                                }
                            }
                        },
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unterminated string"))?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_still_parses_the_basics() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn oracle_depth_cap_matches_pull() {
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(parse(&ok).is_ok());
        let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(parse(&deep).unwrap_err().msg.contains("too deep"));
    }

    #[test]
    fn oracle_surrogates() {
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap(), Json::Str("😀".into()));
        assert!(parse(r#""\ud83d""#).is_err());
        assert!(parse(r#""\ude00""#).is_err());
    }
}
