//! Shared `\u` escape decoding used by BOTH JSON parsers — the pull
//! tokenizer ([`super::pull`]) and the recursive tree oracle
//! ([`super::reference`]) — so surrogate handling cannot drift between
//! them. The parsers own the byte fetching; this module owns the
//! classification and combination rules.

/// One decoded UTF-16 code unit from a `\uXXXX` escape, classified.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum UnitClass {
    /// A plain BMP scalar (not a surrogate).
    Scalar(char),
    /// High (lead) surrogate `0xD800..=0xDBFF` — must be immediately
    /// followed by a low surrogate escape.
    High(u16),
    /// Low (trail) surrogate `0xDC00..=0xDFFF` — invalid on its own.
    Low(u16),
}

/// Parse 4 ASCII hex digits into a UTF-16 code unit. Strict: exactly
/// `[0-9a-fA-F]`, no signs or whitespace (unlike `from_str_radix`,
/// which admits a leading `+`).
pub(crate) fn hex4(h: [u8; 4]) -> Option<u16> {
    let mut v: u16 = 0;
    for b in h {
        let d = match b {
            b'0'..=b'9' => b - b'0',
            b'a'..=b'f' => b - b'a' + 10,
            b'A'..=b'F' => b - b'A' + 10,
            _ => return None,
        };
        v = (v << 4) | d as u16;
    }
    Some(v)
}

/// Classify a decoded UTF-16 unit. Non-surrogate BMP units are always
/// valid scalars; the fallback is unreachable.
pub(crate) fn classify(unit: u16) -> UnitClass {
    match unit {
        0xD800..=0xDBFF => UnitClass::High(unit),
        0xDC00..=0xDFFF => UnitClass::Low(unit),
        u => UnitClass::Scalar(char::from_u32(u as u32).unwrap_or('\u{fffd}')),
    }
}

/// Combine a validated surrogate pair into its scalar value. The result
/// is always in `0x10000..=0x10FFFF`, so the fallback is unreachable.
pub(crate) fn combine(hi: u16, lo: u16) -> char {
    let c = 0x10000 + (((hi as u32 - 0xD800) << 10) | (lo as u32 - 0xDC00));
    char::from_u32(c).unwrap_or('\u{fffd}')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex4_strict() {
        assert_eq!(hex4(*b"0041"), Some(0x41));
        assert_eq!(hex4(*b"FFff"), Some(0xFFFF));
        assert_eq!(hex4(*b"+123"), None, "no signs, unlike from_str_radix");
        assert_eq!(hex4(*b"12g4"), None);
    }

    #[test]
    fn classify_splits_the_planes() {
        assert_eq!(classify(0x41), UnitClass::Scalar('A'));
        assert_eq!(classify(0xD83D), UnitClass::High(0xD83D));
        assert_eq!(classify(0xDE00), UnitClass::Low(0xDE00));
    }

    #[test]
    fn combine_reaches_the_astral_planes() {
        assert_eq!(combine(0xD83D, 0xDE00), '\u{1F600}');
        assert_eq!(combine(0xD800, 0xDC00), '\u{10000}');
        assert_eq!(combine(0xDBFF, 0xDFFF), '\u{10FFFF}');
    }
}
