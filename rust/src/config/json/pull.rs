//! Non-recursive pull tokenizer over any `std::io::Read` source — the
//! picojson `SliceParser`/`StreamParser` split collapsed into one
//! generic parser (`&[u8]` implements `Read`, so the slice path is the
//! stream path with a trivial source).
//!
//! Design rules (enforced by the `engine-hot-loop` lint on this file):
//!
//! - **No recursion.** Nesting is tracked by a fixed bitstack (one bit
//!   per level: set = object, clear = array), so a pathologically deep
//!   document errors at [`MAX_DEPTH`] instead of overflowing the stack.
//! - **No per-token heap allocation.** The read buffer is one fixed
//!   chunk; string and number tokens decode into reusable scratch
//!   buffers that are cleared, not reallocated, per token. Resident
//!   memory is O(largest token), never O(document) —
//!   [`PullParser::resident_bytes`] reports it so tests can pin the
//!   bound.
//!
//! Grammar quirks are bit-compatible with the recursive tree oracle in
//! [`super::reference`] (differential-tested in
//! `tests/json_differential.rs`): the number text is collected by the
//! same character classes and handed to `str::parse::<f64>` (so `"1."`
//! and `"01"` parse, `"1e999"` is `inf`), raw control characters inside
//! strings pass through, and both share the `\u` escape decoder in
//! [`super::escape`] (surrogate pairs combine, lone surrogates reject).

use std::io::Read;

use super::escape::{classify, combine, hex4, UnitClass};
use super::JsonError;

/// Maximum container nesting either parser accepts.
pub const MAX_DEPTH: usize = 512;

/// Size of the bounded read buffer.
const CHUNK: usize = 8 * 1024;

/// One structural event from the token stream. Borrowing tokens
/// (`Key`, `Str`) point into the parser's scratch buffer and are valid
/// until the next [`PullParser::next`] call.
#[derive(Debug, PartialEq)]
pub enum Token<'a> {
    BeginObj,
    EndObj,
    BeginArr,
    EndArr,
    /// An object key; the following `:` is already consumed.
    Key(&'a str),
    Null,
    Bool(bool),
    Num(f64),
    Str(&'a str),
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum State {
    /// Expecting the document's root value.
    TopValue,
    /// Expecting a value (after `:` or after `,` inside an array).
    Value,
    /// Just opened `[`: a value or an immediate `]`.
    FirstInArr,
    /// Just opened `{`: a key or an immediate `}`.
    FirstInObj,
    /// After `,` inside an object: a key is required.
    KeyNext,
    /// After a complete value inside a container: `,` or the closer.
    CommaOrEnd,
    /// Root value complete; only whitespace may follow.
    Done,
}

/// Streaming JSON tokenizer. See the module docs for the memory and
/// grammar contract.
pub struct PullParser<R: Read> {
    src: R,
    /// Bounded read buffer (fixed `CHUNK` bytes, refilled in place).
    buf: Vec<u8>,
    /// Valid prefix of `buf`.
    len: usize,
    /// Cursor into `buf`.
    pos: usize,
    /// Absolute byte offset of `buf[0]` in the source.
    base: usize,
    eof: bool,
    /// Decoded bytes of the current string/key token (reused).
    scratch: Vec<u8>,
    /// Raw text of the current number token (reused).
    numbuf: Vec<u8>,
    /// Container bitstack: bit set = object, clear = array.
    stack: [u64; MAX_DEPTH / 64],
    depth: usize,
    state: State,
}

impl<'a> PullParser<&'a [u8]> {
    /// Parse from an in-memory slice (`&[u8]` is a `Read` source).
    pub fn from_slice(b: &'a [u8]) -> PullParser<&'a [u8]> {
        PullParser::new(b)
    }
}

impl<R: Read> PullParser<R> {
    pub fn new(src: R) -> PullParser<R> {
        let mut buf = Vec::with_capacity(CHUNK);
        buf.resize(CHUNK, 0);
        PullParser {
            src,
            buf,
            len: 0,
            pos: 0,
            base: 0,
            eof: false,
            scratch: Vec::with_capacity(64),
            numbuf: Vec::with_capacity(32),
            stack: [0; MAX_DEPTH / 64],
            depth: 0,
            state: State::TopValue,
        }
    }

    /// Absolute byte offset of the next unconsumed byte.
    pub fn offset(&self) -> usize {
        self.base + self.pos
    }

    /// Bytes resident in this parser right now: the fixed chunk plus the
    /// reusable token scratch — O(largest token), never O(document).
    pub fn resident_bytes(&self) -> usize {
        self.buf.capacity()
            + self.scratch.capacity()
            + self.numbuf.capacity()
            + std::mem::size_of::<[u64; MAX_DEPTH / 64]>()
    }

    /// After a document completed (the previous [`PullParser::next`]
    /// returned the root's last token), re-arm the parser to read
    /// another document from the same source. Byte accounting
    /// continues; this is how JSONL streams replay record after record.
    pub fn reset_document(&mut self) {
        debug_assert_eq!(self.state, State::Done, "reset mid-document");
        self.state = State::TopValue;
    }

    /// True when nothing but whitespace remains in the source.
    pub fn at_eof(&mut self) -> Result<bool, JsonError> {
        self.skip_ws()?;
        Ok(self.peek()?.is_none())
    }

    /// Skip whitespace and peek the next byte without consuming it —
    /// lets callers sniff the document shape (`[` vs `{`) before
    /// pulling tokens.
    pub fn sniff(&mut self) -> Result<Option<u8>, JsonError> {
        self.skip_ws()?;
        self.peek()
    }

    /// Pull the next token. `Ok(None)` only at a clean end of document
    /// with no trailing bytes; every malformed input is an `Err`.
    #[allow(clippy::should_implement_trait)] // lending: Token borrows self
    pub fn next(&mut self) -> Result<Option<Token<'_>>, JsonError> {
        self.skip_ws()?;
        match self.state {
            State::Done => match self.peek()? {
                None => Ok(None),
                Some(_) => Err(self.err("trailing characters after document")),
            },
            State::TopValue | State::Value => self.value_token(),
            State::FirstInArr => {
                if self.peek()? == Some(b']') {
                    self.bump();
                    self.pop_level();
                    return Ok(Some(Token::EndArr));
                }
                self.value_token()
            }
            State::FirstInObj => {
                if self.peek()? == Some(b'}') {
                    self.bump();
                    self.pop_level();
                    return Ok(Some(Token::EndObj));
                }
                self.key_token()
            }
            State::KeyNext => self.key_token(),
            State::CommaOrEnd => {
                let in_obj = self.top_is_obj();
                match self.peek()? {
                    Some(b',') => {
                        self.bump();
                        self.skip_ws()?;
                        if in_obj {
                            self.state = State::KeyNext;
                            self.key_token()
                        } else {
                            self.state = State::Value;
                            self.value_token()
                        }
                    }
                    Some(b'}') if in_obj => {
                        self.bump();
                        self.pop_level();
                        Ok(Some(Token::EndObj))
                    }
                    Some(b']') if !in_obj => {
                        self.bump();
                        self.pop_level();
                        Ok(Some(Token::EndArr))
                    }
                    _ => Err(self.err(if in_obj {
                        "expected ',' or '}'"
                    } else {
                        "expected ',' or ']'"
                    })),
                }
            }
        }
    }

    fn err(&self, msg: &'static str) -> JsonError {
        JsonError { offset: self.base + self.pos, msg: msg.into() }
    }

    /// Refill the chunk buffer; only called when `pos == len`.
    fn fill(&mut self) -> Result<(), JsonError> {
        self.base += self.len;
        self.pos = 0;
        self.len = 0;
        while !self.eof {
            match self.src.read(&mut self.buf) {
                Ok(0) => self.eof = true,
                Ok(n) => {
                    self.len = n;
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Err(self.err("i/o error while reading source")),
            }
        }
        Ok(())
    }

    fn peek(&mut self) -> Result<Option<u8>, JsonError> {
        if self.pos == self.len {
            if self.eof {
                return Ok(None);
            }
            self.fill()?;
            if self.len == 0 {
                return Ok(None);
            }
        }
        Ok(Some(self.buf[self.pos]))
    }

    fn bump(&mut self) {
        self.pos += 1;
    }

    fn skip_ws(&mut self) -> Result<(), JsonError> {
        while matches!(self.peek()?, Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.bump();
        }
        Ok(())
    }

    fn push_level(&mut self, is_obj: bool) -> Result<(), JsonError> {
        if self.depth == MAX_DEPTH {
            return Err(self.err("document too deep"));
        }
        let (word, bit) = (self.depth / 64, self.depth % 64);
        if is_obj {
            self.stack[word] |= 1 << bit;
        } else {
            self.stack[word] &= !(1 << bit);
        }
        self.depth += 1;
        Ok(())
    }

    fn top_is_obj(&self) -> bool {
        let d = self.depth - 1;
        (self.stack[d / 64] >> (d % 64)) & 1 == 1
    }

    fn pop_level(&mut self) {
        self.depth -= 1;
        self.state = if self.depth == 0 { State::Done } else { State::CommaOrEnd };
    }

    /// Set the state that follows a completed scalar value.
    fn after_value(&mut self) {
        self.state = if self.depth == 0 { State::Done } else { State::CommaOrEnd };
    }

    fn value_token(&mut self) -> Result<Option<Token<'_>>, JsonError> {
        match self.peek()? {
            Some(b'n') => {
                self.expect_lit(b"null", "expected 'null'")?;
                self.after_value();
                Ok(Some(Token::Null))
            }
            Some(b't') => {
                self.expect_lit(b"true", "expected 'true'")?;
                self.after_value();
                Ok(Some(Token::Bool(true)))
            }
            Some(b'f') => {
                self.expect_lit(b"false", "expected 'false'")?;
                self.after_value();
                Ok(Some(Token::Bool(false)))
            }
            Some(b'"') => {
                self.read_string()?;
                self.after_value();
                Ok(Some(Token::Str(self.scratch_str()?)))
            }
            Some(b'[') => {
                self.bump();
                self.push_level(false)?;
                self.state = State::FirstInArr;
                Ok(Some(Token::BeginArr))
            }
            Some(b'{') => {
                self.bump();
                self.push_level(true)?;
                self.state = State::FirstInObj;
                Ok(Some(Token::BeginObj))
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let n = self.read_number()?;
                self.after_value();
                Ok(Some(Token::Num(n)))
            }
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn key_token(&mut self) -> Result<Option<Token<'_>>, JsonError> {
        if self.peek()? != Some(b'"') {
            return Err(self.err("expected '\"'"));
        }
        self.read_string()?;
        self.skip_ws()?;
        if self.peek()? != Some(b':') {
            return Err(self.err("expected ':'"));
        }
        self.bump();
        self.state = State::Value;
        Ok(Some(Token::Key(self.scratch_str()?)))
    }

    fn expect_lit(&mut self, word: &[u8], msg: &'static str) -> Result<(), JsonError> {
        for &w in word {
            if self.peek()? != Some(w) {
                return Err(self.err(msg));
            }
            self.bump();
        }
        Ok(())
    }

    /// Decode one string (cursor on the opening quote) into `scratch`.
    fn read_string(&mut self) -> Result<(), JsonError> {
        self.bump();
        self.scratch.clear();
        loop {
            match self.peek()? {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.bump();
                    return Ok(());
                }
                Some(b'\\') => {
                    self.bump();
                    self.read_escape()?;
                }
                Some(c) => {
                    // raw bytes (incl. control chars, matching the
                    // oracle); UTF-8 is validated once per token
                    self.scratch.push(c);
                    self.bump();
                }
            }
        }
    }

    /// Decode one escape (cursor on the byte after the backslash).
    fn read_escape(&mut self) -> Result<(), JsonError> {
        let simple = match self.peek()? {
            Some(b'"') => '"',
            Some(b'\\') => '\\',
            Some(b'/') => '/',
            Some(b'n') => '\n',
            Some(b't') => '\t',
            Some(b'r') => '\r',
            Some(b'b') => '\u{8}',
            Some(b'f') => '\u{c}',
            Some(b'u') => {
                self.bump();
                let c = match classify(self.read_hex4()?) {
                    UnitClass::Scalar(c) => c,
                    UnitClass::Low(_) => {
                        return Err(self.err("lone low surrogate in \\u escape"))
                    }
                    UnitClass::High(hi) => {
                        if self.peek()? != Some(b'\\') {
                            return Err(self.err("unpaired surrogate in \\u escape"));
                        }
                        self.bump();
                        if self.peek()? != Some(b'u') {
                            return Err(self.err("unpaired surrogate in \\u escape"));
                        }
                        self.bump();
                        match classify(self.read_hex4()?) {
                            UnitClass::Low(lo) => combine(hi, lo),
                            _ => {
                                return Err(self.err("unpaired surrogate in \\u escape"))
                            }
                        }
                    }
                };
                self.push_char(c);
                return Ok(());
            }
            _ => return Err(self.err("bad escape")),
        };
        self.push_char(simple);
        self.bump();
        Ok(())
    }

    /// Consume exactly 4 hex digits into a UTF-16 unit.
    fn read_hex4(&mut self) -> Result<u16, JsonError> {
        let mut h = [0u8; 4];
        for slot in &mut h {
            match self.peek()? {
                None => return Err(self.err("truncated \\u escape")),
                Some(c) => {
                    *slot = c;
                    self.bump();
                }
            }
        }
        hex4(h).ok_or_else(|| self.err("bad \\u escape"))
    }

    fn push_char(&mut self, c: char) {
        let mut tmp = [0u8; 4];
        self.scratch.extend_from_slice(c.encode_utf8(&mut tmp).as_bytes());
    }

    fn scratch_str(&self) -> Result<&str, JsonError> {
        std::str::from_utf8(&self.scratch).map_err(|_| self.err("invalid utf-8"))
    }

    /// Collect number text by the oracle's character classes and defer
    /// to `str::parse::<f64>` — identical accept/reject and values.
    fn read_number(&mut self) -> Result<f64, JsonError> {
        self.numbuf.clear();
        if self.peek()? == Some(b'-') {
            self.numbuf.push(b'-');
            self.bump();
        }
        while let Some(c) = self.peek()? {
            if !c.is_ascii_digit() {
                break;
            }
            self.numbuf.push(c);
            self.bump();
        }
        if self.peek()? == Some(b'.') {
            self.numbuf.push(b'.');
            self.bump();
            while let Some(c) = self.peek()? {
                if !c.is_ascii_digit() {
                    break;
                }
                self.numbuf.push(c);
                self.bump();
            }
        }
        if matches!(self.peek()?, Some(b'e' | b'E')) {
            self.numbuf.push(b'e');
            self.bump();
            if matches!(self.peek()?, Some(b'+' | b'-')) {
                if self.peek()? == Some(b'-') {
                    self.numbuf.push(b'-');
                }
                self.bump();
            }
            while let Some(c) = self.peek()? {
                if !c.is_ascii_digit() {
                    break;
                }
                self.numbuf.push(c);
                self.bump();
            }
        }
        let text =
            std::str::from_utf8(&self.numbuf).map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>().map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens(text: &str) -> Result<Vec<String>, JsonError> {
        let mut p = PullParser::from_slice(text.as_bytes());
        let mut out = Vec::new();
        while let Some(t) = p.next()? {
            out.push(format!("{t:?}"));
        }
        Ok(out)
    }

    #[test]
    fn tokenizes_a_nested_document() {
        let toks = tokens(r#"{"a": [1, true, null], "b": "x"}"#).unwrap();
        assert_eq!(
            toks,
            vec![
                "BeginObj",
                "Key(\"a\")",
                "BeginArr",
                "Num(1.0)",
                "Bool(true)",
                "Null",
                "EndArr",
                "Key(\"b\")",
                "Str(\"x\")",
                "EndObj",
            ]
        );
    }

    #[test]
    fn empty_containers_and_scalar_roots() {
        assert_eq!(tokens("[]").unwrap(), vec!["BeginArr", "EndArr"]);
        assert_eq!(tokens("{}").unwrap(), vec!["BeginObj", "EndObj"]);
        assert_eq!(tokens(" 42 ").unwrap(), vec!["Num(42.0)"]);
    }

    #[test]
    fn rejects_structural_garbage() {
        for bad in ["", "[1,]", "{\"a\":1,}", "[1 2]", "{\"a\" 1}", "1 2", "[}", "{]"] {
            assert!(tokens(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn depth_cap_is_exact() {
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(tokens(&ok).is_ok());
        let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        let err = tokens(&deep).unwrap_err();
        assert!(err.msg.contains("too deep"), "{err}");
    }

    #[test]
    fn surrogate_pairs_decode_and_lone_ones_reject() {
        assert_eq!(
            tokens(r#""\ud83d\ude00""#).unwrap(),
            vec!["Str(\"\u{1F600}\")"]
        );
        assert!(tokens(r#""\ud83d""#).is_err());
        assert!(tokens(r#""\ude00""#).is_err());
        assert!(tokens(r#""\ud83dx""#).is_err());
        assert!(tokens(r#""\ud83d\n""#).is_err());
    }

    #[test]
    fn resident_bytes_is_bounded_by_chunk_plus_scratch() {
        let doc = format!("[{}]", (0..10_000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let mut p = PullParser::from_slice(doc.as_bytes());
        let mut peak = 0;
        loop {
            let more = p.next().unwrap().is_some();
            peak = peak.max(p.resident_bytes());
            if !more {
                break;
            }
        }
        assert!(peak < 2 * CHUNK, "resident {peak} should be ~one chunk");
        assert_eq!(p.offset(), doc.len());
    }

    #[test]
    fn reset_document_streams_jsonl() {
        let src = "{\"a\": 1}\n{\"a\": 2}\n";
        let mut p = PullParser::new(src.as_bytes());
        let mut roots = 0;
        while !p.at_eof().unwrap() {
            if roots > 0 {
                p.reset_document();
            }
            while let Some(t) = p.next().unwrap() {
                if t == Token::EndObj {
                    break;
                }
            }
            roots += 1;
        }
        assert_eq!(roots, 2);
    }
}
