//! Typed experiment configuration: TOML document -> [`RunConfig`], with
//! validation. This is the launcher's config schema:
//!
//! ```toml
//! scheduler = "bayes"          # fifo|fair|capacity|bayes|bayes-xla|...
//! seed = 1
//!
//! [cluster]
//! nodes = 40
//! racks = 4
//!
//! [workload]
//! n_jobs = 200
//! arrival_rate = 0.5
//! n_users = 8
//! mix = "balanced"             # balanced | cpu_heavy | ... | cpu:<frac>
//!
//! [bayes]
//! alpha = 1.0
//! starvation_wait = false
//!
//! [overload]
//! cpu = 0.9
//! mem = 0.9
//! slowdown = 1.5
//!
//! [heartbeat]
//! interval = 3.0
//! ```

use crate::errors::{anyhow, Result};

use crate::bayes::overload::OverloadRule;
use crate::cluster::heartbeat::HeartbeatConfig;
use crate::coordinator::builder::RunConfig;
use crate::coordinator::jobtracker::TrackerConfig;
use crate::job::profile::JobClass;
use crate::workload::generator::{Mix, WorkloadConfig};

use super::toml::{parse, TomlDoc};

/// Parse + validate a config file's text.
pub fn run_config_from_toml(text: &str) -> Result<RunConfig> {
    let doc = parse(text).map_err(|e| anyhow!("{e}"))?;
    run_config_from_doc(&doc)
}

/// Load from a path.
pub fn load_run_config(path: &std::path::Path) -> Result<RunConfig> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading config {path:?}: {e}"))?;
    run_config_from_toml(&text)
}

fn parse_mix(s: &str) -> Result<Mix> {
    if s == "balanced" {
        return Ok(Mix::balanced());
    }
    if let Some(frac) = s.strip_prefix("cpu:") {
        let f: f64 = frac
            .parse()
            .map_err(|_| anyhow!("bad cpu fraction in mix '{s}'"))?;
        if !(0.0..=1.0).contains(&f) {
            return Err(anyhow!("cpu fraction must be in [0,1], got {f}"));
        }
        return Ok(Mix::cpu_fraction(f));
    }
    JobClass::from_name(s)
        .map(Mix::only)
        .ok_or_else(|| anyhow!("unknown mix '{s}'"))
}

fn run_config_from_doc(doc: &TomlDoc) -> Result<RunConfig> {
    let d = RunConfig::default();
    let seed = doc.i64_or("seed", 1) as u64;
    let scheduler = doc.str_or("scheduler", &d.scheduler).to_string();

    let n_nodes = doc.i64_or("cluster.nodes", d.n_nodes as i64);
    let n_racks = doc.i64_or("cluster.racks", d.n_racks as i64);
    if n_nodes < 1 || n_racks < 1 {
        return Err(anyhow!("cluster.nodes and cluster.racks must be >= 1"));
    }

    let n_jobs = doc.i64_or("workload.n_jobs", 200);
    let arrival_rate = doc.f64_or("workload.arrival_rate", 0.5);
    if n_jobs < 1 || arrival_rate <= 0.0 {
        return Err(anyhow!("workload.n_jobs >= 1 and arrival_rate > 0 required"));
    }
    let workload = WorkloadConfig {
        n_jobs: n_jobs as usize,
        arrival_rate,
        mix: parse_mix(doc.str_or("workload.mix", "balanced"))?,
        n_users: doc.i64_or("workload.n_users", 8).max(1) as usize,
        seed,
    };

    let overload_rule = OverloadRule {
        cpu_threshold: doc.f64_or("overload.cpu", 0.90),
        mem_threshold: doc.f64_or("overload.mem", 0.90),
        io_threshold: doc.f64_or("overload.io", 0.95),
        net_threshold: doc.f64_or("overload.net", 0.95),
        slowdown_threshold: doc.f64_or("overload.slowdown", 1.5),
    };
    let heartbeat =
        HeartbeatConfig { interval: doc.f64_or("heartbeat.interval", 3.0) };
    if heartbeat.interval <= 0.0 {
        return Err(anyhow!("heartbeat.interval must be > 0"));
    }

    let alpha = doc.f64_or("bayes.alpha", 1.0);
    if alpha <= 0.0 {
        return Err(anyhow!("bayes.alpha must be > 0"));
    }

    Ok(RunConfig {
        scheduler,
        n_nodes: n_nodes as u32,
        n_racks: n_racks as u32,
        workload,
        tracker: TrackerConfig {
            heartbeat,
            overload_rule,
            failures: crate::coordinator::jobtracker::FailureConfig {
                mtbf: {
                    let v = doc.f64_or("failures.mtbf", 0.0);
                    (v > 0.0).then_some(v)
                },
                mttr: doc.f64_or("failures.mttr", 120.0),
            },
            timeline_interval: doc.f64_or("tracker.timeline_interval", 0.0),
            oom_kill_delay: doc.f64_or("tracker.oom_kill_delay", 4.0),
            max_task_attempts: doc.i64_or("tracker.max_task_attempts", 4) as u32,
            max_sim_time: doc.f64_or("tracker.max_sim_time", 1e7),
        },
        alpha: alpha as f32,
        starvation_wait: doc.bool_or("bayes.starvation_wait", false),
        artifacts_dir: doc
            .get("bayes.artifacts_dir")
            .and_then(|v| v.as_str())
            .map(std::path::PathBuf::from),
        model_path: doc
            .get("bayes.model_path")
            .and_then(|v| v.as_str())
            .map(std::path::PathBuf::from),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty_doc() {
        let cfg = run_config_from_toml("").unwrap();
        assert_eq!(cfg.scheduler, "bayes");
        assert_eq!(cfg.n_nodes, 40);
        assert_eq!(cfg.workload.n_jobs, 200);
    }

    #[test]
    fn full_document() {
        let cfg = run_config_from_toml(
            r#"
scheduler = "fifo"
seed = 9
[cluster]
nodes = 10
racks = 2
[workload]
n_jobs = 50
arrival_rate = 1.5
mix = "cpu_heavy"
[overload]
cpu = 0.8
[heartbeat]
interval = 2.0
[bayes]
alpha = 0.5
starvation_wait = true
"#,
        )
        .unwrap();
        assert_eq!(cfg.scheduler, "fifo");
        assert_eq!(cfg.workload.seed, 9);
        assert_eq!(cfg.n_nodes, 10);
        assert_eq!(cfg.workload.arrival_rate, 1.5);
        assert_eq!(cfg.tracker.overload_rule.cpu_threshold, 0.8);
        assert_eq!(cfg.tracker.heartbeat.interval, 2.0);
        assert_eq!(cfg.alpha, 0.5);
        assert!(cfg.starvation_wait);
    }

    #[test]
    fn cpu_fraction_mix() {
        let cfg =
            run_config_from_toml("[workload]\nmix = \"cpu:0.75\"\n").unwrap();
        let w: f64 = cfg.workload.mix.0.iter().map(|(_, w)| w).sum();
        assert!((w - 1.0).abs() < 1e-9);
    }

    #[test]
    fn validation_errors() {
        assert!(run_config_from_toml("[cluster]\nnodes = 0\n").is_err());
        assert!(run_config_from_toml("[workload]\narrival_rate = -1\n").is_err());
        assert!(run_config_from_toml("[workload]\nmix = \"bogus\"\n").is_err());
        assert!(run_config_from_toml("[bayes]\nalpha = 0\n").is_err());
        assert!(run_config_from_toml("[heartbeat]\ninterval = 0\n").is_err());
        assert!(run_config_from_toml("[workload]\nmix = \"cpu:1.5\"\n").is_err());
    }
}
