//! Project-specific static analysis (`repro lint`) and the SchedEvent
//! protocol auditor.
//!
//! Three layers:
//! * [`source`] — hand-rolled lints over the repo's own sources (registry
//!   hygiene, N_FEATURES sync, scheduler coverage, forbidden patterns,
//!   experiment numbering, bench-baseline schema). See LINTS.md.
//! * [`protocol`] — a state-machine checker for the normative SchedEvent
//!   lifecycle (rules R1..R8, `scheduler/api.rs` module docs): runs over
//!   recorded traces, inline as a debug-build shadow auditor in both
//!   drivers, and inside the churn conformance sweep below.
//! * [`trace`] — JSONL serialisation of audit-event streams
//!   (`repro run --record-events`, `repro lint --trace`).

pub mod protocol;
pub mod source;
pub mod trace;

use crate::cluster::Cluster;
use crate::coordinator::jobtracker::{
    FailureConfig, JobTracker, TrackerConfig,
};
use crate::errors::{anyhow, Result};
use crate::workload::generator::{generate, WorkloadConfig};
use crate::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

use protocol::{audit_stream, AuditEvent, AuditSink, Violation};

/// One audited fail/recover-churn simulation: which driver and scheduler
/// ran, the full recorded event stream, and every protocol violation the
/// replay auditor found (including end-of-stream drain checks).
pub struct ChurnReport {
    pub driver: &'static str,
    pub scheduler: String,
    pub events: Vec<AuditEvent>,
    pub violations: Vec<Violation>,
}

/// Churn workload: small but busy enough to exercise OOM kills,
/// speculative backups, node failures and recoveries.
fn churn_specs(seed: u64) -> Vec<crate::job::job::JobSpec> {
    generate(&WorkloadConfig {
        n_jobs: 12,
        arrival_rate: 1.0,
        seed,
        ..Default::default()
    })
}

const CHURN_MTBF: f64 = 220.0;
const CHURN_MTTR: f64 = 35.0;

/// Run one scheduler under the MRv1 JobTracker with failure injection and
/// a recording audit sink; replay the stream through a fresh auditor.
pub fn audited_mrv1_run(name: &str, seed: u64) -> Result<ChurnReport> {
    let sched = crate::scheduler::by_name(name, seed)
        .ok_or_else(|| anyhow!("unknown scheduler '{name}'"))?;
    let cfg = TrackerConfig {
        failures: FailureConfig { mtbf: Some(CHURN_MTBF), mttr: CHURN_MTTR },
        ..Default::default()
    };
    let cluster = Cluster::homogeneous(6, 2);
    let mut jt = JobTracker::new(cluster, sched, churn_specs(seed), seed, cfg);
    jt.set_audit(AuditSink::recording());
    jt.run();
    let events = jt.audit.take_recording();
    let violations = audit_stream(&events);
    Ok(ChurnReport {
        driver: "mrv1",
        scheduler: name.to_string(),
        events,
        violations,
    })
}

/// Same as [`audited_mrv1_run`] but under the YARN ResourceManager.
pub fn audited_yarn_run(name: &str, seed: u64) -> Result<ChurnReport> {
    let policy = yarn_policy_by_name(name, 1.0)?;
    let cfg = YarnConfig {
        failures: FailureConfig { mtbf: Some(CHURN_MTBF), mttr: CHURN_MTTR },
        ..Default::default()
    };
    let cluster = Cluster::homogeneous(6, 2);
    let mut rm =
        ResourceManager::new(cluster, policy, churn_specs(seed), seed, cfg);
    rm.set_audit(AuditSink::recording());
    rm.run();
    let events = rm.audit.take_recording();
    let violations = audit_stream(&events);
    Ok(ChurnReport {
        driver: "yarn",
        scheduler: name.to_string(),
        events,
        violations,
    })
}

/// The conformance sweep behind `repro lint`: every `by_name` scheduler
/// through fail/recover churn under BOTH drivers, fully audited.
pub fn audit_all_schedulers(seed: u64) -> Result<Vec<ChurnReport>> {
    let mut out = Vec::new();
    for name in crate::scheduler::ALL_NAMES {
        out.push(audited_mrv1_run(name, seed)?);
        out.push(audited_yarn_run(name, seed)?);
    }
    Ok(out)
}

#[cfg(test)]
mod conformance {
    use super::*;

    /// Every scheduler, both drivers, failure churn: zero protocol
    /// violations end to end. This is the live half of the tentpole — the
    /// broken-fixture tests in `protocol::tests` prove each rule CAN fire;
    /// this proves the real drivers never make them fire.
    #[test]
    fn every_scheduler_survives_churn_audit_under_both_drivers() {
        for rep in audit_all_schedulers(7).unwrap() {
            assert!(
                rep.violations.is_empty(),
                "{}/{}: {:?}",
                rep.driver,
                rep.scheduler,
                rep.violations
            );
            assert!(
                rep.events.len() > 100,
                "{}/{} recorded suspiciously few events ({})",
                rep.driver,
                rep.scheduler,
                rep.events.len()
            );
        }
    }

    /// The recorded stream must survive a JSONL round-trip and still audit
    /// clean — the exact path `repro run --record-events` + `repro lint
    /// --trace` takes.
    #[test]
    fn recorded_stream_roundtrips_and_audits_clean() {
        let rep = audited_mrv1_run("bayes", 11).unwrap();
        let text = trace::to_jsonl(&rep.events);
        let back = trace::from_jsonl(&text).unwrap();
        assert_eq!(back, rep.events);
        assert!(audit_stream(&back).is_empty());
    }

    /// Churn must actually churn: the audited runs see failures, else the
    /// sweep proves nothing about rules R6..R8.
    #[test]
    fn churn_runs_exercise_failures() {
        let rep = audited_mrv1_run("fifo", 7).unwrap();
        let failed_nodes = rep
            .events
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    AuditEvent::Sched(
                        crate::scheduler::api::SchedEvent::NodeFailed { .. }
                    )
                )
            })
            .count();
        assert!(failed_nodes > 0, "no node failures in churn workload");
    }
}
