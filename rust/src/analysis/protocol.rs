//! The SchedEvent protocol auditor: a state-machine checker that validates
//! an event stream against the lifecycle contract documented in
//! `scheduler/api.rs` (the normative state table). The ATLAS line of work
//! (arXiv 1511.01446, 1507.03562) shows that a learned scheduler degrades
//! silently when the rows it scores at decision time drift from the rows it
//! learns from at feedback time — so besides the lifecycle rules, the
//! auditor carries a train/serve skew check: every `Feedback` row must be
//! bit-identical to a row some placement was actually scored on.
//!
//! The auditor consumes [`AuditEvent`]s: the scheduler-visible
//! [`SchedEvent`] stream plus the driver-side context the stream alone
//! cannot carry (node slot capacities, job arrivals, per-attempt launch and
//! end records with task identity). Drivers produce the full audit stream
//! through [`AuditSink`]; recorded streams round-trip through
//! [`crate::analysis::trace`] for offline auditing (`repro lint --trace`).
//!
//! Three modes (ISSUE 6):
//! * offline — replay a recorded trace through [`ProtocolAuditor::observe`]
//! * shadow — drivers attach [`AuditSink::shadow`] in debug builds and
//!   panic on the first violation, so every debug test run audits itself
//! * conformance — [`crate::analysis::audit_all_schedulers`] drives every
//!   `by_name` scheduler through fail/recover churn with a recording sink
//!   and replays the streams (the sweep behind `repro lint`)

use std::collections::BTreeMap;

use crate::bayes::features::FeatureVec;
use crate::cluster::node::NodeId;
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;
use crate::scheduler::api::SchedEvent;

/// One audited event: the scheduler-visible stream plus driver context.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AuditEvent {
    /// A node exists with these typed slot capacities (sent once per node
    /// before any other event, like the driver's construction preamble).
    NodeSpec { node: NodeId, maps: u32, reduces: u32 },
    /// The driver admitted `job` to the job table.
    JobArrived { job: JobId },
    /// The driver launched one attempt of `task` on `node`, scored on
    /// `feats` (the decision row the skew check matches feedback against).
    Launched {
        task: TaskRef,
        node: NodeId,
        speculative: bool,
        feats: FeatureVec,
    },
    /// The attempt of `task` running on `node` left the node (completed,
    /// failed, or was cancelled) — emitted before the paired
    /// `TaskFinished`/`TaskFailed` scheduler event.
    Ended { task: TaskRef, node: NodeId },
    /// One event of the scheduler-visible stream.
    Sched(SchedEvent),
}

/// The lifecycle rules the auditor enforces. `R<n>` ids match the
/// normative state table in the `scheduler/api.rs` module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// R1: no task event before its job arrived (or after it completed).
    StartBeforeArrival,
    /// R2: per-(node, kind) running attempts never exceed the node's slot
    /// capacity — the cumulative form of the `SlotBudget` batch contract.
    SlotOvercommit,
    /// R3: a task never has two live attempts of the same role, and a
    /// regular launch requires the task to have no live attempt at all.
    DoubleAssign,
    /// R4: a speculative launch requires a live primary on a *different*
    /// node and no live backup; a backup is promoted at most once per
    /// launch (promotion consumes it).
    BadSpeculation,
    /// R5: `JobCompleted` only after the job's last attempt drained.
    CompletedBeforeDrain,
    /// R6: no event for a failed node until its `NodeRecovered`; fail/
    /// recover strictly alternate per node.
    DeadNodeEvent,
    /// R7: every attempt end pairs with a live attempt (no end without a
    /// start, no stale duplicate ends).
    EndWithoutStart,
    /// R8: every `Feedback` row is bit-identical to a row some placement
    /// was scored on (train/serve skew).
    TrainServeSkew,
    /// Stream-shape errors: unknown node, duplicate arrival, events after
    /// the audited run was finished.
    Malformed,
}

impl Rule {
    pub fn name(&self) -> &'static str {
        match self {
            Rule::StartBeforeArrival => "start-before-arrival",
            Rule::SlotOvercommit => "slot-overcommit",
            Rule::DoubleAssign => "double-assign",
            Rule::BadSpeculation => "bad-speculation",
            Rule::CompletedBeforeDrain => "completed-before-drain",
            Rule::DeadNodeEvent => "dead-node-event",
            Rule::EndWithoutStart => "end-without-start",
            Rule::TrainServeSkew => "train-serve-skew",
            Rule::Malformed => "malformed-stream",
        }
    }
}

/// One contract violation: which rule, at which event index, and what
/// happened.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: Rule,
    /// 0-based index of the offending event in the audited stream.
    pub index: u64,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] event #{}: {}", self.rule.name(), self.index, self.detail)
    }
}

#[derive(Debug, Clone, Copy)]
struct NodeState {
    maps: u32,
    reduces: u32,
    alive: bool,
    running_maps: u32,
    running_reduces: u32,
}

/// Live attempts of one task: where the primary runs, and where the backup
/// (speculative copy) runs, if any.
#[derive(Debug, Clone, Copy)]
struct Attempt {
    primary: NodeId,
    backup: Option<NodeId>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobPhase {
    Arrived,
    Completed,
}

/// The state machine. Feed it the full audit stream in order; collect
/// [`Violation`]s at any point. The checker never panics on bad input —
/// every contract breach becomes a `Violation` (panicking is the
/// [`AuditSink::shadow`] wrapper's job).
#[derive(Debug, Default)]
pub struct ProtocolAuditor {
    nodes: BTreeMap<NodeId, NodeState>,
    jobs: BTreeMap<JobId, JobPhase>,
    /// Live attempts keyed by task.
    attempts: BTreeMap<TaskRef, Attempt>,
    /// Live attempts per job as seen through the SchedEvent stream
    /// (TaskStarted minus TaskFinished/TaskFailed) — must agree with
    /// `attempts` at JobCompleted.
    started: BTreeMap<JobId, i64>,
    /// Multiset of decision rows placements were scored on. Feedback rows
    /// must be members (never retired: a row may feed back twice — the
    /// overload verdict plus an OOM `Bad` sample).
    scored: BTreeMap<FeatureVec, u64>,
    violations: Vec<Violation>,
    seen: u64,
}

impl ProtocolAuditor {
    pub fn new() -> ProtocolAuditor {
        ProtocolAuditor::default()
    }

    pub fn violations(&self) -> &[Violation] {
        &self.violations
    }

    /// Events observed so far.
    pub fn events_seen(&self) -> u64 {
        self.seen
    }

    /// Violations recorded so far (cheap check for shadow mode).
    pub fn violation_count(&self) -> usize {
        self.violations.len()
    }

    fn fail(&mut self, rule: Rule, detail: String) {
        // the offending event is the one currently being observed
        let index = self.seen.saturating_sub(1);
        self.violations.push(Violation { rule, index, detail });
    }

    /// Feed one event. Order matters; call in stream order.
    pub fn observe(&mut self, ev: &AuditEvent) {
        self.seen += 1;
        match *ev {
            AuditEvent::NodeSpec { node, maps, reduces } => {
                let st = NodeState {
                    maps,
                    reduces,
                    alive: true,
                    running_maps: 0,
                    running_reduces: 0,
                };
                if self.nodes.insert(node, st).is_some() {
                    self.fail(Rule::Malformed, format!("duplicate NodeSpec for {node}"));
                }
            }
            AuditEvent::JobArrived { job } => {
                if self.jobs.insert(job, JobPhase::Arrived).is_some() {
                    self.fail(Rule::Malformed, format!("duplicate arrival of {job}"));
                }
            }
            AuditEvent::Launched { task, node, speculative, feats } => {
                self.on_launched(task, node, speculative, feats)
            }
            AuditEvent::Ended { task, node } => self.on_ended(task, node),
            AuditEvent::Sched(ref sev) => self.on_sched(sev),
        }
    }

    fn require_job_live(&mut self, job: JobId, what: &str) {
        match self.jobs.get(&job) {
            Some(JobPhase::Arrived) => {}
            Some(JobPhase::Completed) => self.fail(
                Rule::StartBeforeArrival,
                format!("{what} for {job} after its JobCompleted"),
            ),
            None => self.fail(
                Rule::StartBeforeArrival,
                format!("{what} for {job} before its arrival"),
            ),
        }
    }

    fn require_node_alive(&mut self, node: NodeId, what: &str) {
        match self.nodes.get(&node) {
            Some(st) if st.alive => {}
            Some(_) => self.fail(
                Rule::DeadNodeEvent,
                format!("{what} on {node} while it is failed"),
            ),
            None => {
                self.fail(Rule::Malformed, format!("{what} on unknown {node}"))
            }
        }
    }

    fn on_launched(
        &mut self,
        task: TaskRef,
        node: NodeId,
        speculative: bool,
        feats: FeatureVec,
    ) {
        self.require_job_live(task.job, "attempt launch");
        self.require_node_alive(node, "attempt launch");
        *self.scored.entry(feats).or_insert(0) += 1;

        // typed-slot accounting (R2): count on launch, release on end
        if let Some(st) = self.nodes.get_mut(&node) {
            let (running, cap) = match task.kind {
                TaskKind::Map => (&mut st.running_maps, st.maps),
                TaskKind::Reduce => (&mut st.running_reduces, st.reduces),
            };
            *running += 1;
            if *running > cap {
                let n = *running;
                self.fail(
                    Rule::SlotOvercommit,
                    format!(
                        "{node} runs {n} {:?} attempts but has {cap} slots \
                         (launching {task})",
                        task.kind
                    ),
                );
            }
        }

        match (speculative, self.attempts.get(&task).copied()) {
            (false, None) => {
                self.attempts.insert(task, Attempt { primary: node, backup: None });
            }
            (false, Some(a)) => self.fail(
                Rule::DoubleAssign,
                format!(
                    "regular launch of {task} on {node} but it already runs \
                     on {}",
                    a.primary
                ),
            ),
            (true, Some(a)) if a.backup.is_none() && a.primary != node => {
                self.attempts
                    .insert(task, Attempt { primary: a.primary, backup: Some(node) });
            }
            (true, Some(a)) if a.backup.is_some() => self.fail(
                Rule::BadSpeculation,
                format!("{task} already has a live backup; second copy on {node}"),
            ),
            (true, Some(_)) => self.fail(
                Rule::BadSpeculation,
                format!("speculative copy of {task} on its own primary {node}"),
            ),
            (true, None) => self.fail(
                Rule::BadSpeculation,
                format!("speculative launch of {task} with no running primary"),
            ),
        }
    }

    fn on_ended(&mut self, task: TaskRef, node: NodeId) {
        if let Some(st) = self.nodes.get_mut(&node) {
            let running = match task.kind {
                TaskKind::Map => &mut st.running_maps,
                TaskKind::Reduce => &mut st.running_reduces,
            };
            *running = running.saturating_sub(1);
        }
        match self.attempts.get(&task).copied() {
            Some(a) if a.backup == Some(node) => {
                // the backup ended; the primary keeps running
                self.attempts
                    .insert(task, Attempt { primary: a.primary, backup: None });
            }
            Some(a) if a.primary == node => match a.backup {
                // the primary ended with a live backup: promotion (R4) —
                // the backup becomes the new primary, consuming it
                Some(b) => {
                    self.attempts.insert(task, Attempt { primary: b, backup: None });
                }
                None => {
                    self.attempts.remove(&task);
                }
            },
            Some(a) => self.fail(
                Rule::EndWithoutStart,
                format!(
                    "end of {task} on {node}, but its attempts run on {} \
                     (backup {:?})",
                    a.primary, a.backup
                ),
            ),
            None => self.fail(
                Rule::EndWithoutStart,
                format!("end of {task} on {node} with no live attempt"),
            ),
        }
    }

    fn on_sched(&mut self, ev: &SchedEvent) {
        match *ev {
            SchedEvent::ClusterInfo { total_slots } => {
                if !self.nodes.is_empty() {
                    let declared: u32 =
                        self.nodes.values().map(|n| n.maps + n.reduces).sum();
                    if declared != total_slots {
                        self.fail(
                            Rule::Malformed,
                            format!(
                                "ClusterInfo says {total_slots} slots but \
                                 NodeSpecs sum to {declared}"
                            ),
                        );
                    }
                }
            }
            SchedEvent::Feedback { feats, .. } => {
                if self.scored.get(&feats).copied().unwrap_or(0) == 0 {
                    self.fail(
                        Rule::TrainServeSkew,
                        format!(
                            "feedback row {feats:?} was never a decision row \
                             — decision-time and feedback-time features drifted"
                        ),
                    );
                }
            }
            SchedEvent::TaskStarted { job, node, .. } => {
                self.require_job_live(job, "TaskStarted");
                self.require_node_alive(node, "TaskStarted");
                *self.started.entry(job).or_insert(0) += 1;
            }
            SchedEvent::TaskFinished { job, node, .. }
            | SchedEvent::TaskFailed { job, node, .. } => {
                self.require_job_live(job, "attempt-end event");
                self.require_node_alive(node, "attempt-end event");
                let live = self.started.entry(job).or_insert(0);
                *live -= 1;
                if *live < 0 {
                    self.fail(
                        Rule::EndWithoutStart,
                        format!("attempt-end event for {job} with none started"),
                    );
                }
            }
            SchedEvent::JobCompleted { job } => {
                match self.jobs.get(&job) {
                    Some(JobPhase::Arrived) => {}
                    Some(JobPhase::Completed) => self.fail(
                        Rule::Malformed,
                        format!("duplicate JobCompleted for {job}"),
                    ),
                    None => self.fail(
                        Rule::StartBeforeArrival,
                        format!("JobCompleted for {job} before its arrival"),
                    ),
                }
                let live_events = self.started.get(&job).copied().unwrap_or(0);
                let live_attempts =
                    self.attempts.keys().filter(|t| t.job == job).count();
                if live_events != 0 || live_attempts != 0 {
                    self.fail(
                        Rule::CompletedBeforeDrain,
                        format!(
                            "JobCompleted for {job} with {live_attempts} live \
                             attempts ({live_events} by event count)"
                        ),
                    );
                }
                self.jobs.insert(job, JobPhase::Completed);
                self.started.remove(&job);
            }
            SchedEvent::NodeFailed { node } => match self.nodes.get_mut(&node) {
                Some(st) if st.alive => {
                    st.alive = false;
                    let stranded = st.running_maps + st.running_reduces;
                    if stranded > 0 {
                        self.fail(
                            Rule::DeadNodeEvent,
                            format!(
                                "NodeFailed for {node} before its {stranded} \
                                 running attempts were reported lost"
                            ),
                        );
                    }
                }
                Some(_) => self.fail(
                    Rule::DeadNodeEvent,
                    format!("NodeFailed for already-failed {node}"),
                ),
                None => {
                    self.fail(Rule::Malformed, format!("NodeFailed for unknown {node}"))
                }
            },
            SchedEvent::NodeRecovered { node } => match self.nodes.get_mut(&node) {
                Some(st) if !st.alive => st.alive = true,
                Some(_) => self.fail(
                    Rule::DeadNodeEvent,
                    format!("NodeRecovered for {node} which never failed"),
                ),
                None => self.fail(
                    Rule::Malformed,
                    format!("NodeRecovered for unknown {node}"),
                ),
            },
        }
    }

    /// End-of-run checks for complete recorded traces: every attempt must
    /// have drained and every arrived job completed. Do NOT call this from
    /// shadow mode (a shadow audit can stop mid-run).
    pub fn finish(&mut self) {
        let leftovers: Vec<String> =
            self.attempts.keys().map(|t| t.to_string()).collect();
        if !leftovers.is_empty() {
            self.seen += 1;
            self.fail(
                Rule::CompletedBeforeDrain,
                format!("stream ended with live attempts: {}", leftovers.join(", ")),
            );
        }
        let undone: Vec<String> = self
            .jobs
            .iter()
            .filter(|(_, p)| **p == JobPhase::Arrived)
            .map(|(j, _)| j.to_string())
            .collect();
        if !undone.is_empty() {
            self.seen += 1;
            self.fail(
                Rule::CompletedBeforeDrain,
                format!("stream ended with unfinished jobs: {}", undone.join(", ")),
            );
        }
    }
}

/// The driver-side fan-out: forwards every audit event to an optional
/// [`ProtocolAuditor`] (panicking on violations when in shadow mode) and an
/// optional recording buffer (for `repro run --record-events`).
#[derive(Debug, Default)]
pub struct AuditSink {
    auditor: Option<ProtocolAuditor>,
    recording: Option<Vec<AuditEvent>>,
    panic_on_violation: bool,
}

impl AuditSink {
    /// No auditing, no recording: every call is a no-op.
    pub fn disabled() -> AuditSink {
        AuditSink::default()
    }

    /// The debug-build default: audit inline and panic on the first
    /// violation, so every debug test run checks the protocol for free.
    pub fn shadow() -> AuditSink {
        AuditSink {
            auditor: Some(ProtocolAuditor::new()),
            recording: None,
            panic_on_violation: true,
        }
    }

    /// Audit inline, collecting violations instead of panicking
    /// (conformance tests, `repro lint`).
    pub fn auditing() -> AuditSink {
        AuditSink {
            auditor: Some(ProtocolAuditor::new()),
            recording: None,
            panic_on_violation: false,
        }
    }

    /// Record the stream (and audit it, collecting) for later replay.
    pub fn recording() -> AuditSink {
        AuditSink {
            auditor: Some(ProtocolAuditor::new()),
            recording: Some(Vec::new()),
            panic_on_violation: false,
        }
    }

    /// What drivers attach by default: shadow in debug builds, disabled in
    /// release (zero overhead on the measured paths).
    pub fn default_for_build() -> AuditSink {
        if cfg!(debug_assertions) {
            AuditSink::shadow()
        } else {
            AuditSink::disabled()
        }
    }

    /// True when pushes do something (lets drivers skip building events).
    pub fn enabled(&self) -> bool {
        self.auditor.is_some() || self.recording.is_some()
    }

    /// Feed one event through the sink.
    pub fn push(&mut self, ev: AuditEvent) {
        if let Some(rec) = &mut self.recording {
            rec.push(ev);
        }
        if let Some(aud) = &mut self.auditor {
            let before = aud.violation_count();
            aud.observe(&ev);
            if self.panic_on_violation && aud.violation_count() > before {
                let v = &aud.violations()[before];
                panic!("SchedEvent protocol violation: {v} (on {ev:?})");
            }
        }
    }

    /// Shorthand for pushing a scheduler-visible event.
    pub fn sched(&mut self, ev: &SchedEvent) {
        if self.enabled() {
            self.push(AuditEvent::Sched(*ev));
        }
    }

    /// The inline auditor's violations so far (empty when not auditing).
    pub fn violations(&self) -> &[Violation] {
        self.auditor.as_ref().map(|a| a.violations()).unwrap_or(&[])
    }

    /// Take the recorded stream (empty when not recording).
    pub fn take_recording(&mut self) -> Vec<AuditEvent> {
        self.recording.take().unwrap_or_default()
    }
}

/// Replay a recorded stream through a fresh auditor, including end-of-run
/// checks. Returns all violations.
pub fn audit_stream(events: &[AuditEvent]) -> Vec<Violation> {
    let mut aud = ProtocolAuditor::new();
    for ev in events {
        aud.observe(ev);
    }
    aud.finish();
    aud.violations().to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayes::classifier::Label;
    use crate::bayes::features::N_FEATURES;

    fn node(i: u32) -> NodeId {
        NodeId(i)
    }

    fn job(i: u32) -> JobId {
        JobId::dense(i)
    }

    fn task(j: u32, index: u32) -> TaskRef {
        TaskRef { job: job(j), kind: TaskKind::Map, index }
    }

    fn feats(tag: u8) -> FeatureVec {
        [tag; N_FEATURES]
    }

    /// A minimal healthy preamble: one node, one job.
    fn preamble() -> Vec<AuditEvent> {
        vec![
            AuditEvent::NodeSpec { node: node(0), maps: 2, reduces: 1 },
            AuditEvent::NodeSpec { node: node(1), maps: 2, reduces: 1 },
            AuditEvent::Sched(SchedEvent::ClusterInfo { total_slots: 6 }),
            AuditEvent::JobArrived { job: job(0) },
        ]
    }

    fn launch(t: TaskRef, n: NodeId, tag: u8) -> [AuditEvent; 2] {
        [
            AuditEvent::Launched {
                task: t,
                node: n,
                speculative: false,
                feats: feats(tag),
            },
            AuditEvent::Sched(SchedEvent::TaskStarted {
                job: t.job,
                node: n,
                kind: t.kind,
            }),
        ]
    }

    fn end_ok(t: TaskRef, n: NodeId) -> [AuditEvent; 2] {
        [
            AuditEvent::Ended { task: t, node: n },
            AuditEvent::Sched(SchedEvent::TaskFinished {
                job: t.job,
                node: n,
                kind: t.kind,
            }),
        ]
    }

    fn rules(vs: &[Violation]) -> Vec<Rule> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn clean_lifecycle_has_no_violations() {
        let mut evs = preamble();
        evs.extend(launch(task(0, 0), node(0), 1));
        evs.extend(launch(task(0, 1), node(1), 2));
        evs.push(AuditEvent::Sched(SchedEvent::Feedback {
            feats: feats(1),
            label: Label::Good,
        }));
        evs.extend(end_ok(task(0, 0), node(0)));
        evs.extend(end_ok(task(0, 1), node(1)));
        evs.push(AuditEvent::Sched(SchedEvent::JobCompleted { job: job(0) }));
        let vs = audit_stream(&evs);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn start_before_arrival_fires() {
        let mut evs = vec![AuditEvent::NodeSpec {
            node: node(0),
            maps: 2,
            reduces: 1,
        }];
        evs.extend(launch(task(9, 0), node(0), 1));
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::StartBeforeArrival), "{vs:?}");
    }

    #[test]
    fn slot_overcommit_fires() {
        let mut evs = preamble();
        // node 0 has 2 map slots; launch 3 attempts on it
        evs.extend(launch(task(0, 0), node(0), 1));
        evs.extend(launch(task(0, 1), node(0), 1));
        evs.extend(launch(task(0, 2), node(0), 1));
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::SlotOvercommit), "{vs:?}");
    }

    #[test]
    fn double_assign_fires() {
        let mut evs = preamble();
        evs.extend(launch(task(0, 0), node(0), 1));
        evs.extend(launch(task(0, 0), node(1), 1)); // same task, regular again
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::DoubleAssign), "{vs:?}");
    }

    #[test]
    fn speculation_without_primary_fires() {
        let mut evs = preamble();
        evs.push(AuditEvent::Launched {
            task: task(0, 0),
            node: node(0),
            speculative: true,
            feats: feats(1),
        });
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::BadSpeculation), "{vs:?}");
    }

    #[test]
    fn second_backup_fires() {
        let mut evs = preamble();
        evs.extend(launch(task(0, 0), node(0), 1));
        for n in [1, 1] {
            evs.push(AuditEvent::Launched {
                task: task(0, 0),
                node: node(n),
                speculative: true,
                feats: feats(2),
            });
        }
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::BadSpeculation), "{vs:?}");
    }

    #[test]
    fn backup_promotion_is_legal_exactly_once() {
        let mut evs = preamble();
        let t = task(0, 0);
        evs.extend(launch(t, node(0), 1));
        evs.push(AuditEvent::Launched {
            task: t,
            node: node(1),
            speculative: true,
            feats: feats(2),
        });
        evs.push(AuditEvent::Sched(SchedEvent::TaskStarted {
            job: t.job,
            node: node(1),
            kind: t.kind,
        }));
        // primary dies -> backup promoted in place
        evs.push(AuditEvent::Ended { task: t, node: node(0) });
        evs.push(AuditEvent::Sched(SchedEvent::TaskFailed {
            job: t.job,
            node: node(0),
            kind: t.kind,
            attempt: 1,
            reason: crate::scheduler::api::FailReason::NodeLost,
        }));
        // the promoted attempt completes on node 1
        evs.extend(end_ok(t, node(1)));
        evs.push(AuditEvent::Sched(SchedEvent::JobCompleted { job: job(0) }));
        let vs = audit_stream(&evs);
        assert!(vs.is_empty(), "{vs:?}");

        // but ending it twice on node 1 is an end-without-start
        let mut evs2 = preamble();
        evs2.extend(launch(t, node(0), 1));
        evs2.push(AuditEvent::Ended { task: t, node: node(0) });
        evs2.push(AuditEvent::Ended { task: t, node: node(0) });
        let vs2 = audit_stream(&evs2);
        assert!(rules(&vs2).contains(&Rule::EndWithoutStart), "{vs2:?}");
    }

    #[test]
    fn completed_before_drain_fires() {
        let mut evs = preamble();
        evs.extend(launch(task(0, 0), node(0), 1));
        evs.push(AuditEvent::Sched(SchedEvent::JobCompleted { job: job(0) }));
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::CompletedBeforeDrain), "{vs:?}");
    }

    #[test]
    fn dead_node_event_fires() {
        let mut evs = preamble();
        evs.push(AuditEvent::Sched(SchedEvent::NodeFailed { node: node(0) }));
        evs.extend(launch(task(0, 0), node(0), 1));
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::DeadNodeEvent), "{vs:?}");

        // recovery re-opens the node
        let mut evs2 = preamble();
        evs2.push(AuditEvent::Sched(SchedEvent::NodeFailed { node: node(0) }));
        evs2.push(AuditEvent::Sched(SchedEvent::NodeRecovered { node: node(0) }));
        evs2.extend(launch(task(0, 0), node(0), 1));
        evs2.extend(end_ok(task(0, 0), node(0)));
        evs2.push(AuditEvent::Sched(SchedEvent::JobCompleted { job: job(0) }));
        assert!(audit_stream(&evs2).is_empty());
    }

    #[test]
    fn recover_without_fail_fires() {
        let mut evs = preamble();
        evs.push(AuditEvent::Sched(SchedEvent::NodeRecovered { node: node(0) }));
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::DeadNodeEvent), "{vs:?}");
    }

    #[test]
    fn train_serve_skew_fires_on_foreign_row() {
        let mut evs = preamble();
        evs.extend(launch(task(0, 0), node(0), 1));
        evs.push(AuditEvent::Sched(SchedEvent::Feedback {
            feats: feats(9), // never a decision row
            label: Label::Bad,
        }));
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::TrainServeSkew), "{vs:?}");
    }

    #[test]
    fn oom_double_feedback_of_same_row_is_legal() {
        let mut evs = preamble();
        let t = task(0, 0);
        evs.extend(launch(t, node(0), 3));
        // OOM: the Bad sample reuses the launch row, then the heartbeat
        // verdict delivers the same row again
        evs.push(AuditEvent::Ended { task: t, node: node(0) });
        evs.push(AuditEvent::Sched(SchedEvent::Feedback {
            feats: feats(3),
            label: Label::Bad,
        }));
        evs.push(AuditEvent::Sched(SchedEvent::TaskFailed {
            job: t.job,
            node: node(0),
            kind: t.kind,
            attempt: 1,
            reason: crate::scheduler::api::FailReason::Oom,
        }));
        evs.push(AuditEvent::Sched(SchedEvent::Feedback {
            feats: feats(3),
            label: Label::Bad,
        }));
        // retry elsewhere, drain
        evs.extend(launch(t, node(1), 4));
        evs.extend(end_ok(t, node(1)));
        evs.push(AuditEvent::Sched(SchedEvent::JobCompleted { job: job(0) }));
        let vs = audit_stream(&evs);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn unfinished_stream_fails_finish() {
        let mut evs = preamble();
        evs.extend(launch(task(0, 0), node(0), 1));
        let vs = audit_stream(&evs); // finish() runs inside
        assert!(rules(&vs).contains(&Rule::CompletedBeforeDrain), "{vs:?}");
    }

    #[test]
    fn cluster_info_slot_mismatch_is_malformed() {
        let evs = vec![
            AuditEvent::NodeSpec { node: node(0), maps: 2, reduces: 1 },
            AuditEvent::Sched(SchedEvent::ClusterInfo { total_slots: 99 }),
        ];
        let vs = audit_stream(&evs);
        assert!(rules(&vs).contains(&Rule::Malformed), "{vs:?}");
    }

    #[test]
    fn shadow_sink_panics_on_violation() {
        let result = std::panic::catch_unwind(|| {
            let mut sink = AuditSink::shadow();
            sink.push(AuditEvent::Sched(SchedEvent::NodeRecovered {
                node: node(7),
            }));
        });
        assert!(result.is_err(), "shadow sink must panic on a violation");
    }

    #[test]
    fn recording_sink_captures_stream() {
        let mut sink = AuditSink::recording();
        let evs = preamble();
        for ev in &evs {
            sink.push(*ev);
        }
        assert_eq!(sink.take_recording().len(), evs.len());
    }
}
