//! JSONL (de)serialization of audit-event streams, for
//! `repro run --record-events FILE` / `repro lint --trace FILE`.
//!
//! Format: one JSON object per line, `{"ev": "<tag>", ...fields}`. Feature
//! vectors serialize as arrays of bin indices. The format is versioned by
//! the header line `{"ev": "trace", "version": 2, "n_features": N}` so a
//! replay against a binary with a different feature width fails loudly
//! instead of mis-auditing.
//!
//! Version 2: generational job ids — `"job"` carries the serial
//! (submission number) and the `"slot"` field carries the arena slot, so
//! replays reconstruct the exact handles of runs with slot reclamation on.

use std::collections::BTreeMap;

use crate::bayes::classifier::Label;
use crate::bayes::features::{FeatureVec, N_FEATURES};
use crate::cluster::node::NodeId;
use crate::config::json::Json;
use crate::errors::{Context, Result};
use crate::job::task::{TaskKind, TaskRef};
use crate::job::JobId;
use crate::scheduler::api::{FailReason, SchedEvent};

use super::protocol::AuditEvent;

pub const TRACE_VERSION: u64 = 2;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(n: impl Into<f64>) -> Json {
    Json::Num(n.into())
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn kind_str(k: TaskKind) -> &'static str {
    match k {
        TaskKind::Map => "map",
        TaskKind::Reduce => "reduce",
    }
}

fn feats_json(f: &FeatureVec) -> Json {
    Json::Arr(f.iter().map(|b| num(*b as f64)).collect())
}

fn job_fields(j: JobId) -> [(&'static str, Json); 2] {
    [("job", num(j.serial)), ("slot", num(j.slot))]
}

fn task_fields(t: TaskRef) -> Vec<(&'static str, Json)> {
    let mut fields: Vec<(&'static str, Json)> = job_fields(t.job).into();
    fields.push(("kind", s(kind_str(t.kind))));
    fields.push(("index", num(t.index)));
    fields
}

/// Serialize one audit event to a single-line JSON object.
pub fn event_to_json(ev: &AuditEvent) -> Json {
    match *ev {
        AuditEvent::NodeSpec { node, maps, reduces } => obj(vec![
            ("ev", s("node_spec")),
            ("node", num(node.0)),
            ("maps", num(maps)),
            ("reduces", num(reduces)),
        ]),
        AuditEvent::JobArrived { job } => {
            let mut fields = vec![("ev", s("job_arrived"))];
            fields.extend(job_fields(job));
            obj(fields)
        }
        AuditEvent::Launched { task, node, speculative, feats } => {
            let mut fields = vec![("ev", s("launched"))];
            fields.extend(task_fields(task));
            fields.push(("node", num(node.0)));
            fields.push(("speculative", Json::Bool(speculative)));
            fields.push(("feats", feats_json(&feats)));
            obj(fields)
        }
        AuditEvent::Ended { task, node } => {
            let mut fields = vec![("ev", s("ended"))];
            fields.extend(task_fields(task));
            fields.push(("node", num(node.0)));
            obj(fields)
        }
        AuditEvent::Sched(ref sev) => sched_to_json(sev),
    }
}

fn sched_to_json(ev: &SchedEvent) -> Json {
    match *ev {
        SchedEvent::ClusterInfo { total_slots } => obj(vec![
            ("ev", s("cluster_info")),
            ("total_slots", num(total_slots)),
        ]),
        SchedEvent::Feedback { feats, label } => obj(vec![
            ("ev", s("feedback")),
            ("feats", feats_json(&feats)),
            ("label", s(if label == Label::Good { "good" } else { "bad" })),
        ]),
        SchedEvent::TaskStarted { job, node, kind } => {
            let mut fields = vec![("ev", s("task_started"))];
            fields.extend(job_fields(job));
            fields.push(("node", num(node.0)));
            fields.push(("kind", s(kind_str(kind))));
            obj(fields)
        }
        SchedEvent::TaskFinished { job, node, kind } => {
            let mut fields = vec![("ev", s("task_finished"))];
            fields.extend(job_fields(job));
            fields.push(("node", num(node.0)));
            fields.push(("kind", s(kind_str(kind))));
            obj(fields)
        }
        SchedEvent::TaskFailed { job, node, kind, attempt, reason } => {
            let mut fields = vec![("ev", s("task_failed"))];
            fields.extend(job_fields(job));
            fields.push(("node", num(node.0)));
            fields.push(("kind", s(kind_str(kind))));
            fields.push(("attempt", num(attempt)));
            fields.push((
                "reason",
                s(match reason {
                    FailReason::Oom => "oom",
                    FailReason::NodeLost => "node_lost",
                }),
            ));
            obj(fields)
        }
        SchedEvent::JobCompleted { job } => {
            let mut fields = vec![("ev", s("job_completed"))];
            fields.extend(job_fields(job));
            obj(fields)
        }
        SchedEvent::NodeFailed { node } => {
            obj(vec![("ev", s("node_failed")), ("node", num(node.0))])
        }
        SchedEvent::NodeRecovered { node } => {
            obj(vec![("ev", s("node_recovered")), ("node", num(node.0))])
        }
    }
}

/// Serialize a stream to JSONL text (header line + one line per event).
pub fn to_jsonl(events: &[AuditEvent]) -> String {
    let mut out = String::new();
    let header = obj(vec![
        ("ev", s("trace")),
        ("version", num(TRACE_VERSION as f64)),
        ("n_features", num(N_FEATURES as f64)),
    ]);
    out.push_str(&header.to_string_compact());
    out.push('\n');
    for ev in events {
        out.push_str(&event_to_json(ev).to_string_compact());
        out.push('\n');
    }
    out
}

fn get_u32(o: &BTreeMap<String, Json>, key: &str) -> Result<u32> {
    o.get(key)
        .and_then(|v| v.as_u64())
        .and_then(|v| u32::try_from(v).ok())
        .ok_or_else(|| crate::errors::Error::msg(format!("bad field '{key}'")))
}

fn get_kind(o: &BTreeMap<String, Json>) -> Result<TaskKind> {
    match o.get("kind").and_then(|v| v.as_str()) {
        Some("map") => Ok(TaskKind::Map),
        Some("reduce") => Ok(TaskKind::Reduce),
        other => crate::bail!("bad task kind {other:?}"),
    }
}

fn get_job(o: &BTreeMap<String, Json>) -> Result<JobId> {
    Ok(JobId { slot: get_u32(o, "slot")?, serial: get_u32(o, "job")? })
}

fn get_task(o: &BTreeMap<String, Json>) -> Result<TaskRef> {
    Ok(TaskRef { job: get_job(o)?, kind: get_kind(o)?, index: get_u32(o, "index")? })
}

fn get_feats(o: &BTreeMap<String, Json>) -> Result<FeatureVec> {
    let arr = o
        .get("feats")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| crate::errors::Error::msg("missing 'feats' array"))?;
    if arr.len() != N_FEATURES {
        crate::bail!("feats has {} entries, expected {N_FEATURES}", arr.len());
    }
    let mut out = [0u8; N_FEATURES];
    for (i, v) in arr.iter().enumerate() {
        out[i] = v
            .as_u64()
            .and_then(|b| u8::try_from(b).ok())
            .ok_or_else(|| crate::errors::Error::msg("bad feats entry"))?;
    }
    Ok(out)
}

fn event_from_json(j: &Json) -> Result<AuditEvent> {
    let o = j
        .as_obj()
        .ok_or_else(|| crate::errors::Error::msg("trace line is not an object"))?;
    let tag = o
        .get("ev")
        .and_then(|v| v.as_str())
        .ok_or_else(|| crate::errors::Error::msg("trace line has no 'ev' tag"))?;
    let ev = match tag {
        "node_spec" => AuditEvent::NodeSpec {
            node: NodeId(get_u32(o, "node")?),
            maps: get_u32(o, "maps")?,
            reduces: get_u32(o, "reduces")?,
        },
        "job_arrived" => AuditEvent::JobArrived { job: get_job(o)? },
        "launched" => AuditEvent::Launched {
            task: get_task(o)?,
            node: NodeId(get_u32(o, "node")?),
            speculative: o
                .get("speculative")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            feats: get_feats(o)?,
        },
        "ended" => AuditEvent::Ended {
            task: get_task(o)?,
            node: NodeId(get_u32(o, "node")?),
        },
        "cluster_info" => AuditEvent::Sched(SchedEvent::ClusterInfo {
            total_slots: get_u32(o, "total_slots")?,
        }),
        "feedback" => AuditEvent::Sched(SchedEvent::Feedback {
            feats: get_feats(o)?,
            label: match o.get("label").and_then(|v| v.as_str()) {
                Some("good") => Label::Good,
                Some("bad") => Label::Bad,
                other => crate::bail!("bad feedback label {other:?}"),
            },
        }),
        "task_started" => AuditEvent::Sched(SchedEvent::TaskStarted {
            job: get_job(o)?,
            node: NodeId(get_u32(o, "node")?),
            kind: get_kind(o)?,
        }),
        "task_finished" => AuditEvent::Sched(SchedEvent::TaskFinished {
            job: get_job(o)?,
            node: NodeId(get_u32(o, "node")?),
            kind: get_kind(o)?,
        }),
        "task_failed" => AuditEvent::Sched(SchedEvent::TaskFailed {
            job: get_job(o)?,
            node: NodeId(get_u32(o, "node")?),
            kind: get_kind(o)?,
            attempt: get_u32(o, "attempt")?,
            reason: match o.get("reason").and_then(|v| v.as_str()) {
                Some("oom") => FailReason::Oom,
                Some("node_lost") => FailReason::NodeLost,
                other => crate::bail!("bad fail reason {other:?}"),
            },
        }),
        "job_completed" => {
            AuditEvent::Sched(SchedEvent::JobCompleted { job: get_job(o)? })
        }
        "node_failed" => {
            AuditEvent::Sched(SchedEvent::NodeFailed { node: NodeId(get_u32(o, "node")?) })
        }
        "node_recovered" => AuditEvent::Sched(SchedEvent::NodeRecovered {
            node: NodeId(get_u32(o, "node")?),
        }),
        other => crate::bail!("unknown trace event tag '{other}'"),
    };
    Ok(ev)
}

/// Parse a JSONL trace. Validates the header (version + feature width).
pub fn from_jsonl(text: &str) -> Result<Vec<AuditEvent>> {
    let mut events = Vec::new();
    let mut saw_header = false;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("trace line {}", lineno + 1))?;
        if !saw_header {
            saw_header = true;
            if j.get("ev").and_then(|v| v.as_str()) != Some("trace") {
                crate::bail!("trace has no header line");
            }
            let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(0);
            if version != TRACE_VERSION {
                crate::bail!("trace version {version}, expected {TRACE_VERSION}");
            }
            let nf = j.get("n_features").and_then(|v| v.as_u64()).unwrap_or(0);
            if nf != N_FEATURES as u64 {
                crate::bail!(
                    "trace recorded with {nf} features, this binary has {N_FEATURES}"
                );
            }
            continue;
        }
        events.push(
            event_from_json(&j).with_context(|| format!("trace line {}", lineno + 1))?,
        );
    }
    if !saw_header {
        crate::bail!("empty trace");
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stream() -> Vec<AuditEvent> {
        // a recycled slot (slot != serial) must survive the round trip
        let recycled = JobId { slot: 0, serial: 7 };
        let t = TaskRef { job: recycled, kind: TaskKind::Map, index: 3 };
        vec![
            AuditEvent::NodeSpec { node: NodeId(0), maps: 2, reduces: 1 },
            AuditEvent::Sched(SchedEvent::ClusterInfo { total_slots: 3 }),
            AuditEvent::JobArrived { job: recycled },
            AuditEvent::Launched {
                task: t,
                node: NodeId(0),
                speculative: false,
                feats: [1, 2, 3, 4, 5, 6, 7, 8, 9, 0],
            },
            AuditEvent::Sched(SchedEvent::TaskStarted {
                job: recycled,
                node: NodeId(0),
                kind: TaskKind::Map,
            }),
            AuditEvent::Sched(SchedEvent::Feedback {
                feats: [1, 2, 3, 4, 5, 6, 7, 8, 9, 0],
                label: Label::Good,
            }),
            AuditEvent::Ended { task: t, node: NodeId(0) },
            AuditEvent::Sched(SchedEvent::TaskFailed {
                job: recycled,
                node: NodeId(0),
                kind: TaskKind::Map,
                attempt: 1,
                reason: FailReason::Oom,
            }),
            AuditEvent::Sched(SchedEvent::JobCompleted { job: recycled }),
            AuditEvent::Sched(SchedEvent::NodeFailed { node: NodeId(0) }),
            AuditEvent::Sched(SchedEvent::NodeRecovered { node: NodeId(0) }),
        ]
    }

    #[test]
    fn jsonl_round_trips_every_variant() {
        let evs = sample_stream();
        let text = to_jsonl(&evs);
        let back = from_jsonl(&text).expect("parse back");
        assert_eq!(evs, back);
    }

    #[test]
    fn missing_header_is_rejected() {
        let evs = sample_stream();
        let text = to_jsonl(&evs);
        let body: String = text.lines().skip(1).collect::<Vec<_>>().join("\n");
        assert!(from_jsonl(&body).is_err());
        assert!(from_jsonl("").is_err());
    }

    #[test]
    fn wrong_feature_width_is_rejected() {
        let text = "{\"ev\":\"trace\",\"version\":2,\"n_features\":8}\n";
        let err = from_jsonl(text).unwrap_err().to_string();
        assert!(err.contains("features"), "{err}");
    }

    #[test]
    fn old_trace_version_is_rejected() {
        let text = "{\"ev\":\"trace\",\"version\":1,\"n_features\":10}\n";
        let err = from_jsonl(text).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn garbage_line_reports_line_number() {
        let text = format!("{}not json\n", to_jsonl(&[]));
        let err = from_jsonl(&text).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }
}
