//! Layer 1 of `repro lint`: project-specific source lints over the
//! workspace, in the spirit of the in-repo dependency substitutes — a small
//! hand-rolled scanner (the parsing style of `config/json.rs`), not a
//! rustc plugin. Each lint enforces one invariant the ROADMAP previously
//! guarded ad hoc; `LINTS.md` documents every lint, its rationale, and the
//! allowlist syntax.
//!
//! Allowlisting: a line is exempt from lint `<name>` when it, or the line
//! directly above it, contains `lint: allow(<name>)` (inside a comment); a
//! whole file is exempt when any line contains `lint: allow-file(<name>)`.
//!
//! Test code is out of scope for the style lints: files named `tests.rs`,
//! anything under `testkit/`, `rust/tests/`, `rust/benches/`, and
//! `#[cfg(test)]` regions (found by brace counting) are skipped.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::json::Json;
use crate::errors::{Context, Result};

/// One lint hit.
#[derive(Debug, Clone)]
pub struct Finding {
    pub lint: &'static str,
    /// Workspace-relative path.
    pub file: String,
    /// 1-based; 0 for file-level findings.
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "{}: [{}] {}", self.file, self.lint, self.msg)
        } else {
            write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.msg)
        }
    }
}

/// One loaded `rust/src` file with its per-line test-region mask.
#[derive(Debug)]
pub struct SourceFile {
    pub rel: String,
    pub lines: Vec<String>,
    /// `in_test[i]` — line i is inside a `#[cfg(test)]` region.
    pub in_test: Vec<bool>,
}

impl SourceFile {
    fn new(rel: String, text: &str) -> SourceFile {
        let lines: Vec<String> = text.lines().map(str::to_string).collect();
        let in_test = test_mask(&lines);
        SourceFile { rel, lines, in_test }
    }

    /// Is line `i` (0-based) exempt from `lint`?
    fn allowed(&self, i: usize, lint: &str) -> bool {
        let file_tag = format!("lint: allow-file({lint})");
        if self.lines.iter().any(|l| l.contains(&file_tag)) {
            return true;
        }
        let tag = format!("lint: allow({lint})");
        if self.lines[i].contains(&tag) {
            return true;
        }
        i > 0 && self.lines[i - 1].contains(&tag)
    }
}

/// Everything the lints look at, loaded once.
#[derive(Debug, Default)]
pub struct Workspace {
    pub root: PathBuf,
    /// `rust/src/**/*.rs`, minus `tests.rs` files and `testkit/`.
    pub src: Vec<SourceFile>,
    /// Every `Cargo.toml` (workspace root + members).
    pub cargo_tomls: Vec<(String, Vec<String>)>,
    /// `rust/tests/*.rs` (rel path, content).
    pub tests: Vec<(String, String)>,
    /// `rust/benches/*.rs` (rel path, content).
    pub benches: Vec<(String, String)>,
    /// `python/compile/constants.py` lines, if present.
    pub py_constants: Option<(String, Vec<String>)>,
    /// Committed perf baselines (`BENCH_e6.json`, `BENCH_engine.json`,
    /// `BENCH_ingest.json`), as present: `(file name, content)`.
    pub bench_baselines: Vec<(String, String)>,
    /// Committed obs regression baseline (`BENCH_obs_baseline.prom`), if
    /// present: `(file name, content)`.
    pub obs_baseline: Option<(String, String)>,
    /// Committed SLO specs (`slo/*.json`), as present: `(rel path, content)`.
    pub slo_specs: Vec<(String, String)>,
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

fn rel_of(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

impl Workspace {
    /// Load the workspace under `root` (the repo checkout). Missing pieces
    /// are tolerated here; each lint decides whether absence is a finding.
    pub fn load(root: &Path) -> Result<Workspace> {
        let mut ws = Workspace { root: root.to_path_buf(), ..Default::default() };

        let src_root = root.join("rust/src");
        let mut files = Vec::new();
        walk_rs(&src_root, &mut files);
        for p in files {
            let rel = rel_of(root, &p);
            if rel.contains("/testkit/") || rel.ends_with("/tests.rs") {
                continue;
            }
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading {rel}"))?;
            ws.src.push(SourceFile::new(rel, &text));
        }

        for rel in ["Cargo.toml", "rust/Cargo.toml"] {
            let p = root.join(rel);
            if let Ok(text) = std::fs::read_to_string(&p) {
                ws.cargo_tomls
                    .push((rel.to_string(), text.lines().map(str::to_string).collect()));
            }
        }

        for (dir, bucket) in
            [("rust/tests", 0usize), ("rust/benches", 1usize)]
        {
            let mut files = Vec::new();
            walk_rs(&root.join(dir), &mut files);
            for p in files {
                let rel = rel_of(root, &p);
                let text = std::fs::read_to_string(&p)
                    .with_context(|| format!("reading {rel}"))?;
                if bucket == 0 {
                    ws.tests.push((rel, text));
                } else {
                    ws.benches.push((rel, text));
                }
            }
        }

        let py = root.join("python/compile/constants.py");
        if let Ok(text) = std::fs::read_to_string(&py) {
            ws.py_constants = Some((
                "python/compile/constants.py".to_string(),
                text.lines().map(str::to_string).collect(),
            ));
        }

        for name in ["BENCH_e6.json", "BENCH_engine.json", "BENCH_ingest.json"] {
            if let Ok(text) = std::fs::read_to_string(root.join(name)) {
                ws.bench_baselines.push((name.to_string(), text));
            }
        }

        let prom = "BENCH_obs_baseline.prom";
        if let Ok(text) = std::fs::read_to_string(root.join(prom)) {
            ws.obs_baseline = Some((prom.to_string(), text));
        }

        if let Ok(entries) = std::fs::read_dir(root.join("slo")) {
            let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
            paths.sort();
            for p in paths {
                if p.extension().map(|e| e == "json").unwrap_or(false) {
                    let rel = rel_of(root, &p);
                    let text = std::fs::read_to_string(&p)
                        .with_context(|| format!("reading {rel}"))?;
                    ws.slo_specs.push((rel, text));
                }
            }
        }

        Ok(ws)
    }

    fn find_src(&self, suffix: &str) -> Option<&SourceFile> {
        self.src.iter().find(|f| f.rel.ends_with(suffix))
    }
}

/// Mark lines inside `#[cfg(test)]` regions by brace counting. An
/// attribute followed by `;` before any `{` (e.g. `#[cfg(test)] mod t;`)
/// covers only those lines.
fn test_mask(lines: &[String]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].trim_start().starts_with("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // scan forward for the region: first `{` opens it, a `;` before
        // any `{` ends it immediately
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        'scan: while j < lines.len() {
            mask[j] = true;
            for c in strip_code(&lines[j]).chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            break 'scan;
                        }
                    }
                    ';' if !opened => break 'scan,
                    _ => {}
                }
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Strip line comments and the *contents* of string/char literals so
/// pattern lints do not fire inside text. Single-line heuristic (raw
/// multi-line strings are not tracked — fine for this codebase).
fn strip_code(line: &str) -> String {
    let b = line.as_bytes();
    let mut out = String::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            break;
        }
        if c == b'"' {
            out.push('"');
            i += 1;
            while i < b.len() && b[i] != b'"' {
                i += if b[i] == b'\\' { 2 } else { 1 };
            }
            out.push('"');
            i += 1;
            continue;
        }
        if c == b'\'' {
            // char literal ('x', '\n', b'"'); lifetimes ('a) pass through
            if i + 3 < b.len() && b[i + 1] == b'\\' && b[i + 3] == b'\'' {
                out.push_str("' '");
                i += 4;
                continue;
            }
            if i + 2 < b.len() && b[i + 2] == b'\'' {
                out.push_str("' '");
                i += 3;
                continue;
            }
        }
        out.push(c as char);
        i += 1;
    }
    out
}

/// Does `tok` look like a float literal (or float const path)?
fn is_float_token(tok: &str) -> bool {
    let t = tok
        .trim_matches(|c: char| "();,{}".contains(c))
        .trim_start_matches('-');
    if t.starts_with("f32::") || t.starts_with("f64::") {
        return true;
    }
    if !t.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false) {
        return false;
    }
    let core = t.trim_end_matches("f32").trim_end_matches("f64").trim_end_matches('_');
    core.contains('.') && core.parse::<f64>().is_ok()
}

fn first_token_after(s: &str) -> &str {
    s.trim_start().split_whitespace().next().unwrap_or("")
}

fn last_token_before(s: &str) -> &str {
    s.trim_end().split_whitespace().last().unwrap_or("")
}

// ---------------------------------------------------------------- lints --

/// `registry-deps`: every `[dependencies]`-family section in every
/// Cargo.toml must be empty — the build is offline by design; in-crate
/// substitutes replace would-be deps.
fn lint_registry_deps(ws: &Workspace, out: &mut Vec<Finding>) {
    for (rel, lines) in &ws.cargo_tomls {
        let mut in_deps = false;
        for (i, line) in lines.iter().enumerate() {
            let t = line.trim();
            if t.starts_with('[') {
                let section = t.trim_matches(|c| c == '[' || c == ']');
                in_deps = section == "dependencies"
                    || section == "dev-dependencies"
                    || section == "build-dependencies"
                    || section.ends_with(".dependencies");
                continue;
            }
            if in_deps && !t.is_empty() && !t.starts_with('#') {
                out.push(Finding {
                    lint: "registry-deps",
                    file: rel.clone(),
                    line: i + 1,
                    msg: format!(
                        "registry dependency '{t}' — this build is offline by \
                         design; write an in-crate substitute instead"
                    ),
                });
            }
        }
    }
}

fn parse_const_int(lines: &[String], pattern: &str) -> Option<(usize, u64)> {
    for (i, line) in lines.iter().enumerate() {
        if let Some(pos) = line.find(pattern) {
            let rest = &line[pos + pattern.len()..];
            let digits: String = rest
                .chars()
                .skip_while(|c| !c.is_ascii_digit())
                .take_while(|c| c.is_ascii_digit())
                .collect();
            if let Ok(v) = digits.parse() {
                return Some((i + 1, v));
            }
        }
    }
    None
}

/// `n-features-sync`: the feature width must agree across the rust feature
/// pipeline (`bayes/features.rs`), the artifact shape contract
/// (`runtime/artifacts.rs` EXPECTED), and the python lowering constants —
/// the PR-2 8→10 widening left `runtime/artifacts.rs` behind; this lint
/// makes that drift impossible to reintroduce.
fn lint_n_features_sync(ws: &Workspace, out: &mut Vec<Finding>) {
    let Some(features) = ws.find_src("bayes/features.rs") else { return };
    let Some((_, nf)) = parse_const_int(&features.lines, "N_FEATURES: usize =")
    else {
        out.push(Finding {
            lint: "n-features-sync",
            file: features.rel.clone(),
            line: 0,
            msg: "cannot find `N_FEATURES: usize = <int>`".into(),
        });
        return;
    };
    let nb = parse_const_int(&features.lines, "N_BINS: usize =").map(|(_, v)| v);

    match ws.find_src("runtime/artifacts.rs") {
        Some(art) => {
            // non-test region only (the test fixture has its own copies)
            let lib_lines: Vec<String> = art
                .lines
                .iter()
                .zip(&art.in_test)
                .map(|(l, t)| if *t { String::new() } else { l.clone() })
                .collect();
            match parse_const_int(&lib_lines, "n_features:") {
                Some((line, v)) if v != nf => out.push(Finding {
                    lint: "n-features-sync",
                    file: art.rel.clone(),
                    line,
                    msg: format!(
                        "EXPECTED.n_features = {v} but bayes/features.rs has \
                         N_FEATURES = {nf}"
                    ),
                }),
                Some(_) => {}
                None => out.push(Finding {
                    lint: "n-features-sync",
                    file: art.rel.clone(),
                    line: 0,
                    msg: "cannot find `n_features: <int>` in EXPECTED".into(),
                }),
            }
            if let (Some((line, fd)), Some(nb)) =
                (parse_const_int(&lib_lines, "feature_dim:"), nb)
            {
                if fd != nf * nb {
                    out.push(Finding {
                        lint: "n-features-sync",
                        file: art.rel.clone(),
                        line,
                        msg: format!(
                            "EXPECTED.feature_dim = {fd} but N_FEATURES × \
                             N_BINS = {}",
                            nf * nb
                        ),
                    });
                }
            }
        }
        None => out.push(Finding {
            lint: "n-features-sync",
            file: "rust/src/runtime/artifacts.rs".into(),
            line: 0,
            msg: "missing — cannot verify the artifact shape contract".into(),
        }),
    }

    match &ws.py_constants {
        Some((rel, lines)) => {
            match parse_const_int(lines, "N_FEATURES =") {
                Some((line, v)) if v != nf => out.push(Finding {
                    lint: "n-features-sync",
                    file: rel.clone(),
                    line,
                    msg: format!(
                        "python N_FEATURES = {v} but bayes/features.rs has {nf}"
                    ),
                }),
                Some(_) => {}
                None => out.push(Finding {
                    lint: "n-features-sync",
                    file: rel.clone(),
                    line: 0,
                    msg: "cannot find `N_FEATURES = <int>`".into(),
                }),
            }
            if let (Some((line, pb)), Some(nb)) =
                (parse_const_int(lines, "N_BINS ="), nb)
            {
                if pb != nb {
                    out.push(Finding {
                        lint: "n-features-sync",
                        file: rel.clone(),
                        line,
                        msg: format!(
                            "python N_BINS = {pb} but bayes/features.rs has {nb}"
                        ),
                    });
                }
            }
        }
        None => out.push(Finding {
            lint: "n-features-sync",
            file: "python/compile/constants.py".into(),
            line: 0,
            msg: "missing — cannot verify the lowering constants".into(),
        }),
    }
}

fn all_names(ws: &Workspace) -> Option<(&SourceFile, Vec<(usize, String)>)> {
    let f = ws.find_src("scheduler/mod.rs")?;
    let start = f
        .lines
        .iter()
        .position(|l| l.contains("pub const ALL_NAMES"))?;
    let mut names = Vec::new();
    for (i, line) in f.lines.iter().enumerate().skip(start) {
        for part in line.split('"').skip(1).step_by(2) {
            names.push((i + 1, part.to_string()));
        }
        if line.contains(']') && i > start {
            break;
        }
        if line.contains("];") {
            break;
        }
    }
    Some((f, names))
}

/// `scheduler-coverage`: every scheduler in `ALL_NAMES` must be exercised
/// by `rust/tests/api_conformance.rs` (a literal name or an `ALL_NAMES`
/// sweep) and by at least one experiment — a registered-but-unmeasured
/// scheduler is dead weight the report tables silently omit.
fn lint_scheduler_coverage(ws: &Workspace, out: &mut Vec<Finding>) {
    let Some((modfile, names)) = all_names(ws) else { return };
    let conformance = ws.tests.iter().find(|(rel, _)| rel.ends_with("api_conformance.rs"));
    let experiments: Vec<&SourceFile> = ws
        .src
        .iter()
        .filter(|f| f.rel.contains("report/experiments/"))
        .collect();
    for (line, name) in &names {
        let quoted = format!("\"{name}\"");
        let covered_conf = match &conformance {
            Some((_, text)) => text.contains(&quoted) || text.contains("ALL_NAMES"),
            None => false,
        };
        if !covered_conf {
            out.push(Finding {
                lint: "scheduler-coverage",
                file: modfile.rel.clone(),
                line: *line,
                msg: format!(
                    "scheduler '{name}' is not exercised by \
                     rust/tests/api_conformance.rs"
                ),
            });
        }
        let covered_exp = experiments.iter().any(|f| {
            f.lines
                .iter()
                .any(|l| l.contains(&quoted) || l.contains("ALL_NAMES"))
        });
        if !covered_exp {
            out.push(Finding {
                lint: "scheduler-coverage",
                file: modfile.rel.clone(),
                line: *line,
                msg: format!("scheduler '{name}' appears in no experiment"),
            });
        }
    }
}

/// `unwrap-in-lib`: no `.unwrap()` / `.expect(` in library paths — failures
/// must flow through `errors.rs` so callers can react; panics are for tests.
fn lint_unwrap(ws: &Workspace, out: &mut Vec<Finding>) {
    for f in &ws.src {
        for (i, line) in f.lines.iter().enumerate() {
            if f.in_test[i] {
                continue;
            }
            let code = strip_code(line);
            let hit = if code.contains(".unwrap()") {
                Some(".unwrap()")
            } else if code.contains(".expect(") {
                Some(".expect(…)")
            } else {
                None
            };
            if let Some(what) = hit {
                if f.allowed(i, "unwrap-in-lib") {
                    continue;
                }
                out.push(Finding {
                    lint: "unwrap-in-lib",
                    file: f.rel.clone(),
                    line: i + 1,
                    msg: format!(
                        "{what} in library code — return a typed error \
                         (errors.rs) or allowlist a proven invariant"
                    ),
                });
            }
        }
    }
}

/// `float-eq`: no `==`/`!=` against a float literal — simulation arithmetic
/// must compare with tolerances (or `total_cmp`), not exact equality.
fn lint_float_eq(ws: &Workspace, out: &mut Vec<Finding>) {
    for f in &ws.src {
        for (i, line) in f.lines.iter().enumerate() {
            if f.in_test[i] {
                continue;
            }
            let code = strip_code(line);
            let bytes = code.as_bytes();
            let mut flagged = false;
            for (pos, w) in code.match_indices("==").chain(code.match_indices("!=")) {
                if w == "==" {
                    // skip <=, >=, ===-like runs and != (handled separately)
                    let prev = if pos > 0 { bytes[pos - 1] } else { b' ' };
                    if prev == b'<' || prev == b'>' || prev == b'!' || prev == b'=' {
                        continue;
                    }
                }
                let left = last_token_before(&code[..pos]);
                let right = first_token_after(&code[pos + 2..]);
                if is_float_token(left) || is_float_token(right) {
                    flagged = true;
                }
            }
            if flagged && !f.allowed(i, "float-eq") {
                out.push(Finding {
                    lint: "float-eq",
                    file: f.rel.clone(),
                    line: i + 1,
                    msg: "exact equality against a float literal — compare \
                          with a tolerance or allowlist the invariant"
                        .into(),
                });
            }
        }
    }
}

/// `engine-hot-loop`: the per-event core must stay allocation-free,
/// collection-free, and iterative — `sim/engine.rs`, `sim/calendar.rs`,
/// and `sim/arena.rs` are the paths every experiment multiplies by
/// millions of events, and a recursive pop/schedule path would turn a deep
/// backlog into a stack overflow. The streaming trace path is hot the
/// same way (once per spec over million-record files): the JSON pull
/// tokenizer (`config/json/pull.rs`) is held to the full list, and the
/// record decoder (`workload/trace.rs`) to a narrower one — specs own
/// their strings, so `String::`/`Vec::new` assembly is sanctioned there,
/// but collections, formatting and wall clocks stay banned.
fn lint_engine_hot_loop(ws: &Workspace, out: &mut Vec<Finding>) {
    const FORBIDDEN: [&str; 9] = [
        "BTreeMap",
        "HashMap",
        "format!",
        "to_string",
        "String::",
        "vec![",
        "Vec::new",
        "Instant",
        "SystemTime",
    ];
    // per-record decode: everything above except owned-string assembly
    const DECODE: [&str; 7] = [
        "BTreeMap",
        "HashMap",
        "format!",
        "to_string",
        "vec![",
        "Instant",
        "SystemTime",
    ];
    const HOT_FILES: [(&str, &[&str]); 5] = [
        ("sim/engine.rs", &FORBIDDEN),
        ("sim/calendar.rs", &FORBIDDEN),
        ("sim/arena.rs", &FORBIDDEN),
        ("config/json/pull.rs", &FORBIDDEN),
        ("workload/trace.rs", &DECODE),
    ];
    for (suffix, forbidden) in HOT_FILES {
        let Some(f) = ws.find_src(suffix) else { continue };
        for (i, line) in f.lines.iter().enumerate() {
            if f.in_test[i] {
                continue;
            }
            let code = strip_code(line);
            for pat in forbidden.iter().copied() {
                if code.contains(pat) && !f.allowed(i, "engine-hot-loop") {
                    out.push(Finding {
                        lint: "engine-hot-loop",
                        file: f.rel.clone(),
                        line: i + 1,
                        msg: format!(
                            "`{pat}` in the per-event hot path — keep the \
                             per-event cost allocation-free"
                        ),
                    });
                }
            }
        }
        lint_self_recursion(f, out);
    }
}

/// The recursion half of `engine-hot-loop`: inside each `fn name(...)` of a
/// hot file, a direct `self.name(` call is direct self-recursion. Brace
/// counting bounds the body; delegation to a field's same-named method
/// (`self.queue.pop()`) does not match the `self.name(` pattern.
fn lint_self_recursion(f: &SourceFile, out: &mut Vec<Finding>) {
    for (i, line) in f.lines.iter().enumerate() {
        if f.in_test[i] {
            continue;
        }
        let sig = strip_code(line);
        let Some(pos) = sig.find("fn ") else { continue };
        let name: String = sig[pos + 3..]
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if name.is_empty() {
            continue;
        }
        let needle = format!("self.{name}(");
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        'body: while j < f.lines.len() {
            let code = strip_code(&f.lines[j]);
            if code.contains(&needle) && !f.allowed(j, "engine-hot-loop") {
                out.push(Finding {
                    lint: "engine-hot-loop",
                    file: f.rel.clone(),
                    line: j + 1,
                    msg: format!(
                        "`fn {name}` calls `self.{name}(` — the hot paths \
                         must be iterative, not recursive"
                    ),
                });
            }
            for c in code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            break 'body;
                        }
                    }
                    ';' if !opened => break 'body, // trait method decl
                    _ => {}
                }
            }
            j += 1;
        }
    }
}

/// `wallclock-in-sim`: library code must read time from the virtual
/// clock only — `Instant::now`/`SystemTime::now` break determinism. The
/// one sanctioned wall-clock site is `rust/src/obs/` (the observability
/// layer's `Stopwatch` wraps it); everything else goes through that.
fn lint_wallclock(ws: &Workspace, out: &mut Vec<Finding>) {
    const SANCTIONED: &str = "rust/src/obs/";
    for f in &ws.src {
        if f.rel.starts_with(SANCTIONED) {
            continue;
        }
        for (i, line) in f.lines.iter().enumerate() {
            if f.in_test[i] {
                continue;
            }
            let code = strip_code(line);
            if (code.contains("Instant::now") || code.contains("SystemTime::now"))
                && !f.allowed(i, "wallclock-in-sim")
            {
                out.push(Finding {
                    lint: "wallclock-in-sim",
                    file: f.rel.clone(),
                    line: i + 1,
                    msg: "wall-clock read outside `obs/` — time flows from \
                          the virtual clock (`Engine::now`) or, for real \
                          latency measurement, `obs::Stopwatch`"
                        .into(),
                });
            }
        }
    }
}

/// `experiment-numbering`: `report/experiments` must stay internally
/// consistent — every id in `ALL` has a dispatch arm and a `pub fn`, and
/// every experiment entry point is registered in `ALL`.
fn lint_experiment_numbering(ws: &Workspace, out: &mut Vec<Finding>) {
    let Some(modfile) = ws.find_src("report/experiments/mod.rs") else { return };
    let start = modfile.lines.iter().position(|l| l.contains("pub const ALL"));
    let Some(start) = start else { return };
    let mut ids: Vec<String> = Vec::new();
    for line in modfile.lines.iter().skip(start) {
        for part in line.split('"').skip(1).step_by(2) {
            ids.push(part.to_string());
        }
        if line.contains("];") {
            break;
        }
    }
    let exp_files: Vec<&SourceFile> = ws
        .src
        .iter()
        .filter(|f| f.rel.contains("report/experiments/"))
        .collect();
    for id in &ids {
        let arm = format!("\"{id}\" =>");
        if !modfile.lines.iter().any(|l| l.contains(&arm)) {
            out.push(Finding {
                lint: "experiment-numbering",
                file: modfile.rel.clone(),
                line: 0,
                msg: format!("'{id}' is in ALL but has no dispatch arm in run()"),
            });
        }
        let def = format!("pub fn {id}(");
        if !exp_files.iter().any(|f| f.lines.iter().any(|l| l.contains(&def))) {
            out.push(Finding {
                lint: "experiment-numbering",
                file: modfile.rel.clone(),
                line: 0,
                msg: format!("'{id}' is in ALL but `pub fn {id}(` exists nowhere"),
            });
        }
    }
    for f in &exp_files {
        for (i, line) in f.lines.iter().enumerate() {
            let code = strip_code(line);
            let Some(pos) = code.find("pub fn e") else { continue };
            let digits: String = code[pos + "pub fn e".len()..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            if digits.is_empty() {
                continue;
            }
            let id = format!("e{digits}");
            if !ids.contains(&id) {
                out.push(Finding {
                    lint: "experiment-numbering",
                    file: f.rel.clone(),
                    line: i + 1,
                    msg: format!("experiment `{id}` is not registered in ALL"),
                });
            }
        }
    }
}

/// `bench-baseline`: each tracked perf baseline (`BENCH_e6.json`,
/// `BENCH_engine.json`, `BENCH_ingest.json`) must exist and its schema must match what its bench
/// emitter actually writes (key sets extracted from the bench source), so
/// the in-repo perf trajectory cannot silently diverge from the tool that
/// produces it. A pair is skipped when its bench source is absent. The
/// committed obs artifacts (`BENCH_obs_baseline.prom`, `slo/*.json`) are
/// held to the same standard by [`lint_obs_artifacts`].
fn lint_bench_baseline(ws: &Workspace, out: &mut Vec<Finding>) {
    const PAIRS: [(&str, &str); 3] = [
        ("e6_decision_latency.rs", "BENCH_e6.json"),
        ("engine_events_per_sec.rs", "BENCH_engine.json"),
        ("trace_ingest_throughput.rs", "BENCH_ingest.json"),
    ];
    for (bench_file, baseline_file) in PAIRS {
        lint_bench_pair(ws, bench_file, baseline_file, out);
    }
    lint_obs_artifacts(ws, out);
}

/// The observability half of `bench-baseline`: the committed obs regression
/// baseline must parse with the crate's own Prometheus loader and carry
/// every counter the drivers always emit, and each committed SLO spec must
/// parse and only reference metrics the baseline (or a tracked bench file)
/// can answer — so CI's `repro obs diff`/`check` gates cannot rot into
/// comparing against garbage. Skipped when the obs SLO engine is absent
/// (fixture workspaces).
fn lint_obs_artifacts(ws: &Workspace, out: &mut Vec<Finding>) {
    use crate::obs::slo::{SloRule, SloSpec};

    if ws.find_src("obs/slo.rs").is_none() {
        return;
    }
    const PROM: &str = "BENCH_obs_baseline.prom";
    let mut complain = |file: &str, msg: String| {
        out.push(Finding { lint: "bench-baseline", file: file.into(), line: 0, msg });
    };
    let dump = match &ws.obs_baseline {
        None => {
            complain(
                PROM,
                "missing — run the quick E10 sweep with --obs-dump and \
                 commit cell 5 (see OBSERVABILITY.md)"
                    .into(),
            );
            return;
        }
        Some((rel, text)) => match crate::obs::export::dump_from_prometheus(text) {
            Ok(d) => d,
            Err(e) => {
                complain(rel, format!("does not parse as a Prometheus snapshot: {e}"));
                return;
            }
        },
    };
    for name in crate::scheduler::api::OBS_EVENT_NAMES {
        if dump.value(name).is_none() {
            complain(PROM, format!("misses the '{name}' counter the drivers always emit"));
        }
    }
    match dump.value("obs_collisions") {
        None => complain(PROM, "misses the 'obs_collisions' counter".into()),
        // a collision in the committed baseline means the registry that
        // produced it was broken -- lint: allow(float-eq)
        Some(v) if v != 0.0 => {
            complain(PROM, format!("obs_collisions is {v}, expected 0"));
        }
        Some(_) => {}
    }

    if ws.slo_specs.is_empty() {
        complain("slo/ci.json", "missing — CI's obs gate needs a committed SLO spec".into());
        return;
    }
    // every metric an SLO rule names must be answerable, so a renamed
    // counter cannot quietly turn a gate vacuous
    let known =
        |m: &str| dump.value(m).is_some() || dump.hists.contains_key(m);
    for (rel, text) in &ws.slo_specs {
        let spec = match SloSpec::parse(text) {
            Ok(s) => s,
            Err(e) => {
                complain(rel, format!("does not parse as an SLO spec: {e}"));
                continue;
            }
        };
        for rule in &spec.rules {
            match rule {
                SloRule::Value { metric, .. }
                | SloRule::Percentile { metric, .. }
                | SloRule::Burn { metric, .. } => {
                    if !known(metric) {
                        complain(rel, format!("rule names '{metric}', absent from {PROM}"));
                    }
                }
                SloRule::Ratio { num, den, .. } => {
                    for m in [num, den] {
                        if !known(m) {
                            complain(rel, format!("rule names '{m}', absent from {PROM}"));
                        }
                    }
                }
                SloRule::Bench { file, key, .. } => {
                    let Some((_, btext)) =
                        ws.bench_baselines.iter().find(|(n, _)| n == file)
                    else {
                        complain(
                            rel,
                            format!("bench rule reads '{file}', not a tracked baseline"),
                        );
                        continue;
                    };
                    let has_key = Json::parse(btext)
                        .ok()
                        .as_ref()
                        .and_then(|j| j.get("results"))
                        .and_then(Json::as_obj)
                        .is_some_and(|results| {
                            results.values().any(|e| {
                                e.get(key).and_then(Json::as_f64).is_some()
                            })
                        });
                    if !has_key {
                        complain(
                            rel,
                            format!("bench rule reads '{file}:{key}', but no result carries that key"),
                        );
                    }
                }
            }
        }
    }
}

/// Check one `(bench source, committed baseline)` pair.
fn lint_bench_pair(
    ws: &Workspace,
    bench_file: &str,
    baseline_file: &str,
    out: &mut Vec<Finding>,
) {
    let Some((bench_rel, bench_src)) =
        ws.benches.iter().find(|(rel, _)| rel.ends_with(bench_file))
    else {
        return;
    };
    // key sets straight from the emitter source
    let keys_of = |var: &str| -> Vec<String> {
        let pat = format!("{var}.insert(\"");
        bench_src
            .lines()
            .filter_map(|l| {
                let pos = l.find(&pat)?;
                let rest = &l[pos + pat.len()..];
                rest.split('"').next().map(str::to_string)
            })
            .collect()
    };
    let doc_keys = keys_of("doc");
    let entry_keys = keys_of("entry");
    if doc_keys.is_empty() || entry_keys.is_empty() {
        out.push(Finding {
            lint: "bench-baseline",
            file: bench_rel.clone(),
            line: 0,
            msg: "cannot extract the emitter's schema keys".into(),
        });
        return;
    }

    let Some((rel, text)) =
        ws.bench_baselines.iter().find(|(name, _)| name == baseline_file)
    else {
        let stem = bench_file.trim_end_matches(".rs");
        out.push(Finding {
            lint: "bench-baseline",
            file: baseline_file.into(),
            line: 0,
            msg: format!(
                "missing — run `BENCH_SMOKE=1 cargo bench --bench {stem}` \
                 and commit the baseline"
            ),
        });
        return;
    };
    let json = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            out.push(Finding {
                lint: "bench-baseline",
                file: rel.clone(),
                line: 0,
                msg: format!("not valid JSON: {e}"),
            });
            return;
        }
    };
    let mut complain = |msg: String| {
        out.push(Finding { lint: "bench-baseline", file: rel.clone(), line: 0, msg })
    };
    let Some(obj) = json.as_obj() else {
        complain("top level is not an object".into());
        return;
    };
    for k in &doc_keys {
        if !obj.contains_key(k) {
            complain(format!("missing top-level key '{k}' (emitter writes it)"));
        }
    }
    for k in obj.keys() {
        if !doc_keys.contains(k) {
            complain(format!("unknown top-level key '{k}' (emitter never writes it)"));
        }
    }
    match json.get("results").and_then(Json::as_obj) {
        Some(results) if !results.is_empty() => {
            for (name, entry) in results {
                let Some(eo) = entry.as_obj() else {
                    complain(format!("results['{name}'] is not an object"));
                    continue;
                };
                for k in &entry_keys {
                    match eo.get(k) {
                        Some(v) if v.as_f64().is_some() => {}
                        Some(_) => complain(format!(
                            "results['{name}'].{k} is not a number"
                        )),
                        None => complain(format!("results['{name}'] misses '{k}'")),
                    }
                }
                for k in eo.keys() {
                    if !entry_keys.contains(k) {
                        complain(format!("results['{name}'] has unknown key '{k}'"));
                    }
                }
            }
        }
        _ => complain("'results' is missing or empty".into()),
    }
}

/// Names of every source lint, for docs/help output.
pub const LINT_NAMES: [&str; 9] = [
    "registry-deps",
    "n-features-sync",
    "scheduler-coverage",
    "unwrap-in-lib",
    "float-eq",
    "engine-hot-loop",
    "wallclock-in-sim",
    "experiment-numbering",
    "bench-baseline",
];

/// Run every source lint over the workspace at `root`.
pub fn run_lints(root: &Path) -> Result<Vec<Finding>> {
    let ws = Workspace::load(root)?;
    let mut out = Vec::new();
    lint_registry_deps(&ws, &mut out);
    lint_n_features_sync(&ws, &mut out);
    lint_scheduler_coverage(&ws, &mut out);
    lint_unwrap(&ws, &mut out);
    lint_float_eq(&ws, &mut out);
    lint_engine_hot_loop(&ws, &mut out);
    lint_wallclock(&ws, &mut out);
    lint_experiment_numbering(&ws, &mut out);
    lint_bench_baseline(&ws, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scratch workspace root, unique per test.
    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("repro_lint_fixture_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn put(root: &Path, rel: &str, text: &str) {
        let p = root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(p, text).unwrap();
    }

    fn lints_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.lint).collect()
    }

    #[test]
    fn registry_deps_fires_on_dependency() {
        let root = scratch("deps");
        put(&root, "Cargo.toml", "[package]\nname = \"x\"\n[dependencies]\nserde = \"1\"\n");
        let f = run_lints(&root).unwrap();
        assert!(lints_of(&f).contains(&"registry-deps"), "{f:?}");

        let root2 = scratch("deps_ok");
        put(&root2, "Cargo.toml", "[package]\nname = \"x\"\n[dependencies]\n\n[features]\nxla = []\n");
        assert!(run_lints(&root2).unwrap().is_empty());
    }

    #[test]
    fn n_features_sync_fires_on_drift() {
        let root = scratch("nfeat");
        put(
            &root,
            "rust/src/bayes/features.rs",
            "pub const N_FEATURES: usize = 10;\npub const N_BINS: usize = 10;\n",
        );
        put(
            &root,
            "rust/src/runtime/artifacts.rs",
            "pub const EXPECTED: S = S { n_features: 8, feature_dim: 80 };\n",
        );
        put(&root, "python/compile/constants.py", "N_FEATURES = 10\nN_BINS = 10\n");
        let f = run_lints(&root).unwrap();
        let hits: Vec<_> =
            f.iter().filter(|x| x.lint == "n-features-sync").collect();
        assert_eq!(hits.len(), 2, "n_features and feature_dim both drift: {f:?}");

        // fixing the rust side makes it green
        let root2 = scratch("nfeat_ok");
        put(
            &root2,
            "rust/src/bayes/features.rs",
            "pub const N_FEATURES: usize = 10;\npub const N_BINS: usize = 10;\n",
        );
        put(
            &root2,
            "rust/src/runtime/artifacts.rs",
            "pub const EXPECTED: S = S { n_features: 10, feature_dim: 100 };\n",
        );
        put(&root2, "python/compile/constants.py", "N_FEATURES = 10\nN_BINS = 10\n");
        assert!(run_lints(&root2).unwrap().is_empty());
    }

    #[test]
    fn python_drift_is_caught() {
        let root = scratch("pydrift");
        put(&root, "rust/src/bayes/features.rs", "pub const N_FEATURES: usize = 10;\n");
        put(&root, "rust/src/runtime/artifacts.rs", "n_features: 10,\n");
        put(&root, "python/compile/constants.py", "N_FEATURES = 8\n");
        let f = run_lints(&root).unwrap();
        assert!(f.iter().any(|x| x.lint == "n-features-sync"
            && x.file.contains("constants.py")), "{f:?}");
    }

    #[test]
    fn scheduler_coverage_fires_on_unexercised_name() {
        let root = scratch("cov");
        put(
            &root,
            "rust/src/scheduler/mod.rs",
            "pub const ALL_NAMES: [&str; 2] = [\"fifo\", \"mystery\"];\n",
        );
        put(&root, "rust/tests/api_conformance.rs", "run(\"fifo\");\n");
        put(&root, "rust/src/report/experiments/e1.rs", "let s = \"fifo\";\n");
        let f = run_lints(&root).unwrap();
        let hits: Vec<_> =
            f.iter().filter(|x| x.lint == "scheduler-coverage").collect();
        assert_eq!(hits.len(), 2, "mystery misses both conformance and experiments: {f:?}");

        // an ALL_NAMES sweep in the conformance test covers everything
        let root2 = scratch("cov_ok");
        put(
            &root2,
            "rust/src/scheduler/mod.rs",
            "pub const ALL_NAMES: [&str; 2] = [\"fifo\", \"mystery\"];\n",
        );
        put(&root2, "rust/tests/api_conformance.rs", "for n in ALL_NAMES {}\n");
        put(
            &root2,
            "rust/src/report/experiments/e1.rs",
            "for n in [\"fifo\", \"mystery\"] {}\n",
        );
        assert!(run_lints(&root2).unwrap().is_empty());
    }

    #[test]
    fn unwrap_in_lib_fires_and_allowlists() {
        let root = scratch("unwrap");
        put(
            &root,
            "rust/src/a.rs",
            "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
             pub fn g(x: Option<u32>) -> u32 { x.expect(\"always\") }\n",
        );
        let f = run_lints(&root).unwrap();
        assert_eq!(
            f.iter().filter(|x| x.lint == "unwrap-in-lib").count(),
            2,
            "{f:?}"
        );

        let root2 = scratch("unwrap_allow");
        put(
            &root2,
            "rust/src/a.rs",
            "// proven non-empty above -- lint: allow(unwrap-in-lib)\n\
             pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
             pub fn h(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n",
        );
        assert!(run_lints(&root2).unwrap().is_empty());
    }

    #[test]
    fn unwrap_inside_cfg_test_is_ignored() {
        let root = scratch("unwrap_test");
        put(
            &root,
            "rust/src/a.rs",
            "pub fn f() {}\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 #[test]\n\
                 fn t() { Some(1).unwrap(); }\n\
             }\n",
        );
        assert!(run_lints(&root).unwrap().is_empty());

        // ...and `#[cfg(test)] mod tests;` only masks its own line
        let root2 = scratch("unwrap_decl");
        put(
            &root2,
            "rust/src/b.rs",
            "#[cfg(test)]\nmod tests;\npub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        let f = run_lints(&root2).unwrap();
        assert!(lints_of(&f).contains(&"unwrap-in-lib"), "{f:?}");
    }

    #[test]
    fn unwrap_inside_string_or_comment_is_ignored() {
        let root = scratch("unwrap_str");
        put(
            &root,
            "rust/src/a.rs",
            "pub fn f() -> &'static str { \"call .unwrap() later\" }\n\
             // docs mention .expect( here\n",
        );
        assert!(run_lints(&root).unwrap().is_empty());
    }

    #[test]
    fn float_eq_fires_on_literal_comparison() {
        let root = scratch("floateq");
        put(
            &root,
            "rust/src/a.rs",
            "pub fn f(x: f64) -> bool { x == 0.0 }\n\
             pub fn g(x: f64) -> bool { 1.5 != x }\n\
             pub fn h(x: f64) -> bool { x <= 0.5 }\n\
             pub fn k(x: u32) -> bool { x == 3 }\n",
        );
        let f = run_lints(&root).unwrap();
        assert_eq!(f.iter().filter(|x| x.lint == "float-eq").count(), 2, "{f:?}");

        let root2 = scratch("floateq_allow");
        put(
            &root2,
            "rust/src/a.rs",
            "pub fn f(x: f64) -> bool { x == 0.0 } // exact by construction -- lint: allow(float-eq)\n",
        );
        assert!(run_lints(&root2).unwrap().is_empty());
    }

    #[test]
    fn engine_hot_loop_fires_on_collections() {
        let root = scratch("hotloop");
        put(
            &root,
            "rust/src/sim/engine.rs",
            "use std::collections::HashMap;\npub struct Engine { m: HashMap<u32, u32> }\n",
        );
        let f = run_lints(&root).unwrap();
        assert!(
            f.iter().filter(|x| x.lint == "engine-hot-loop").count() >= 2,
            "{f:?}"
        );
    }

    #[test]
    fn engine_hot_loop_covers_calendar_and_arena() {
        // the per-event hot path spans all three files, not just engine.rs
        let root = scratch("hotloop_span");
        put(&root, "rust/src/sim/calendar.rs", "pub fn f() -> String { format!(\"x\") }\n");
        put(&root, "rust/src/sim/arena.rs", "use std::collections::BTreeMap;\n");
        let f = run_lints(&root).unwrap();
        let files: Vec<&str> = f
            .iter()
            .filter(|x| x.lint == "engine-hot-loop")
            .map(|x| x.file.as_str())
            .collect();
        assert!(files.iter().any(|p| p.contains("calendar.rs")), "{f:?}");
        assert!(files.iter().any(|p| p.contains("arena.rs")), "{f:?}");
    }

    #[test]
    fn engine_hot_loop_covers_the_streaming_trace_path() {
        // the pull tokenizer is held to the full forbidden list; the
        // record decoder to the narrow one — owned-string assembly is
        // sanctioned there, collections and formatting are not
        let root = scratch("hotloop_stream");
        put(
            &root,
            "rust/src/config/json/pull.rs",
            "pub fn f() -> String { String::new() }\n",
        );
        put(
            &root,
            "rust/src/workload/trace.rs",
            "pub fn ok() -> String { String::with_capacity(8) }\n\
             pub fn bad() -> String { format!(\"x\") }\n",
        );
        let f = run_lints(&root).unwrap();
        let hits: Vec<(&str, usize)> = f
            .iter()
            .filter(|x| x.lint == "engine-hot-loop")
            .map(|x| (x.file.as_str(), x.line))
            .collect();
        assert!(hits.iter().any(|(p, _)| p.contains("pull.rs")), "{f:?}");
        assert!(
            hits.iter().any(|(p, l)| p.contains("trace.rs") && *l == 2),
            "{f:?}"
        );
        assert!(
            !hits.iter().any(|(p, l)| p.contains("trace.rs") && *l == 1),
            "String:: must stay sanctioned in the decoder: {f:?}"
        );
    }

    #[test]
    fn engine_hot_loop_fires_on_self_recursion() {
        let root = scratch("hotloop_rec");
        put(
            &root,
            "rust/src/sim/calendar.rs",
            "pub struct Q { n: u64 }\n\
             impl Q {\n\
                 pub fn pop(&mut self) -> u64 {\n\
                     if self.n > 0 { self.n -= 1; return self.pop(); }\n\
                     0\n\
                 }\n\
             }\n",
        );
        let f = run_lints(&root).unwrap();
        assert!(
            f.iter().any(|x| x.lint == "engine-hot-loop" && x.msg.contains("recursive")),
            "{f:?}"
        );

        // delegation to a field's same-named method is not recursion
        let root2 = scratch("hotloop_deleg");
        put(
            &root2,
            "rust/src/sim/calendar.rs",
            "pub struct Q { inner: Inner }\n\
             impl Q {\n\
                 pub fn pop(&mut self) -> u64 { self.inner.pop() }\n\
             }\n",
        );
        assert!(run_lints(&root2).unwrap().is_empty());
    }

    #[test]
    fn wallclock_fires_everywhere_except_obs() {
        let root = scratch("wallclock");
        // broken fixture: two wall-clock reads outside obs/, one inside
        put(
            &root,
            "rust/src/sim/clock.rs",
            "pub fn now() -> std::time::Instant { std::time::Instant::now() }\n",
        );
        put(
            &root,
            "rust/src/report/bench.rs",
            "pub fn t() -> std::time::Instant { std::time::Instant::now() }\n",
        );
        put(
            &root,
            "rust/src/obs/clock.rs",
            "pub fn start() -> std::time::Instant { std::time::Instant::now() }\n",
        );
        let f = run_lints(&root).unwrap();
        let mut hits: Vec<_> = f
            .iter()
            .filter(|x| x.lint == "wallclock-in-sim")
            .map(|x| x.file.as_str())
            .collect();
        hits.sort_unstable();
        assert_eq!(hits.len(), 2, "{f:?}");
        assert!(hits[0].contains("report/bench.rs"), "{hits:?}");
        assert!(hits[1].contains("sim/clock.rs"), "{hits:?}");
    }

    #[test]
    fn experiment_numbering_fires_on_gaps_and_orphans() {
        let root = scratch("expnum");
        put(
            &root,
            "rust/src/report/experiments/mod.rs",
            "pub const ALL: [&str; 2] = [\"e1\", \"e2\"];\n\
             pub fn run(id: &str) { match id { \"e1\" => e1(), _ => {} } }\n\
             pub fn e1() {}\n",
        );
        put(&root, "rust/src/report/experiments/extra.rs", "pub fn e3() {}\n");
        let f = run_lints(&root).unwrap();
        let msgs: Vec<&str> = f
            .iter()
            .filter(|x| x.lint == "experiment-numbering")
            .map(|x| x.msg.as_str())
            .collect();
        assert!(msgs.iter().any(|m| m.contains("'e2'") && m.contains("dispatch")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`e2`") || m.contains("'e2'")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("e3")), "{msgs:?}");
    }

    const EMITTER: &str = r#"
        doc.insert("bench".to_string(), x);
        doc.insert("results".to_string(), x);
        entry.insert("batched_ns".to_string(), x);
        entry.insert("speedup".to_string(), x);
    "#;

    #[test]
    fn bench_baseline_missing_or_mismatched_fires() {
        let root = scratch("bench_missing");
        put(&root, "rust/benches/e6_decision_latency.rs", EMITTER);
        let f = run_lints(&root).unwrap();
        assert!(lints_of(&f).contains(&"bench-baseline"), "{f:?}");

        // schema drift: an entry misses a key the emitter writes
        let root2 = scratch("bench_drift");
        put(&root2, "rust/benches/e6_decision_latency.rs", EMITTER);
        put(
            &root2,
            "BENCH_e6.json",
            r#"{"bench": "e6", "results": {"fifo_q16": {"batched_ns": 10}}}"#,
        );
        let f2 = run_lints(&root2).unwrap();
        assert!(
            f2.iter().any(|x| x.lint == "bench-baseline" && x.msg.contains("speedup")),
            "{f2:?}"
        );

        // matching schema is green
        let root3 = scratch("bench_ok");
        put(&root3, "rust/benches/e6_decision_latency.rs", EMITTER);
        put(
            &root3,
            "BENCH_e6.json",
            r#"{"bench": "e6", "results": {"fifo_q16": {"batched_ns": 10, "speedup": 2.0}}}"#,
        );
        assert!(run_lints(&root3).unwrap().is_empty());
    }

    const ENGINE_EMITTER: &str = r#"
        doc.insert("bench".to_string(), x);
        doc.insert("results".to_string(), x);
        entry.insert("heap_ns".to_string(), x);
        entry.insert("calendar_ns".to_string(), x);
    "#;

    #[test]
    fn bench_baseline_checks_each_pair_independently() {
        // the engine bench present without its baseline fires for
        // BENCH_engine.json specifically
        let root = scratch("bench_engine_missing");
        put(&root, "rust/benches/engine_events_per_sec.rs", ENGINE_EMITTER);
        let f = run_lints(&root).unwrap();
        assert!(
            f.iter().any(|x| {
                x.lint == "bench-baseline" && x.file == "BENCH_engine.json"
            }),
            "{f:?}"
        );

        // both pairs present and matching is green
        let root2 = scratch("bench_engine_ok");
        put(&root2, "rust/benches/e6_decision_latency.rs", EMITTER);
        put(
            &root2,
            "BENCH_e6.json",
            r#"{"bench": "e6", "results": {"fifo_q16": {"batched_ns": 10, "speedup": 2.0}}}"#,
        );
        put(&root2, "rust/benches/engine_events_per_sec.rs", ENGINE_EMITTER);
        put(
            &root2,
            "BENCH_engine.json",
            r#"{"bench": "engine", "results": {"pending_1000": {"heap_ns": 95.0, "calendar_ns": 88.0}}}"#,
        );
        assert!(run_lints(&root2).unwrap().is_empty());
    }

    #[test]
    fn obs_artifacts_are_schema_checked() {
        use crate::scheduler::api::OBS_EVENT_NAMES;

        // without the obs SLO engine in the tree the whole check skips,
        // so plain fixture workspaces stay green
        let root = scratch("obs_skip");
        put(&root, "rust/src/a.rs", "pub fn f() {}\n");
        assert!(run_lints(&root).unwrap().is_empty());

        // with it present, a missing baseline is its own finding
        let root = scratch("obs_missing");
        put(&root, "rust/src/obs/slo.rs", "// slo engine\n");
        let f = run_lints(&root).unwrap();
        assert!(
            f.iter().any(|x| {
                x.lint == "bench-baseline" && x.file == "BENCH_obs_baseline.prom"
            }),
            "{f:?}"
        );

        // a complete baseline + a spec whose rules all resolve is green
        let mut prom = String::from("obs_collisions 0\n");
        for n in OBS_EVENT_NAMES {
            prom.push_str(&format!("{n} 12\n"));
        }
        let spec = "{\"slo\": [\
            {\"kind\": \"value\", \"metric\": \"obs_collisions\", \"max\": 0},\
            {\"kind\": \"bench\", \"file\": \"BENCH_engine.json\", \
             \"key\": \"obs_overhead_pct\", \"max\": 5.0}]}";
        let root = scratch("obs_ok");
        put(&root, "rust/src/obs/slo.rs", "// slo engine\n");
        put(&root, "BENCH_obs_baseline.prom", &prom);
        put(&root, "slo/ci.json", spec);
        put(
            &root,
            "BENCH_engine.json",
            "{\"results\": {\"engine\": {\"obs_overhead_pct\": 3.2}}}",
        );
        assert!(run_lints(&root).unwrap().is_empty());

        // a collision, missing driver counters, a rule naming a ghost
        // metric, and a bench rule on an untracked file all fire
        let root = scratch("obs_bad");
        put(&root, "rust/src/obs/slo.rs", "// slo engine\n");
        put(&root, "BENCH_obs_baseline.prom", "obs_collisions 3\n");
        put(
            &root,
            "slo/ci.json",
            "{\"slo\": [\
              {\"kind\": \"value\", \"metric\": \"ghost_metric\", \"max\": 1},\
              {\"kind\": \"bench\", \"file\": \"BENCH_nope.json\", \
               \"key\": \"x\", \"max\": 1}]}",
        );
        let f = run_lints(&root).unwrap();
        let msgs: Vec<&str> = f.iter().map(|x| x.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("obs_collisions is 3")), "{f:?}");
        assert!(msgs.iter().any(|m| m.contains("'ghost_metric'")), "{f:?}");
        assert!(msgs.iter().any(|m| m.contains("'BENCH_nope.json'")), "{f:?}");
        assert!(
            msgs.iter().any(|m| m.contains("'sched_ev_task_started'")),
            "{f:?}"
        );

        // an unparseable spec is reported, not swallowed
        let root = scratch("obs_garbage_spec");
        put(&root, "rust/src/obs/slo.rs", "// slo engine\n");
        put(&root, "BENCH_obs_baseline.prom", &prom);
        put(&root, "slo/ci.json", "not json");
        let f = run_lints(&root).unwrap();
        assert!(
            f.iter().any(|x| {
                x.file == "slo/ci.json" && x.msg.contains("does not parse")
            }),
            "{f:?}"
        );
    }

    #[test]
    fn testkit_and_tests_rs_are_out_of_scope() {
        let root = scratch("scope");
        put(&root, "rust/src/testkit/mod.rs", "pub fn f() { None::<u32>.unwrap(); }\n");
        put(&root, "rust/src/scheduler/tests.rs", "pub fn g() { None::<u32>.unwrap(); }\n");
        assert!(run_lints(&root).unwrap().is_empty());
    }

    #[test]
    fn the_real_repo_lints_clean() {
        // repo root = two levels up from rust/src (CARGO_MANIFEST_DIR/..)
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let findings = run_lints(&root).unwrap();
        assert!(
            findings.is_empty(),
            "the repo must lint clean:\n{}",
            findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
