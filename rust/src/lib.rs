//! # bayes-sched
//!
//! Reproduction of **"The Improved Job Scheduling Algorithm of Hadoop
//! Platform"** (CS.DC 2015): a Naive-Bayes job scheduler for a
//! Hadoop-MRv1-style cluster, built as a three-layer rust + JAX + Pallas
//! stack (DESIGN.md). The classifier hot path is AOT-compiled from
//! JAX/Pallas to HLO and executed via xla/PJRT; python never runs at
//! simulation time.
//!
//! Layer map:
//! * substrates — [`sim`], [`cluster`], [`hdfs`], [`job`], [`workload`]
//! * the contribution — [`bayes`], [`scheduler`]
//! * runtime — [`runtime`] (PJRT), [`coordinator`] (JobTracker loop)
//! * extension — [`yarn`] (RM/NM/AM mode)
//! * tooling — [`config`], [`cli`], [`metrics`], [`obs`] (registry +
//!   span tracing + exporters), [`report`], [`testkit`], [`analysis`]
//!   (`repro lint` + SchedEvent protocol auditor)

pub mod analysis;
pub mod bayes;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod errors;
pub mod hdfs;
pub mod job;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod testkit;
pub mod workload;
pub mod yarn;
