//! The online Naive Bayes good/bad classifier (paper §4.2).
//!
//! [`NaiveBayes`] is the pure-rust implementation. The XLA-backed
//! [`crate::runtime::XlaClassifier`] implements the same [`Classifier`]
//! trait by executing the AOT artifacts; both use the identical update
//! semantics (buffer feedback, flush in batches, Laplace smoothing) and f32
//! arithmetic, so they agree to float tolerance — enforced by differential
//! tests in `rust/tests/integration_runtime.rs`.

use super::features::{FeatureVec, N_BINS, N_FEATURES};

/// Feedback batch size: flushes happen at most every `MAX_BATCH` samples.
/// Mirrors `python/compile/constants.py::MAX_BATCH`.
pub const MAX_BATCH: usize = 128;
/// Scoring window: a single classify call scores at most this many jobs.
/// Mirrors `python/compile/constants.py::MAX_JOBS`.
pub const MAX_JOBS: usize = 256;
/// Flattened feature dimension (N_FEATURES * N_BINS).
pub const FEATURE_DIM: usize = N_FEATURES * N_BINS;

/// Feedback label from the overload rule (paper: good = did not overload
/// the TaskTracker; bad = did).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Label {
    Good = 0,
    Bad = 1,
}

/// Result of scoring a job queue against one node.
#[derive(Debug, Clone)]
pub struct ClassifyResult {
    /// Posterior P(good | J) per job.
    pub p_good: Vec<f32>,
    /// Expected utility P(good|J) * U(i) per job.
    pub score: Vec<f32>,
    /// Index of the maximum score.
    pub best: usize,
}

impl ClassifyResult {
    /// Jobs the classifier calls *good* (P(good) >= 0.5).
    pub fn is_good(&self, i: usize) -> bool {
        self.p_good[i] >= 0.5
    }
}

/// The classifier interface the Bayes scheduler programs against.
///
/// Not `Send`: the PJRT client wraps a thread-local `Rc`, and the
/// simulation loop is single-threaded by design (determinism contract).
pub trait Classifier {
    /// Score `feats[i]` (job+node features) with utility `utility[i]`.
    /// `feats.len()` must be in `1..=MAX_JOBS`. Implementations flush any
    /// buffered feedback first so the scores reflect all observations.
    fn classify(&mut self, feats: &[FeatureVec], utility: &[f32]) -> ClassifyResult;

    /// Record one overload-rule feedback sample. May buffer; buffered
    /// samples are applied on [`Classifier::flush`] or automatically when
    /// the buffer reaches `MAX_BATCH` or at the next classify.
    fn observe(&mut self, feats: FeatureVec, label: Label);

    /// Apply all buffered feedback to the model tables.
    fn flush(&mut self);

    /// (good, bad) sample counts absorbed so far (flushed only).
    fn class_counts(&self) -> [f32; 2];

    /// Implementation name for logs/reports.
    fn name(&self) -> &'static str;

    /// Raw model state (counts, class_counts) for persistence; both
    /// implementations expose the identical layout.
    fn export_state(&self) -> (Vec<f32>, [f32; 2], f32);
}

/// Pure-rust online Naive Bayes with Laplace smoothing.
///
/// State layout matches the artifacts: `counts[c * FEATURE_DIM + j * N_BINS
/// + bin]`, class 0 = good. All arithmetic in f32 to track the XLA path.
#[derive(Debug, Clone)]
pub struct NaiveBayes {
    counts: Vec<f32>,       // [2 * FEATURE_DIM]
    class_counts: [f32; 2], // [good, bad]
    log_prior: [f32; 2],
    log_lik: Vec<f32>, // [2 * FEATURE_DIM]
    alpha: f32,
    pending: Vec<(FeatureVec, Label)>,
}

impl NaiveBayes {
    /// Fresh classifier with Laplace smoothing strength `alpha` (paper
    /// leaves initialization open; uniform priors = deviation D4).
    pub fn new(alpha: f32) -> Self {
        let mut nb = NaiveBayes {
            counts: vec![0.0; 2 * FEATURE_DIM],
            class_counts: [0.0; 2],
            log_prior: [0.0; 2],
            log_lik: vec![0.0; 2 * FEATURE_DIM],
            alpha,
            pending: Vec::with_capacity(MAX_BATCH),
        };
        nb.recompute_tables();
        nb
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    /// Smoothed log tables (for export / inspection / seeding the XLA path).
    pub fn tables(&self) -> (&[f32; 2], &[f32]) {
        (&self.log_prior, &self.log_lik)
    }

    /// Raw counts (for state persistence and differential tests).
    pub fn state(&self) -> (&[f32], [f32; 2]) {
        (&self.counts, self.class_counts)
    }

    /// Restore from raw counts (e.g. replaying a persisted model).
    pub fn from_state(counts: Vec<f32>, class_counts: [f32; 2], alpha: f32) -> Self {
        assert_eq!(counts.len(), 2 * FEATURE_DIM);
        let mut nb = NaiveBayes {
            counts,
            class_counts,
            log_prior: [0.0; 2],
            log_lik: vec![0.0; 2 * FEATURE_DIM],
            alpha,
            pending: Vec::with_capacity(MAX_BATCH),
        };
        nb.recompute_tables();
        nb
    }

    /// Number of buffered (not yet applied) samples.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    fn recompute_tables(&mut self) {
        // Same smoothing as python model.update_model:
        //   log_lik = ln(count + a) - ln(class_count + a*B)
        //   log_prior = ln(class_count + a) - ln(total + a*C)
        let a = self.alpha;
        let total = self.class_counts[0] + self.class_counts[1];
        for c in 0..2 {
            self.log_prior[c] =
                (self.class_counts[c] + a).ln() - (total + a * 2.0).ln();
            let denom = (self.class_counts[c] + a * N_BINS as f32).ln();
            for k in 0..FEATURE_DIM {
                self.log_lik[c * FEATURE_DIM + k] =
                    (self.counts[c * FEATURE_DIM + k] + a).ln() - denom;
            }
        }
    }

    /// Joint log-probability [good, bad] of one feature row.
    pub fn joint(&self, feats: &FeatureVec) -> [f32; 2] {
        let mut out = self.log_prior;
        for (j, &bin) in feats.iter().enumerate() {
            debug_assert!((bin as usize) < N_BINS);
            let k = j * N_BINS + bin as usize;
            out[0] += self.log_lik[k];
            out[1] += self.log_lik[FEATURE_DIM + k];
        }
        out
    }

    /// Posterior P(good | feats) of one row (stable two-class softmax).
    pub fn posterior_good(&self, feats: &FeatureVec) -> f32 {
        let [g, b] = self.joint(feats);
        let m = g.max(b);
        let eg = (g - m).exp();
        eg / (eg + (b - m).exp())
    }
}

impl Classifier for NaiveBayes {
    fn classify(&mut self, feats: &[FeatureVec], utility: &[f32]) -> ClassifyResult {
        assert!(!feats.is_empty() && feats.len() <= MAX_JOBS);
        assert_eq!(feats.len(), utility.len());
        self.flush();
        let mut p_good = Vec::with_capacity(feats.len());
        let mut score = Vec::with_capacity(feats.len());
        let mut best = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for (i, fv) in feats.iter().enumerate() {
            let p = self.posterior_good(fv);
            let s = p * utility[i];
            if s > best_score {
                best_score = s;
                best = i;
            }
            p_good.push(p);
            score.push(s);
        }
        ClassifyResult { p_good, score, best }
    }

    fn observe(&mut self, feats: FeatureVec, label: Label) {
        self.pending.push((feats, label));
        if self.pending.len() >= MAX_BATCH {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        for (fv, label) in std::mem::take(&mut self.pending) {
            let c = label as usize;
            self.class_counts[c] += 1.0;
            for (j, &bin) in fv.iter().enumerate() {
                self.counts[c * FEATURE_DIM + j * N_BINS + bin as usize] += 1.0;
            }
        }
        self.recompute_tables();
    }

    fn class_counts(&self) -> [f32; 2] {
        self.class_counts
    }

    fn name(&self) -> &'static str {
        "naive-bayes(rust)"
    }

    fn export_state(&self) -> (Vec<f32>, [f32; 2], f32) {
        (self.counts.clone(), self.class_counts, self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fv(val: u8) -> FeatureVec {
        [val; N_FEATURES]
    }

    #[test]
    fn uninformed_posterior_is_half() {
        let mut nb = NaiveBayes::new(1.0);
        let r = nb.classify(&[fv(3), fv(9)], &[1.0, 1.0]);
        for p in r.p_good {
            assert!((p - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn learns_separable_labels() {
        let mut nb = NaiveBayes::new(1.0);
        for _ in 0..50 {
            nb.observe(fv(9), Label::Bad);
            nb.observe(fv(1), Label::Good);
        }
        nb.flush();
        assert!(nb.posterior_good(&fv(1)) > 0.9);
        assert!(nb.posterior_good(&fv(9)) < 0.1);
    }

    #[test]
    fn observe_buffers_until_flush() {
        let mut nb = NaiveBayes::new(1.0);
        nb.observe(fv(9), Label::Bad);
        assert_eq!(nb.class_counts(), [0.0, 0.0]); // buffered
        assert_eq!(nb.pending_len(), 1);
        nb.flush();
        assert_eq!(nb.class_counts(), [0.0, 1.0]);
    }

    #[test]
    fn auto_flush_at_max_batch() {
        let mut nb = NaiveBayes::new(1.0);
        for _ in 0..MAX_BATCH {
            nb.observe(fv(2), Label::Good);
        }
        assert_eq!(nb.pending_len(), 0);
        assert_eq!(nb.class_counts(), [MAX_BATCH as f32, 0.0]);
    }

    #[test]
    fn classify_sees_pending_feedback() {
        let mut nb = NaiveBayes::new(1.0);
        for _ in 0..30 {
            nb.observe(fv(9), Label::Bad);
        }
        // classify() must flush first
        let r = nb.classify(&[fv(9)], &[1.0]);
        assert!(r.p_good[0] < 0.3);
    }

    #[test]
    fn utility_drives_selection() {
        let mut nb = NaiveBayes::new(1.0);
        let r = nb.classify(&[fv(5), fv(5), fv(5)], &[1.0, 7.0, 2.0]);
        assert_eq!(r.best, 1);
    }

    #[test]
    fn posterior_bounds_under_extreme_counts() {
        let mut nb = NaiveBayes::new(1.0);
        for _ in 0..10_000 {
            nb.observe(fv(0), Label::Good);
        }
        nb.flush();
        let p = nb.posterior_good(&fv(0));
        assert!(p > 0.5 && p <= 1.0 && p.is_finite());
        let q = nb.posterior_good(&fv(9));
        assert!(q >= 0.0 && q.is_finite());
    }

    #[test]
    fn counts_equal_sum_of_feedback() {
        let mut nb = NaiveBayes::new(1.0);
        for i in 0..300u32 {
            let label = if i % 3 == 0 { Label::Bad } else { Label::Good };
            nb.observe(fv((i % 10) as u8), label);
        }
        nb.flush();
        let [g, b] = nb.class_counts();
        assert_eq!(g + b, 300.0);
        assert_eq!(b, 100.0);
        // every sample contributes exactly N_FEATURES counts
        let (counts, _) = nb.state();
        let total: f32 = counts.iter().sum();
        assert_eq!(total, 300.0 * N_FEATURES as f32);
    }

    #[test]
    fn from_state_roundtrip() {
        let mut nb = NaiveBayes::new(0.5);
        for _ in 0..40 {
            nb.observe(fv(7), Label::Bad);
            nb.observe(fv(2), Label::Good);
        }
        nb.flush();
        let (counts, cc) = nb.state();
        let nb2 = NaiveBayes::from_state(counts.to_vec(), cc, 0.5);
        for v in [fv(2), fv(5), fv(7)] {
            assert_eq!(nb.posterior_good(&v), nb2.posterior_good(&v));
        }
    }

    #[test]
    fn smoothing_strength_matters() {
        let mut weak = NaiveBayes::new(0.1);
        let mut strong = NaiveBayes::new(10.0);
        for nb in [&mut weak, &mut strong] {
            for _ in 0..5 {
                nb.observe(fv(9), Label::Bad);
            }
            nb.flush();
        }
        // weaker smoothing -> sharper posterior from the same 5 samples
        assert!(weak.posterior_good(&fv(9)) < strong.posterior_good(&fv(9)));
    }
}
