//! The overload rule (paper §4.2): "the rule which determine whether the
//! execution of task allocation leads to the TaskTracker which it execute
//! on overload ... we are not limited to just one judgment standard but
//! synthesis multiple conditions for judging."
//!
//! The rule is evaluated against the node's *next heartbeat after the
//! placement* (deviation D5: the paper's "next hop" observation at
//! heartbeat granularity) and its verdict labels the feedback sample.

use super::classifier::Label;

/// Resource snapshot of a TaskTracker at heartbeat time. All fractions of
/// capacity in [0, ~1.2] (contention can push instantaneous demand past
/// capacity before the contention model throttles it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadObservation {
    pub cpu_used: f64,
    pub mem_used: f64,
    pub io_load: f64,
    pub net_load: f64,
    /// Mean slowdown factor of tasks currently on the node (1.0 = no
    /// contention; 2.0 = tasks running at half speed).
    pub slowdown: f64,
}

/// Configurable multi-condition overload rule. A node is overloaded when
/// ANY enabled threshold is exceeded (the paper's "synthesis multiple
/// conditions": CPU, memory, network and so on).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadRule {
    pub cpu_threshold: f64,
    pub mem_threshold: f64,
    pub io_threshold: f64,
    pub net_threshold: f64,
    pub slowdown_threshold: f64,
}

impl Default for OverloadRule {
    fn default() -> Self {
        OverloadRule {
            cpu_threshold: 0.90,
            mem_threshold: 0.90,
            io_threshold: 0.95,
            net_threshold: 0.95,
            slowdown_threshold: 1.5,
        }
    }
}

impl OverloadRule {
    /// Judge one observation. `true` = overloaded.
    pub fn is_overloaded(&self, obs: &OverloadObservation) -> bool {
        obs.cpu_used > self.cpu_threshold
            || obs.mem_used > self.mem_threshold
            || obs.io_load > self.io_threshold
            || obs.net_load > self.net_threshold
            || obs.slowdown > self.slowdown_threshold
    }

    /// Feedback label for the allocation that preceded `obs`.
    pub fn label(&self, obs: &OverloadObservation) -> Label {
        if self.is_overloaded(obs) {
            Label::Bad
        } else {
            Label::Good
        }
    }

    /// A rule that only looks at CPU (the paper's example: "the most jobs
    /// are CPU intensive ones, then the usage rate of CPU can used to be
    /// the standard").
    pub fn cpu_only(threshold: f64) -> Self {
        OverloadRule {
            cpu_threshold: threshold,
            mem_threshold: f64::INFINITY,
            io_threshold: f64::INFINITY,
            net_threshold: f64::INFINITY,
            slowdown_threshold: f64::INFINITY,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calm() -> OverloadObservation {
        OverloadObservation {
            cpu_used: 0.4,
            mem_used: 0.3,
            io_load: 0.2,
            net_load: 0.1,
            slowdown: 1.0,
        }
    }

    #[test]
    fn calm_node_is_good() {
        let rule = OverloadRule::default();
        assert!(!rule.is_overloaded(&calm()));
        assert_eq!(rule.label(&calm()), Label::Good);
    }

    #[test]
    fn any_condition_triggers() {
        let rule = OverloadRule::default();
        for f in [
            |o: &mut OverloadObservation| o.cpu_used = 0.95,
            |o: &mut OverloadObservation| o.mem_used = 0.99,
            |o: &mut OverloadObservation| o.io_load = 0.97,
            |o: &mut OverloadObservation| o.net_load = 1.0,
            |o: &mut OverloadObservation| o.slowdown = 2.0,
        ] {
            let mut obs = calm();
            f(&mut obs);
            assert!(rule.is_overloaded(&obs), "{obs:?}");
            assert_eq!(rule.label(&obs), Label::Bad);
        }
    }

    #[test]
    fn thresholds_are_exclusive_bounds() {
        let rule = OverloadRule::default();
        let mut obs = calm();
        obs.cpu_used = 0.90; // exactly at threshold -> not overloaded
        assert!(!rule.is_overloaded(&obs));
        obs.cpu_used = 0.9000001;
        assert!(rule.is_overloaded(&obs));
    }

    #[test]
    fn cpu_only_ignores_everything_else() {
        let rule = OverloadRule::cpu_only(0.8);
        let mut obs = calm();
        obs.mem_used = 1.0;
        obs.slowdown = 10.0;
        assert!(!rule.is_overloaded(&obs));
        obs.cpu_used = 0.85;
        assert!(rule.is_overloaded(&obs));
    }
}
