//! Classifier persistence: save/load the Naive Bayes count tables as JSON
//! so a trained model can warm-start later runs (the paper's scheduler
//! learns continuously; operationally you want that learning to survive a
//! JobTracker restart).

use std::path::Path;

use crate::errors::{anyhow, Context, Result};

use crate::config::json::Json;

use super::classifier::{NaiveBayes, FEATURE_DIM};

/// Serialize a classifier's state (counts + alpha).
pub fn to_json(nb: &NaiveBayes) -> Json {
    let (counts, class_counts) = nb.state();
    let mut o = std::collections::BTreeMap::new();
    o.insert("format".into(), Json::Str("bayes-sched-nb-v1".into()));
    o.insert("alpha".into(), Json::Num(nb.alpha() as f64));
    o.insert(
        "class_counts".into(),
        Json::Arr(class_counts.iter().map(|c| Json::Num(*c as f64)).collect()),
    );
    o.insert(
        "counts".into(),
        Json::Arr(counts.iter().map(|c| Json::Num(*c as f64)).collect()),
    );
    Json::Obj(o)
}

/// Restore a classifier from its JSON state.
pub fn from_json(j: &Json) -> Result<NaiveBayes> {
    let format = j
        .get("format")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'format'"))?;
    if format != "bayes-sched-nb-v1" {
        return Err(anyhow!("unsupported model format '{format}'"));
    }
    let alpha = j
        .get("alpha")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing 'alpha'"))? as f32;
    if alpha <= 0.0 {
        return Err(anyhow!("alpha must be > 0"));
    }
    let class_counts: Vec<f32> = j
        .get("class_counts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'class_counts'"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .ok_or_else(|| anyhow!("non-numeric class_counts"))?;
    if class_counts.len() != 2 {
        return Err(anyhow!("class_counts must have 2 entries"));
    }
    let counts: Vec<f32> = j
        .get("counts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'counts'"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .ok_or_else(|| anyhow!("non-numeric counts"))?;
    if counts.len() != 2 * FEATURE_DIM {
        return Err(anyhow!(
            "counts must have {} entries, got {}",
            2 * FEATURE_DIM,
            counts.len()
        ));
    }
    if counts.iter().chain(class_counts.iter()).any(|c| *c < 0.0 || !c.is_finite()) {
        return Err(anyhow!("counts must be finite and non-negative"));
    }
    Ok(NaiveBayes::from_state(counts, [class_counts[0], class_counts[1]], alpha))
}

/// Save to a file.
pub fn save(nb: &NaiveBayes, path: &Path) -> Result<()> {
    std::fs::write(path, to_json(nb).to_string_pretty())
        .with_context(|| format!("writing model {path:?}"))
}

/// Load from a file.
pub fn load(path: &Path) -> Result<NaiveBayes> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading model {path:?}"))?;
    from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayes::classifier::{Classifier, Label};
    use crate::bayes::features::N_FEATURES;

    fn trained() -> NaiveBayes {
        let mut nb = NaiveBayes::new(0.5);
        for i in 0..150u8 {
            let fv = [(i % 10); N_FEATURES];
            let label = if i % 10 >= 5 { Label::Bad } else { Label::Good };
            nb.observe(fv, label);
        }
        nb.flush();
        nb
    }

    #[test]
    fn roundtrip_preserves_posteriors() {
        let nb = trained();
        let restored = from_json(&to_json(&nb)).unwrap();
        assert_eq!(restored.alpha(), nb.alpha());
        assert_eq!(restored.class_counts(), nb.class_counts());
        for bin in 0..10u8 {
            let fv = [bin; N_FEATURES];
            assert_eq!(nb.posterior_good(&fv), restored.posterior_good(&fv));
        }
    }

    #[test]
    fn file_roundtrip() {
        let nb = trained();
        let path = std::env::temp_dir().join("bayes_sched_model_test.json");
        save(&nb, &path).unwrap();
        let restored = load(&path).unwrap();
        assert_eq!(restored.class_counts(), nb.class_counts());
    }

    #[test]
    fn rejects_malformed() {
        let cases = [
            r#"{}"#,
            r#"{"format": "other", "alpha": 1}"#,
            r#"{"format": "bayes-sched-nb-v1", "alpha": 0, "class_counts": [1,1], "counts": []}"#,
            r#"{"format": "bayes-sched-nb-v1", "alpha": 1, "class_counts": [1], "counts": []}"#,
            r#"{"format": "bayes-sched-nb-v1", "alpha": 1, "class_counts": [1,1], "counts": [1,2,3]}"#,
        ];
        for c in cases {
            assert!(from_json(&Json::parse(c).unwrap()).is_err(), "{c}");
        }
    }

    #[test]
    fn rejects_negative_counts() {
        let nb = trained();
        let mut j = to_json(&nb);
        if let Json::Obj(o) = &mut j {
            o.insert("class_counts".into(), Json::Arr(vec![Json::Num(-1.0), Json::Num(2.0)]));
        }
        assert!(from_json(&j).is_err());
    }
}
