//! The utility function U(i) (paper §4.2): "we import utility function to
//! set the prior level of jobs and implements some scheduling strategies.
//! Without utility function, the scheduler will always select the jobs
//! which can provide maximum system availability."
//!
//! The paper does not specify a functional form (deviation D2). We use
//! `U(i) = priority_weight^priority * (1 + age / age_scale)` — monotone in
//! the job's priority level and its queue waiting time, so high-priority
//! and long-waiting jobs win ties among good jobs and starvation is
//! bounded. `UtilityFn::constant()` reproduces the paper's "without utility
//! function" baseline for the E8 ablation.

/// Job priority levels, mirroring Hadoop's five JobPriority values.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    VeryLow = 0,
    Low = 1,
    Normal = 2,
    High = 3,
    VeryHigh = 4,
}

impl Priority {
    pub fn from_index(i: usize) -> Priority {
        match i {
            0 => Priority::VeryLow,
            1 => Priority::Low,
            2 => Priority::Normal,
            3 => Priority::High,
            _ => Priority::VeryHigh,
        }
    }
}

/// Parametrized utility function.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilityFn {
    /// Multiplicative weight per priority level above VeryLow.
    pub priority_weight: f64,
    /// Seconds of queue age that double a job's utility.
    pub age_scale: f64,
}

impl Default for UtilityFn {
    fn default() -> Self {
        UtilityFn { priority_weight: 1.6, age_scale: 120.0 }
    }
}

impl UtilityFn {
    /// The "no utility function" ablation: U(i) = 1 for every job.
    pub fn constant() -> Self {
        UtilityFn { priority_weight: 1.0, age_scale: f64::INFINITY }
    }

    /// U(i) for a job with `priority` that has waited `age_secs` in queue.
    pub fn eval(&self, priority: Priority, age_secs: f64) -> f64 {
        let p = self.priority_weight.powi(priority as i32);
        let age_term = if self.age_scale.is_finite() {
            1.0 + age_secs.max(0.0) / self.age_scale
        } else {
            1.0
        };
        p * age_term
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_priority() {
        let u = UtilityFn::default();
        let mut last = 0.0;
        for p in 0..5 {
            let v = u.eval(Priority::from_index(p), 10.0);
            assert!(v > last);
            last = v;
        }
    }

    #[test]
    fn monotone_in_age() {
        let u = UtilityFn::default();
        assert!(
            u.eval(Priority::Normal, 100.0) > u.eval(Priority::Normal, 10.0)
        );
    }

    #[test]
    fn constant_ignores_everything() {
        let u = UtilityFn::constant();
        assert_eq!(u.eval(Priority::VeryLow, 0.0), 1.0);
        assert_eq!(u.eval(Priority::VeryHigh, 1e6), 1.0);
    }

    #[test]
    fn negative_age_clamped() {
        let u = UtilityFn::default();
        assert_eq!(
            u.eval(Priority::Normal, -5.0),
            u.eval(Priority::Normal, 0.0)
        );
    }

    #[test]
    fn age_scale_doubles() {
        let u = UtilityFn { priority_weight: 1.0, age_scale: 60.0 };
        let base = u.eval(Priority::Normal, 0.0);
        assert!((u.eval(Priority::Normal, 60.0) - 2.0 * base).abs() < 1e-12);
    }
}
