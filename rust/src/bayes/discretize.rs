//! The paper's feature discretization: "The variable values are set from 10
//! to 1, and 10 is the maximum value which represents the utmost using of
//! resources" (§4.2). Internally we use bins 0..=9; bin b displays as the
//! paper's value b+1.

use super::features::N_BINS;

/// Discretize a fraction in [0, 1] to a bin in [0, N_BINS).
///
/// Values outside [0, 1] are clamped — heartbeats can briefly report >100%
/// utilization under contention.
pub fn bin_fraction(frac: f64) -> u8 {
    let f = frac.clamp(0.0, 1.0);
    // 1.0 maps to the top bin, not past it.
    ((f * N_BINS as f64) as usize).min(N_BINS - 1) as u8
}

/// Inverse: representative fraction (bin midpoint) for a bin.
pub fn bin_midpoint(bin: u8) -> f64 {
    (bin as f64 + 0.5) / N_BINS as f64
}

/// The paper's displayed value (1–10) for a bin.
pub fn display_value(bin: u8) -> u8 {
    bin + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        assert_eq!(bin_fraction(0.0), 0);
        assert_eq!(bin_fraction(1.0), 9);
        assert_eq!(bin_fraction(0.999), 9);
    }

    #[test]
    fn clamps_out_of_range() {
        assert_eq!(bin_fraction(-0.5), 0);
        assert_eq!(bin_fraction(1.7), 9);
        assert_eq!(bin_fraction(f64::NAN.clamp(0.0, 1.0)), 0);
    }

    #[test]
    fn uniform_bucket_widths() {
        for b in 0..10u8 {
            let lo = b as f64 / 10.0;
            assert_eq!(bin_fraction(lo + 1e-9), b);
            assert_eq!(bin_fraction(lo + 0.0999), b);
        }
    }

    #[test]
    fn midpoint_roundtrips() {
        for b in 0..10u8 {
            assert_eq!(bin_fraction(bin_midpoint(b)), b);
        }
    }

    #[test]
    fn display_is_one_based() {
        assert_eq!(display_value(0), 1);
        assert_eq!(display_value(9), 10);
    }
}
