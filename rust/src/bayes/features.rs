//! Feature variables (paper §4.2 + ATLAS-style failure awareness): 4 **job
//! features** describing a job's declared resource appetite, 4 **node
//! features** describing the TaskTracker's current capacity, and 2
//! **failure-history features** (per-job failed attempts, per-node recent
//! kill rate — Soualhia et al. 1511.01446 / 1507.03562 show failure
//! history is the strongest scheduling signal under churn). Each feature is
//! discretized to 1–10 (bins 0–9).
//!
//! Keep the layout in sync with `python/compile/constants.py`: feature j of
//! a sample occupies one-hot slots `j*N_BINS .. (j+1)*N_BINS` of the
//! flattened table.

use crate::cluster::node::NodeId;
use crate::job::JobId;
use crate::sim::arena::SlotMap;
use crate::sim::engine::Time;

use super::discretize::bin_fraction;

/// Total feature variables per (job, node) sample:
/// 4 job + 4 node + 2 failure-history.
pub const N_FEATURES: usize = 10;
/// Discretization bins (paper's 1–10 scale).
pub const N_BINS: usize = 10;

/// A discretized (job, node) feature sample: the classifier's input row.
pub type FeatureVec = [u8; N_FEATURES];

/// Job features: "the average usage rate of CPU and average usage rate of
/// memory ... average network usage rate, and average usage rate of IO"
/// (§4.2). Fractions in [0, 1], set when the job is submitted (the paper's
/// "set when the user commits job" option).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobFeatures {
    pub cpu: f64,
    pub mem: f64,
    pub io: f64,
    pub net: f64,
}

impl JobFeatures {
    pub fn bins(&self) -> [u8; 4] {
        [
            bin_fraction(self.cpu),
            bin_fraction(self.mem),
            bin_fraction(self.io),
            bin_fraction(self.net),
        ]
    }
}

/// Node features: "the usage rate of CPU and the size of idle physical
/// memory" (§4.2) plus IO/network load. All *usage/load* fractions in
/// [0, 1] — note `idle_mem` is stored as utilization (1 - idle fraction) so
/// that, like every other feature, **higher bin = more loaded** and the
/// classifier sees a consistent direction (paper: "for node feature, the
/// lower the value, the lower usability").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeFeatures {
    pub cpu_used: f64,
    pub mem_used: f64,
    pub io_load: f64,
    pub net_load: f64,
}

impl NodeFeatures {
    pub fn bins(&self) -> [u8; 4] {
        [
            bin_fraction(self.cpu_used),
            bin_fraction(self.mem_used),
            bin_fraction(self.io_load),
            bin_fraction(self.net_load),
        ]
    }
}

/// Discretized failure-history bins for one (job, node) pair, read out of a
/// [`FailureHistory`]. Higher bin = more failure-prone, matching the
/// direction of every other feature.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FailureFeats {
    /// Failed attempts of the job so far, saturating at bin 9.
    pub job_bin: u8,
    /// Decayed kill score of the node, saturating at bin 9.
    pub node_bin: u8,
}

/// Rolling failure statistics. The **driver** maintains one instance (it is
/// the component that observes every attempt ending) and exposes it to
/// schedulers through `SchedView::failures`, so decision-time rows and
/// feedback-time rows are built from the identical state.
#[derive(Debug, Clone)]
pub struct FailureHistory {
    /// Failed attempts per job, slot-indexed by the job's arena handle;
    /// entries are dropped when the job leaves the system, and a recycled
    /// slot's stale count is invisible to the new occupant's id (the
    /// serial stamp mismatches), so memory stays O(live jobs).
    job_failures: SlotMap<u32>,
    /// Exponentially decayed kill score per node, dense by `NodeId`:
    /// `(score, last_update)`. Nodes are never reclaimed, so a plain
    /// vector indexed by node id is the right shape.
    node_kills: Vec<Option<(f64, Time)>>,
    /// Half-life of the per-node kill score, seconds.
    half_life: f64,
}

impl Default for FailureHistory {
    fn default() -> Self {
        FailureHistory::new()
    }
}

impl FailureHistory {
    /// Default half-life: 10 virtual minutes — long enough that an OOM
    /// storm marks a node for many heartbeats, short enough that a
    /// recovered node is forgiven.
    pub const DEFAULT_HALF_LIFE: f64 = 600.0;

    pub fn new() -> FailureHistory {
        FailureHistory {
            job_failures: SlotMap::new(),
            node_kills: Vec::with_capacity(0),
            half_life: Self::DEFAULT_HALF_LIFE,
        }
    }

    pub fn with_half_life(half_life: f64) -> FailureHistory {
        FailureHistory { half_life: half_life.max(1.0), ..FailureHistory::new() }
    }

    /// One task attempt of `job` ended in failure on `node`.
    pub fn record_failure(&mut self, job: JobId, node: NodeId, now: Time) {
        *self.job_failures.get_or_insert_with(job, || 0) += 1;
        let score = self.node_score(node, now) + 1.0;
        let i = node.0 as usize;
        if i >= self.node_kills.len() {
            self.node_kills.resize_with(i + 1, || None);
        }
        self.node_kills[i] = Some((score, now));
    }

    /// Drop a job's entry once it leaves the system (completed or killed).
    pub fn forget_job(&mut self, job: JobId) {
        self.job_failures.remove(job);
    }

    /// Failed attempts recorded for `job` (0 if never seen).
    pub fn job_failures(&self, job: JobId) -> u32 {
        match self.job_failures.get(job) {
            Some(&n) => n,
            None => 0,
        }
    }

    /// Decayed kill score of `node` at virtual time `now`.
    pub fn node_score(&self, node: NodeId, now: Time) -> f64 {
        match self.node_kills.get(node.0 as usize) {
            Some(&Some((score, last))) => {
                let dt = (now - last).max(0.0);
                score * 0.5f64.powf(dt / self.half_life)
            }
            _ => 0.0,
        }
    }

    /// Jobs currently tracked (leak regression guard).
    pub fn tracked_jobs(&self) -> usize {
        self.job_failures.len()
    }

    /// The two discretized failure features for a (job, node) pair.
    pub fn feats_for(&self, job: JobId, node: NodeId, now: Time) -> FailureFeats {
        FailureFeats {
            job_bin: self.job_failures(job).min(9) as u8,
            node_bin: (self.node_score(node, now).floor() as u64).min(9) as u8,
        }
    }
}

/// Assemble the classifier input row for (job, node): job bins, node bins,
/// then the failure-history bins.
pub fn feature_vec(
    job: &JobFeatures,
    node: &NodeFeatures,
    fail: FailureFeats,
) -> FeatureVec {
    let j = job.bins();
    let n = node.bins();
    [
        j[0],
        j[1],
        j[2],
        j[3],
        n[0],
        n[1],
        n[2],
        n[3],
        fail.job_bin,
        fail.node_bin,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_job_then_node_then_failures() {
        let job = JobFeatures { cpu: 0.95, mem: 0.05, io: 0.55, net: 0.35 };
        let node = NodeFeatures {
            cpu_used: 0.15,
            mem_used: 0.75,
            io_load: 0.0,
            net_load: 1.0,
        };
        let fail = FailureFeats { job_bin: 2, node_bin: 7 };
        assert_eq!(
            feature_vec(&job, &node, fail),
            [9, 0, 5, 3, 1, 7, 0, 9, 2, 7]
        );
    }

    #[test]
    fn all_bins_in_range() {
        let job = JobFeatures { cpu: 2.0, mem: -1.0, io: 0.5, net: 0.5 };
        let node = NodeFeatures {
            cpu_used: 0.5,
            mem_used: 0.5,
            io_load: 9.0,
            net_load: -9.0,
        };
        let mut hist = FailureHistory::new();
        for _ in 0..50 {
            hist.record_failure(JobId::dense(1), NodeId(0), 10.0);
        }
        let fail = hist.feats_for(JobId::dense(1), NodeId(0), 10.0);
        for b in feature_vec(&job, &node, fail) {
            assert!((b as usize) < N_BINS);
        }
        assert_eq!(fail.job_bin, 9, "job failure bin must saturate");
        assert_eq!(fail.node_bin, 9, "node kill bin must saturate");
    }

    #[test]
    fn node_score_decays_with_half_life() {
        let mut hist = FailureHistory::with_half_life(100.0);
        hist.record_failure(JobId::dense(0), NodeId(3), 0.0);
        hist.record_failure(JobId::dense(0), NodeId(3), 0.0);
        assert!((hist.node_score(NodeId(3), 0.0) - 2.0).abs() < 1e-12);
        assert!((hist.node_score(NodeId(3), 100.0) - 1.0).abs() < 1e-12);
        assert!((hist.node_score(NodeId(3), 200.0) - 0.5).abs() < 1e-12);
        // a different node is untouched
        assert_eq!(hist.node_score(NodeId(4), 50.0), 0.0);
    }

    #[test]
    fn forget_job_bounds_memory() {
        let mut hist = FailureHistory::new();
        for i in 0..100 {
            hist.record_failure(JobId::dense(i), NodeId(0), 1.0);
        }
        assert_eq!(hist.tracked_jobs(), 100);
        for i in 0..100 {
            hist.forget_job(JobId::dense(i));
        }
        assert_eq!(hist.tracked_jobs(), 0);
        assert_eq!(hist.job_failures(JobId::dense(5)), 0);
    }

    #[test]
    fn empty_history_yields_zero_bins() {
        let hist = FailureHistory::new();
        let f = hist.feats_for(JobId::dense(9), NodeId(9), 123.0);
        assert_eq!(f, FailureFeats::default());
    }
}
