//! Feature variables (paper §4.2): 4 **job features** describing a job's
//! declared resource appetite, and 4 **node features** describing the
//! TaskTracker's current capacity, each discretized to 1–10 (bins 0–9).
//!
//! Keep the layout in sync with `python/compile/constants.py`: feature j of
//! a sample occupies one-hot slots `j*N_BINS .. (j+1)*N_BINS` of the
//! flattened table.

use super::discretize::bin_fraction;

/// Total feature variables per (job, node) sample.
pub const N_FEATURES: usize = 8;
/// Discretization bins (paper's 1–10 scale).
pub const N_BINS: usize = 10;

/// A discretized (job, node) feature sample: the classifier's input row.
pub type FeatureVec = [u8; N_FEATURES];

/// Job features: "the average usage rate of CPU and average usage rate of
/// memory ... average network usage rate, and average usage rate of IO"
/// (§4.2). Fractions in [0, 1], set when the job is submitted (the paper's
/// "set when the user commits job" option).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobFeatures {
    pub cpu: f64,
    pub mem: f64,
    pub io: f64,
    pub net: f64,
}

impl JobFeatures {
    pub fn bins(&self) -> [u8; 4] {
        [
            bin_fraction(self.cpu),
            bin_fraction(self.mem),
            bin_fraction(self.io),
            bin_fraction(self.net),
        ]
    }
}

/// Node features: "the usage rate of CPU and the size of idle physical
/// memory" (§4.2) plus IO/network load. All *usage/load* fractions in
/// [0, 1] — note `idle_mem` is stored as utilization (1 - idle fraction) so
/// that, like every other feature, **higher bin = more loaded** and the
/// classifier sees a consistent direction (paper: "for node feature, the
/// lower the value, the lower usability").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeFeatures {
    pub cpu_used: f64,
    pub mem_used: f64,
    pub io_load: f64,
    pub net_load: f64,
}

impl NodeFeatures {
    pub fn bins(&self) -> [u8; 4] {
        [
            bin_fraction(self.cpu_used),
            bin_fraction(self.mem_used),
            bin_fraction(self.io_load),
            bin_fraction(self.net_load),
        ]
    }
}

/// Assemble the classifier input row for (job, node).
pub fn feature_vec(job: &JobFeatures, node: &NodeFeatures) -> FeatureVec {
    let j = job.bins();
    let n = node.bins();
    [j[0], j[1], j[2], j[3], n[0], n[1], n[2], n[3]]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_job_then_node() {
        let job = JobFeatures { cpu: 0.95, mem: 0.05, io: 0.55, net: 0.35 };
        let node = NodeFeatures {
            cpu_used: 0.15,
            mem_used: 0.75,
            io_load: 0.0,
            net_load: 1.0,
        };
        assert_eq!(feature_vec(&job, &node), [9, 0, 5, 3, 1, 7, 0, 9]);
    }

    #[test]
    fn all_bins_in_range() {
        let job = JobFeatures { cpu: 2.0, mem: -1.0, io: 0.5, net: 0.5 };
        let node = NodeFeatures {
            cpu_used: 0.5,
            mem_used: 0.5,
            io_load: 9.0,
            net_load: -9.0,
        };
        for b in feature_vec(&job, &node) {
            assert!((b as usize) < N_BINS);
        }
    }
}
