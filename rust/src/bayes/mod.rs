//! The paper's contribution: an online Naive Bayes good/bad job classifier
//! with overload-rule feedback (paper §4).
//!
//! * [`features`] — the 10 discretized feature variables (4 job + 4 node +
//!   2 failure-history, ATLAS-style).
//! * [`discretize`] — the paper's 1–10 value discretization.
//! * [`classifier`] — [`Classifier`] trait + [`NaiveBayes`], the pure-rust
//!   implementation (also the differential-testing oracle for the
//!   XLA-backed [`crate::runtime::XlaClassifier`]).
//! * [`overload`] — the overload rule that labels feedback samples.
//! * [`utility`] — the utility function `U(i)` for expected-utility job
//!   selection.

pub mod classifier;
pub mod discretize;
pub mod features;
pub mod overload;
pub mod persist;
pub mod utility;

pub use classifier::{Classifier, ClassifyResult, Label, NaiveBayes};
pub use discretize::bin_fraction;
pub use features::{
    FailureFeats, FailureHistory, FeatureVec, JobFeatures, NodeFeatures, N_BINS,
    N_FEATURES,
};
pub use overload::{OverloadObservation, OverloadRule};
pub use utility::UtilityFn;
