//! Synthetic workload generator: Poisson job arrivals over a configurable
//! class mix, with per-job feature jitter, heavy-tailed task durations and
//! a small population of users (for the Fair/Capacity baselines' pools and
//! queues).

use crate::bayes::features::JobFeatures;
use crate::bayes::utility::Priority;
use crate::job::job::JobSpec;
use crate::job::profile::JobClass;
use crate::sim::rng::Pcg;

/// Class mix: weights need not sum to 1.
#[derive(Debug, Clone)]
pub struct Mix(pub Vec<(JobClass, f64)>);

impl Mix {
    /// The default mixed workload (E1): every class represented, skewed
    /// toward cpu/io-heavy jobs as the paper's overload discussion assumes.
    pub fn balanced() -> Mix {
        Mix(vec![
            (JobClass::CpuHeavy, 0.30),
            (JobClass::IoHeavy, 0.25),
            (JobClass::MemHeavy, 0.15),
            (JobClass::NetHeavy, 0.10),
            (JobClass::Small, 0.20),
        ])
    }

    /// Single-class workload.
    pub fn only(class: JobClass) -> Mix {
        Mix(vec![(class, 1.0)])
    }

    /// `frac` cpu-heavy, remainder spread over the other classes (E7).
    pub fn cpu_fraction(frac: f64) -> Mix {
        let rest = (1.0 - frac).max(0.0) / 4.0;
        Mix(vec![
            (JobClass::CpuHeavy, frac),
            (JobClass::IoHeavy, rest),
            (JobClass::MemHeavy, rest),
            (JobClass::NetHeavy, rest),
            (JobClass::Small, rest),
        ])
    }
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_jobs: usize,
    /// Poisson arrival rate, jobs/second.
    pub arrival_rate: f64,
    pub mix: Mix,
    pub n_users: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_jobs: 200,
            arrival_rate: 0.5,
            mix: Mix::balanced(),
            n_users: 8,
            seed: 1,
        }
    }
}

/// Generate the job stream lazily. Deterministic in `cfg.seed` and
/// RNG-identical to [`generate`] — collecting this iterator reproduces the
/// eager vector bit for bit — but O(1) memory, so million-job runs feed
/// the drivers' streaming constructors without materializing the specs.
pub fn stream(cfg: &WorkloadConfig) -> impl Iterator<Item = JobSpec> {
    let mut arrivals = Pcg::new(cfg.seed, 1);
    let mut classes = Pcg::new(cfg.seed, 2);
    let mut shapes = Pcg::new(cfg.seed, 3);

    let mix = cfg.mix.0.clone();
    let weights: Vec<f64> = mix.iter().map(|(_, w)| *w).collect();
    let arrival_rate = cfg.arrival_rate;
    let n_users = cfg.n_users.max(1);
    let mut t = 0.0;
    (0..cfg.n_jobs).map(move |i| {
        t += arrivals.exp(arrival_rate);
        let class = mix[classes.weighted(&weights)].0;
        let user_idx = classes.index(n_users);
        make_spec(i, class, user_idx, t, &mut shapes)
    })
}

/// Generate the job stream eagerly. Deterministic in `cfg.seed`.
pub fn generate(cfg: &WorkloadConfig) -> Vec<JobSpec> {
    stream(cfg).collect()
}

fn jitter(rng: &mut Pcg, v: f64) -> f64 {
    (v + rng.range_f64(-0.10, 0.10)).clamp(0.02, 1.0)
}

fn make_spec(
    i: usize,
    class: JobClass,
    user_idx: usize,
    submit_time: f64,
    rng: &mut Pcg,
) -> JobSpec {
    let base = class.base_features();
    let profile = JobFeatures {
        cpu: jitter(rng, base.cpu),
        mem: jitter(rng, base.mem),
        io: jitter(rng, base.io),
        net: jitter(rng, base.net),
    };
    let (mlo, mhi) = class.map_count_range();
    let n_maps = rng.range_u64(mlo as u64, mhi as u64) as usize;
    let (rlo, rhi) = class.reduce_count_range();
    let n_reduces = rng.range_u64(rlo as u64, rhi as u64) as usize;
    let (m_mu, m_sigma) = class.map_work_lognormal();
    let (r_mu, r_sigma) = class.reduce_work_lognormal();
    let map_works = (0..n_maps)
        .map(|_| rng.lognormal(m_mu, m_sigma).clamp(0.5, 600.0))
        .collect();
    let reduce_works = (0..n_reduces)
        .map(|_| rng.lognormal(r_mu, r_sigma).clamp(0.5, 900.0))
        .collect();
    // priorities: mostly Normal, occasionally High/Low (10% each tail)
    let priority = match rng.f64() {
        x if x < 0.05 => Priority::VeryHigh,
        x if x < 0.15 => Priority::High,
        x if x < 0.85 => Priority::Normal,
        x if x < 0.95 => Priority::Low,
        _ => Priority::VeryLow,
    };
    let user = format!("user{user_idx}");
    JobSpec {
        name: format!("{}_{i:04}", class.name()),
        pool: user.clone(),
        queue: format!("q{}", user_idx % 3),
        user,
        class,
        priority,
        profile,
        map_works,
        reduce_works,
        submit_time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.submit_time, y.submit_time);
            assert_eq!(x.map_works, y.map_works);
        }
    }

    #[test]
    fn stream_matches_generate() {
        let cfg = WorkloadConfig { n_jobs: 300, ..Default::default() };
        let eager = generate(&cfg);
        let lazy: Vec<JobSpec> = stream(&cfg).collect();
        assert_eq!(eager.len(), lazy.len());
        for (x, y) in eager.iter().zip(&lazy) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.submit_time, y.submit_time);
            assert_eq!(x.map_works, y.map_works);
            assert_eq!(x.reduce_works, y.reduce_works);
            assert_eq!(x.user, y.user);
        }
    }

    #[test]
    fn arrivals_monotone_and_poisson_ish() {
        let cfg = WorkloadConfig { n_jobs: 2000, arrival_rate: 2.0, ..Default::default() };
        let specs = generate(&cfg);
        let mut last = 0.0;
        for s in &specs {
            assert!(s.submit_time > last);
            last = s.submit_time;
        }
        // mean inter-arrival ~ 1/rate
        let mean = last / 2000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn mix_respected() {
        let cfg = WorkloadConfig {
            n_jobs: 1000,
            mix: Mix::only(JobClass::CpuHeavy),
            ..Default::default()
        };
        assert!(generate(&cfg).iter().all(|s| s.class == JobClass::CpuHeavy));
    }

    #[test]
    fn cpu_fraction_mix() {
        let specs = generate(&WorkloadConfig {
            n_jobs: 2000,
            mix: Mix::cpu_fraction(0.75),
            ..Default::default()
        });
        let cpu = specs.iter().filter(|s| s.class == JobClass::CpuHeavy).count();
        assert!((0.70..0.80).contains(&(cpu as f64 / 2000.0)));
    }

    #[test]
    fn features_in_range_and_tasks_bounded() {
        for s in generate(&WorkloadConfig { n_jobs: 500, ..Default::default() }) {
            for f in [s.profile.cpu, s.profile.mem, s.profile.io, s.profile.net] {
                assert!((0.0..=1.0).contains(&f));
            }
            assert!(!s.map_works.is_empty());
            for w in s.map_works.iter().chain(&s.reduce_works) {
                assert!((0.5..=900.0).contains(w));
            }
        }
    }

    #[test]
    fn users_spread() {
        let specs = generate(&WorkloadConfig { n_jobs: 400, n_users: 4, ..Default::default() });
        let users: std::collections::HashSet<&str> =
            specs.iter().map(|s| s.user.as_str()).collect();
        assert_eq!(users.len(), 4);
    }
}
