//! Workload substrate: synthetic job generation (the paper has no public
//! trace — substitution D1), trace serialization, and replay helpers.

pub mod generator;
pub mod trace;

pub use generator::{generate, Mix, WorkloadConfig};
