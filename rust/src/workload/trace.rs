//! Streaming trace serialization: persist workloads as JSON and replay
//! them with **bounded memory** — one [`JobSpec`] decoded per pull from
//! the tokenizer in `config/json/pull.rs`, never the whole array.
//!
//! Two on-disk formats (documented in `TRACES.md` at the repo root):
//!
//! - **Array** (`[ {...}, {...} ]`): the original format, one JSON
//!   document holding every spec.
//! - **JSONL** (`{...}\n{...}\n`): one compact spec object per line —
//!   seekable, resumable, `cat`-able; `repro trace convert` translates
//!   between the two.
//!
//! [`TraceReader`] sniffs the format from the first structural byte and
//! iterates `Result<JobSpec>` with error-at-record granularity: the
//! first malformed record yields its `Err` and fuses the stream.
//! [`TraceWriter`] streams specs out through a reused line buffer — no
//! `Json` tree is ever built in either direction. The `engine-hot-loop`
//! lint holds this file to the per-record allocation budget (the specs
//! themselves own heap data; nothing else may).

use std::io::{Read, Write};
use std::path::Path;

use crate::errors::{anyhow, bail, Context, Result};

use crate::bayes::features::JobFeatures;
use crate::bayes::utility::Priority;
use crate::config::json;
use crate::config::json::pull::{PullParser, Token};
use crate::config::json::Json;
use crate::job::job::JobSpec;
use crate::job::profile::JobClass;
use crate::obs::{Counter, Gauge, Registry, Stopwatch};

/// On-disk trace layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    /// One JSON array holding every spec (the original format).
    Array,
    /// One compact spec object per line.
    Jsonl,
}

impl TraceFormat {
    pub fn name(&self) -> &'static str {
        match self {
            TraceFormat::Array => "array",
            TraceFormat::Jsonl => "jsonl",
        }
    }

    pub fn from_name(s: &str) -> Option<TraceFormat> {
        match s {
            "array" | "json" => Some(TraceFormat::Array),
            "jsonl" => Some(TraceFormat::Jsonl),
            _ => None,
        }
    }
}

/// Ingest instrumentation: shared handles updated by [`TraceReader`]
/// while the caller keeps a clone to export after the run. Detached by
/// default (always counting, exported nowhere) — `registered` binds the
/// `trace_*` metric names into a [`Registry`] (see OBSERVABILITY.md).
#[derive(Clone, Debug)]
pub struct TraceStats {
    specs_read: Counter,
    bytes_read: Counter,
    ingest_nanos: Counter,
    resident: Gauge,
}

impl Default for TraceStats {
    fn default() -> TraceStats {
        TraceStats {
            specs_read: Counter::detached(),
            bytes_read: Counter::detached(),
            ingest_nanos: Counter::detached(),
            resident: Gauge::detached(),
        }
    }
}

impl TraceStats {
    /// Stats wired to the registry's `trace_specs_read`,
    /// `trace_bytes_read`, `trace_ingest_nanos` counters and the
    /// `trace_ingest_resident` gauge.
    pub fn registered(r: &Registry) -> TraceStats {
        TraceStats {
            specs_read: r.counter("trace_specs_read"),
            bytes_read: r.counter("trace_bytes_read"),
            ingest_nanos: r.counter("trace_ingest_nanos"),
            resident: r.gauge("trace_ingest_resident"),
        }
    }

    /// Records decoded so far.
    pub fn specs_read(&self) -> u64 {
        self.specs_read.get()
    }

    /// Source bytes consumed so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.get()
    }

    /// Wall nanoseconds spent inside the reader (decode + I/O).
    pub fn ingest_nanos(&self) -> u64 {
        self.ingest_nanos.get()
    }

    /// Peak parser-resident bytes — the O(active) memory proof: stays
    /// near one read chunk regardless of trace length.
    pub fn resident_peak(&self) -> u64 {
        self.resident.get()
    }
}

/// Shared slot capturing the first decode error of an infallible spec
/// stream (see [`TraceReader::into_stream`]). Check after the run.
#[derive(Clone, Debug, Default)]
pub struct TraceErrorSlot(std::rc::Rc<std::cell::RefCell<Option<crate::errors::Error>>>);

impl TraceErrorSlot {
    fn park(&self, e: crate::errors::Error) {
        *self.0.borrow_mut() = Some(e);
    }

    /// The parked error, if the stream hit one.
    pub fn take(&self) -> Option<crate::errors::Error> {
        self.0.borrow_mut().take()
    }
}

/// Streaming trace reader: `Iterator<Item = Result<JobSpec>>` decoding
/// one spec per pull. Resident memory is O(one record): the tokenizer's
/// fixed chunk plus per-spec buffers (`resident_bytes` reports it).
pub struct TraceReader<R: Read> {
    parser: PullParser<R>,
    format: TraceFormat,
    records: u64,
    finished: bool,
    last_offset: u64,
    peak_resident: u64,
    stats: Option<TraceStats>,
}

impl TraceReader<std::fs::File> {
    /// Open a trace file, sniffing Array vs JSONL from the first byte.
    pub fn open(path: &Path) -> Result<TraceReader<std::fs::File>> {
        let file = std::fs::File::open(path)
            .with_context(|| anyhow!("opening trace {path:?}"))?;
        TraceReader::new(file)
    }
}

impl<R: Read> TraceReader<R> {
    /// Wrap any byte source, sniffing the format from the first
    /// structural byte: `[` is an Array trace, `{` a JSONL stream.
    pub fn new(src: R) -> Result<TraceReader<R>> {
        let mut parser = PullParser::new(src);
        let (format, finished) = match parser.sniff()? {
            Some(b'[') => (TraceFormat::Array, false),
            Some(b'{') => (TraceFormat::Jsonl, false),
            None => (TraceFormat::Jsonl, true),
            Some(_) => bail!("trace must be a JSON array or a JSONL stream"),
        };
        let mut r = TraceReader {
            parser,
            format,
            records: 0,
            finished,
            last_offset: 0,
            peak_resident: 0,
            stats: None,
        };
        if format == TraceFormat::Array && !finished {
            // consume the opening '[' so each iteration pulls one element
            match next_tok(&mut r.parser)? {
                Token::BeginArr => {}
                _ => bail!("trace must be a JSON array or a JSONL stream"),
            }
        }
        Ok(r)
    }

    /// The sniffed on-disk layout.
    pub fn format(&self) -> TraceFormat {
        self.format
    }

    /// Records decoded so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Source bytes consumed so far.
    pub fn bytes_read(&self) -> u64 {
        self.parser.offset() as u64
    }

    /// Bytes resident in the decode path right now — bounded by the
    /// tokenizer chunk plus the largest single token, never the trace.
    pub fn resident_bytes(&self) -> usize {
        self.parser.resident_bytes()
    }

    /// Attach ingest instrumentation (a clone of `stats` stays with the
    /// caller for export).
    pub fn install_stats(&mut self, stats: TraceStats) {
        self.stats = Some(stats);
    }

    /// Split into an infallible spec iterator (what the drivers'
    /// streaming constructors take) plus the slot that catches the
    /// first malformed-record error — check it after the run.
    pub fn into_stream(self) -> (Box<dyn Iterator<Item = JobSpec>>, TraceErrorSlot)
    where
        R: 'static,
    {
        let slot = TraceErrorSlot::default();
        let park = slot.clone();
        let it = self.map_while(move |item| match item {
            Ok(spec) => Some(spec),
            Err(e) => {
                park.park(e);
                None
            }
        });
        (Box::new(it), slot)
    }

    /// Pull one record; `Ok(None)` at a clean end of trace.
    fn pull_record(&mut self) -> Result<Option<JobSpec>> {
        match self.format {
            TraceFormat::Array => {
                enum Head {
                    End,
                    Obj,
                }
                let head = match next_tok(&mut self.parser)? {
                    Token::EndArr => Head::End,
                    Token::BeginObj => Head::Obj,
                    _ => bail!("trace record must be a JSON object"),
                };
                match head {
                    Head::End => {
                        // end-of-document state errors on trailing bytes
                        self.parser.next()?;
                        Ok(None)
                    }
                    Head::Obj => decode_spec_body(&mut self.parser).map(Some),
                }
            }
            TraceFormat::Jsonl => {
                if self.parser.at_eof()? {
                    return Ok(None);
                }
                if self.records > 0 {
                    self.parser.reset_document();
                }
                let opened = matches!(next_tok(&mut self.parser)?, Token::BeginObj);
                if !opened {
                    bail!("trace record must be a JSON object");
                }
                decode_spec_body(&mut self.parser).map(Some)
            }
        }
    }
}

impl<R: Read> Iterator for TraceReader<R> {
    type Item = Result<JobSpec>;

    fn next(&mut self) -> Option<Result<JobSpec>> {
        if self.finished {
            return None;
        }
        let sw = self.stats.as_ref().map(|_| Stopwatch::start());
        let pulled = self.pull_record();
        let out = match pulled {
            Ok(Some(spec)) => {
                self.records += 1;
                Some(Ok(spec))
            }
            Ok(None) => {
                self.finished = true;
                None
            }
            Err(e) => {
                self.finished = true;
                Some(Err(e))
            }
        };
        if let (Some(stats), Some(sw)) = (&self.stats, sw) {
            let offset = self.parser.offset() as u64;
            stats.bytes_read.add(offset - self.last_offset);
            self.last_offset = offset;
            stats.ingest_nanos.add(sw.elapsed_nanos());
            let resident = self.parser.resident_bytes() as u64;
            if resident > self.peak_resident {
                self.peak_resident = resident;
            }
            stats.resident.set(self.peak_resident);
            if matches!(out, Some(Ok(_))) {
                stats.specs_read.inc();
            }
        }
        out
    }
}

/// Pull the next token, treating a clean EOF as truncation.
fn next_tok<R: Read>(p: &mut PullParser<R>) -> Result<Token<'_>> {
    match p.next()? {
        Some(t) => Ok(t),
        None => Err(anyhow!("unexpected end of trace")),
    }
}

/// Which spec field a key names (tag first, then pull the value — the
/// borrowed key token cannot outlive the next parser call).
enum Field {
    Name,
    User,
    Pool,
    Queue,
    Class,
    PriorityIdx,
    Profile,
    MapWorks,
    ReduceWorks,
    SubmitTime,
    Unknown,
}

/// Decode the remainder of a spec object (its `BeginObj` is consumed).
fn decode_spec_body<R: Read>(p: &mut PullParser<R>) -> Result<JobSpec> {
    let mut name: Option<String> = None;
    let mut user: Option<String> = None;
    let mut pool: Option<String> = None;
    let mut queue: Option<String> = None;
    let mut class_name: Option<String> = None;
    let mut priority: Option<f64> = None;
    let mut profile: Option<Vec<f64>> = None;
    let mut map_works: Option<Vec<f64>> = None;
    let mut reduce_works: Option<Vec<f64>> = None;
    let mut submit_time: Option<f64> = None;
    loop {
        let field = match next_tok(p)? {
            Token::EndObj => break,
            Token::Key(k) => match k {
                "name" => Field::Name,
                "user" => Field::User,
                "pool" => Field::Pool,
                "queue" => Field::Queue,
                "class" => Field::Class,
                "priority" => Field::PriorityIdx,
                "profile" => Field::Profile,
                "map_works" => Field::MapWorks,
                "reduce_works" => Field::ReduceWorks,
                "submit_time" => Field::SubmitTime,
                _ => Field::Unknown,
            },
            _ => bail!("malformed trace record"),
        };
        match field {
            Field::Name => name = Some(read_str(p, "name")?),
            Field::User => user = Some(read_str(p, "user")?),
            Field::Pool => pool = Some(read_str(p, "pool")?),
            Field::Queue => queue = Some(read_str(p, "queue")?),
            Field::Class => class_name = Some(read_str(p, "class")?),
            Field::PriorityIdx => priority = Some(read_num(p, "priority")?),
            Field::Profile => profile = Some(read_nums(p, "profile")?),
            Field::MapWorks => map_works = Some(read_nums(p, "map_works")?),
            Field::ReduceWorks => reduce_works = Some(read_nums(p, "reduce_works")?),
            Field::SubmitTime => submit_time = Some(read_num(p, "submit_time")?),
            Field::Unknown => skip_value(p)?,
        }
    }
    let class_name = class_name.ok_or_else(|| anyhow!("missing string field 'class'"))?;
    let class = JobClass::from_name(&class_name)
        .ok_or_else(|| anyhow!("unknown job class '{class_name}'"))?;
    let prof = profile.ok_or_else(|| anyhow!("missing array field 'profile'"))?;
    if prof.len() != 4 {
        bail!("profile must have 4 entries");
    }
    let priority = priority
        .and_then(|f| Json::Num(f).as_u64())
        .ok_or_else(|| anyhow!("missing priority"))?;
    Ok(JobSpec {
        name: name.ok_or_else(|| anyhow!("missing string field 'name'"))?,
        user: user.ok_or_else(|| anyhow!("missing string field 'user'"))?,
        pool: pool.ok_or_else(|| anyhow!("missing string field 'pool'"))?,
        queue: queue.ok_or_else(|| anyhow!("missing string field 'queue'"))?,
        class,
        priority: Priority::from_index(priority as usize),
        profile: JobFeatures { cpu: prof[0], mem: prof[1], io: prof[2], net: prof[3] },
        map_works: map_works.ok_or_else(|| anyhow!("missing array field 'map_works'"))?,
        reduce_works: reduce_works
            .ok_or_else(|| anyhow!("missing array field 'reduce_works'"))?,
        submit_time: submit_time.ok_or_else(|| anyhow!("missing submit_time"))?,
    })
}

fn read_str<R: Read>(p: &mut PullParser<R>, k: &'static str) -> Result<String> {
    match next_tok(p)? {
        Token::Str(s) => Ok(s.to_owned()),
        _ => Err(anyhow!("missing string field '{k}'")),
    }
}

fn read_num<R: Read>(p: &mut PullParser<R>, k: &'static str) -> Result<f64> {
    match next_tok(p)? {
        Token::Num(n) => Ok(n),
        _ => Err(anyhow!("non-number field '{k}'")),
    }
}

fn read_nums<R: Read>(p: &mut PullParser<R>, k: &'static str) -> Result<Vec<f64>> {
    let opened = matches!(next_tok(p)?, Token::BeginArr);
    if !opened {
        bail!("missing array field '{k}'");
    }
    let mut out: Vec<f64> = Vec::with_capacity(8);
    loop {
        enum El {
            Num(f64),
            End,
        }
        let el = match next_tok(p)? {
            Token::Num(n) => El::Num(n),
            Token::EndArr => El::End,
            _ => bail!("non-number in '{k}'"),
        };
        match el {
            El::Num(n) => out.push(n),
            El::End => return Ok(out),
        }
    }
}

/// Skip one complete value of any shape (for unknown keys).
fn skip_value<R: Read>(p: &mut PullParser<R>) -> Result<()> {
    let mut depth = 0usize;
    loop {
        let done = match next_tok(p)? {
            Token::BeginArr | Token::BeginObj => {
                depth += 1;
                false
            }
            Token::EndArr | Token::EndObj => {
                depth -= 1;
                depth == 0
            }
            Token::Key(_) => false,
            _ => depth == 0,
        };
        if done {
            return Ok(());
        }
    }
}

/// Streaming trace writer: serializes one spec at a time through a
/// reused line buffer — no `Json` tree, O(one record) memory.
pub struct TraceWriter<W: Write> {
    out: W,
    format: TraceFormat,
    count: u64,
    line: String,
}

impl<W: Write> TraceWriter<W> {
    pub fn new(out: W, format: TraceFormat) -> TraceWriter<W> {
        TraceWriter { out, format, count: 0, line: String::with_capacity(256) }
    }

    /// Append one spec.
    pub fn write_spec(&mut self, s: &JobSpec) -> Result<()> {
        self.line.clear();
        match self.format {
            TraceFormat::Array => {
                self.line.push_str(if self.count == 0 { "[\n  " } else { ",\n  " });
                append_spec(&mut self.line, s);
            }
            TraceFormat::Jsonl => {
                append_spec(&mut self.line, s);
                self.line.push('\n');
            }
        }
        self.out.write_all(self.line.as_bytes()).context("writing trace")?;
        self.count += 1;
        Ok(())
    }

    /// Close the trace (writes the array terminator) and flush.
    pub fn finish(mut self) -> Result<u64> {
        if self.format == TraceFormat::Array {
            let tail: &[u8] = if self.count == 0 { b"[]\n" } else { b"\n]\n" };
            self.out.write_all(tail).context("writing trace")?;
        }
        self.out.flush().context("writing trace")?;
        Ok(self.count)
    }
}

/// Serialize one spec compactly, keys in the historical (alphabetical)
/// order, reusing the shared number/string writers from `config/json`.
fn append_spec(out: &mut String, s: &JobSpec) {
    out.push_str("{\"class\":");
    json::write_escaped(out, s.class.name());
    out.push_str(",\"map_works\":");
    append_nums(out, &s.map_works);
    out.push_str(",\"name\":");
    json::write_escaped(out, &s.name);
    out.push_str(",\"pool\":");
    json::write_escaped(out, &s.pool);
    out.push_str(",\"priority\":");
    json::write_num(out, s.priority as i32 as f64);
    out.push_str(",\"profile\":[");
    json::write_num(out, s.profile.cpu);
    out.push(',');
    json::write_num(out, s.profile.mem);
    out.push(',');
    json::write_num(out, s.profile.io);
    out.push(',');
    json::write_num(out, s.profile.net);
    out.push_str("],\"queue\":");
    json::write_escaped(out, &s.queue);
    out.push_str(",\"reduce_works\":");
    append_nums(out, &s.reduce_works);
    out.push_str(",\"submit_time\":");
    json::write_num(out, s.submit_time);
    out.push_str(",\"user\":");
    json::write_escaped(out, &s.user);
    out.push('}');
}

fn append_nums(out: &mut String, xs: &[f64]) {
    out.push('[');
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        json::write_num(out, *x);
    }
    out.push(']');
}

/// Save a materialized trace in the Array format (historical API).
pub fn save(specs: &[JobSpec], path: &Path) -> Result<()> {
    save_stream(specs.iter().cloned(), path, TraceFormat::Array).map(|_| ())
}

/// Stream specs to disk in either format without materializing them;
/// returns the record count.
pub fn save_stream<I>(specs: I, path: &Path, format: TraceFormat) -> Result<u64>
where
    I: IntoIterator<Item = JobSpec>,
{
    let file = std::fs::File::create(path)
        .with_context(|| anyhow!("creating trace {path:?}"))?;
    let mut w = TraceWriter::new(std::io::BufWriter::new(file), format);
    for spec in specs {
        w.write_spec(&spec)?;
    }
    w.finish()
}

/// Load a whole trace into memory (historical API; replay paths should
/// prefer [`TraceReader`] + the drivers' streaming constructors).
pub fn load(path: &Path) -> Result<Vec<JobSpec>> {
    TraceReader::open(path)?.collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::{generate, WorkloadConfig};

    fn to_text(specs: &[JobSpec], format: TraceFormat) -> String {
        let mut buf: Vec<u8> = Vec::new();
        let mut w = TraceWriter::new(&mut buf, format);
        for s in specs {
            w.write_spec(s).unwrap();
        }
        w.finish().unwrap();
        String::from_utf8(buf).unwrap()
    }

    fn decode(text: &str) -> Result<Vec<JobSpec>> {
        TraceReader::new(text.as_bytes())?.collect()
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let specs = generate(&WorkloadConfig { n_jobs: 30, ..Default::default() });
        for format in [TraceFormat::Array, TraceFormat::Jsonl] {
            let parsed = decode(&to_text(&specs, format)).unwrap();
            assert_eq!(specs.len(), parsed.len());
            for (a, b) in specs.iter().zip(&parsed) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.user, b.user);
                assert_eq!(a.pool, b.pool);
                assert_eq!(a.queue, b.queue);
                assert_eq!(a.class, b.class);
                assert_eq!(a.priority, b.priority);
                assert_eq!(a.map_works, b.map_works);
                assert_eq!(a.reduce_works, b.reduce_works);
                assert_eq!(a.submit_time, b.submit_time);
                // all four profile fields, not just cpu
                assert!((a.profile.cpu - b.profile.cpu).abs() < 1e-12);
                assert!((a.profile.mem - b.profile.mem).abs() < 1e-12);
                assert!((a.profile.io - b.profile.io).abs() < 1e-12);
                assert!((a.profile.net - b.profile.net).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn array_output_is_valid_json_and_the_old_parser_agrees() {
        let specs = generate(&WorkloadConfig { n_jobs: 4, ..Default::default() });
        let text = to_text(&specs, TraceFormat::Array);
        let tree = Json::parse(&text).unwrap();
        assert_eq!(tree.as_arr().unwrap().len(), 4);
        assert_eq!(
            tree.as_arr().unwrap()[0].get("name").unwrap().as_str().unwrap(),
            specs[0].name
        );
    }

    #[test]
    fn file_roundtrip() {
        let specs = generate(&WorkloadConfig { n_jobs: 5, ..Default::default() });
        let path = std::env::temp_dir().join("bayes_sched_trace_test.json");
        save(&specs, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 5);
        assert_eq!(loaded[0].name, specs[0].name);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_file_roundtrip_and_sniffing() {
        let specs = generate(&WorkloadConfig { n_jobs: 7, ..Default::default() });
        let path = std::env::temp_dir().join("bayes_sched_trace_test.jsonl");
        let n = save_stream(specs.iter().cloned(), &path, TraceFormat::Jsonl).unwrap();
        assert_eq!(n, 7);
        let mut r = TraceReader::open(&path).unwrap();
        assert_eq!(r.format(), TraceFormat::Jsonl);
        let loaded: Vec<JobSpec> = r.by_ref().collect::<Result<_>>().unwrap();
        assert_eq!(loaded.len(), 7);
        assert_eq!(loaded[6].name, specs[6].name);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_traces_parse_in_both_formats() {
        assert_eq!(decode("[]").unwrap().len(), 0);
        assert_eq!(decode("").unwrap().len(), 0);
        assert_eq!(decode("  \n ").unwrap().len(), 0);
    }

    #[test]
    fn rejects_malformed() {
        // scalar root: neither format
        assert!(TraceReader::new(&b"42"[..]).is_err());
        // wrong shapes fuse at the offending record
        assert!(decode(r#"{"not": "a spec"}"#).is_err());
        assert!(decode(r#"[{"name": "x"}]"#).is_err());
        assert!(decode(r#"[[1,2]]"#).is_err());
        // truncated array
        assert!(decode(r#"[{"name":"x""#).is_err());
    }

    #[test]
    fn error_at_record_granularity() {
        let specs = generate(&WorkloadConfig { n_jobs: 3, ..Default::default() });
        let mut text = to_text(&specs, TraceFormat::Jsonl);
        text.push_str("{\"broken\": true}\n");
        let items: Vec<Result<JobSpec>> =
            TraceReader::new(text.as_bytes()).unwrap().collect();
        assert_eq!(items.len(), 4);
        assert!(items[..3].iter().all(|r| r.is_ok()));
        assert!(items[3].is_err(), "bad record surfaces as Err");
    }

    #[test]
    fn into_stream_parks_the_error_and_stats_count() {
        let specs = generate(&WorkloadConfig { n_jobs: 3, ..Default::default() });
        let mut text = to_text(&specs, TraceFormat::Jsonl);
        text.push_str("{\"broken\": true}\n");
        let owned: Vec<u8> = text.into_bytes();
        let mut reader = TraceReader::new(std::io::Cursor::new(owned)).unwrap();
        let stats = TraceStats::default();
        reader.install_stats(stats.clone());
        let (stream, slot) = reader.into_stream();
        assert_eq!(stream.count(), 3, "good prefix streams through");
        assert!(slot.take().is_some(), "the broken record is parked");
        assert_eq!(stats.specs_read(), 3);
        assert!(stats.bytes_read() > 0);
        assert!(stats.resident_peak() > 0);
    }

    #[test]
    fn unknown_fields_are_skipped() {
        let specs = generate(&WorkloadConfig { n_jobs: 1, ..Default::default() });
        let mut text = to_text(&specs, TraceFormat::Jsonl);
        // graft unknown scalar + container fields into the record
        text = text.replacen(
            "{\"class\":",
            "{\"x_meta\":{\"a\":[1,2,{\"b\":null}]},\"x_tag\":\"v\",\"class\":",
            1,
        );
        let parsed = decode(&text).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].name, specs[0].name);
    }

    #[test]
    fn resident_memory_stays_bounded() {
        let specs = generate(&WorkloadConfig { n_jobs: 200, ..Default::default() });
        let text = to_text(&specs, TraceFormat::Jsonl);
        let total = text.len();
        let mut r = TraceReader::new(text.as_bytes()).unwrap();
        let mut peak = 0usize;
        while let Some(item) = r.next() {
            item.unwrap();
            peak = peak.max(r.resident_bytes());
        }
        assert!(
            peak < total / 2,
            "decode path resident {peak} must stay far below the {total}-byte trace"
        );
    }
}
