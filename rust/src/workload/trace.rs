//! Trace serialization: persist generated workloads as JSON so experiments
//! can replay the exact same job stream across schedulers and seeds.

use std::path::Path;

use crate::errors::{anyhow, Context, Result};

use crate::bayes::features::JobFeatures;
use crate::bayes::utility::Priority;
use crate::config::json::Json;
use crate::job::job::JobSpec;
use crate::job::profile::JobClass;

/// Serialize one spec.
fn spec_to_json(s: &JobSpec) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("name".into(), Json::Str(s.name.clone()));
    o.insert("user".into(), Json::Str(s.user.clone()));
    o.insert("pool".into(), Json::Str(s.pool.clone()));
    o.insert("queue".into(), Json::Str(s.queue.clone()));
    o.insert("class".into(), Json::Str(s.class.name().into()));
    o.insert("priority".into(), Json::Num(s.priority as i32 as f64));
    o.insert(
        "profile".into(),
        Json::Arr(vec![
            Json::Num(s.profile.cpu),
            Json::Num(s.profile.mem),
            Json::Num(s.profile.io),
            Json::Num(s.profile.net),
        ]),
    );
    o.insert(
        "map_works".into(),
        Json::Arr(s.map_works.iter().map(|w| Json::Num(*w)).collect()),
    );
    o.insert(
        "reduce_works".into(),
        Json::Arr(s.reduce_works.iter().map(|w| Json::Num(*w)).collect()),
    );
    o.insert("submit_time".into(), Json::Num(s.submit_time));
    Json::Obj(o)
}

fn spec_from_json(j: &Json) -> Result<JobSpec> {
    let str_field = |k: &str| -> Result<String> {
        Ok(j.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing string field '{k}'"))?
            .to_string())
    };
    let f64s = |k: &str| -> Result<Vec<f64>> {
        j.get(k)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing array field '{k}'"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-number in '{k}'")))
            .collect()
    };
    let class_name = str_field("class")?;
    let class = JobClass::from_name(&class_name)
        .ok_or_else(|| anyhow!("unknown job class '{class_name}'"))?;
    let prof = f64s("profile")?;
    if prof.len() != 4 {
        return Err(anyhow!("profile must have 4 entries"));
    }
    let priority = j
        .get("priority")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing priority"))?;
    Ok(JobSpec {
        name: str_field("name")?,
        user: str_field("user")?,
        pool: str_field("pool")?,
        queue: str_field("queue")?,
        class,
        priority: Priority::from_index(priority as usize),
        profile: JobFeatures { cpu: prof[0], mem: prof[1], io: prof[2], net: prof[3] },
        map_works: f64s("map_works")?,
        reduce_works: f64s("reduce_works")?,
        submit_time: j
            .get("submit_time")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing submit_time"))?,
    })
}

/// Serialize a whole trace.
pub fn to_json(specs: &[JobSpec]) -> Json {
    Json::Arr(specs.iter().map(spec_to_json).collect())
}

/// Parse a whole trace.
pub fn from_json(j: &Json) -> Result<Vec<JobSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("trace must be a JSON array"))?
        .iter()
        .map(spec_from_json)
        .collect()
}

pub fn save(specs: &[JobSpec], path: &Path) -> Result<()> {
    std::fs::write(path, to_json(specs).to_string_pretty())
        .with_context(|| format!("writing trace {path:?}"))
}

pub fn load(path: &Path) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path:?}"))?;
    from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::{generate, WorkloadConfig};

    #[test]
    fn roundtrip_preserves_everything() {
        let specs = generate(&WorkloadConfig { n_jobs: 30, ..Default::default() });
        let parsed = from_json(&Json::parse(&to_json(&specs).to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(specs.len(), parsed.len());
        for (a, b) in specs.iter().zip(&parsed) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.user, b.user);
            assert_eq!(a.class, b.class);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.map_works, b.map_works);
            assert_eq!(a.reduce_works, b.reduce_works);
            assert_eq!(a.submit_time, b.submit_time);
            assert!((a.profile.cpu - b.profile.cpu).abs() < 1e-12);
        }
    }

    #[test]
    fn file_roundtrip() {
        let specs = generate(&WorkloadConfig { n_jobs: 5, ..Default::default() });
        let path = std::env::temp_dir().join("bayes_sched_trace_test.json");
        save(&specs, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 5);
        assert_eq!(loaded[0].name, specs[0].name);
    }

    #[test]
    fn rejects_malformed() {
        assert!(from_json(&Json::parse(r#"{"not": "array"}"#).unwrap()).is_err());
        assert!(from_json(&Json::parse(r#"[{"name": "x"}]"#).unwrap()).is_err());
    }
}
