//! PJRT runtime: loads the AOT artifacts produced by `make artifacts` and
//! executes them from the coordinator hot path. Python is never involved at
//! runtime — the HLO text files are self-contained.
//!
//! * [`artifacts`] — manifest parsing + shape validation.
//! * [`client`] — PJRT CPU client wrapper, one executable per entry point.
//! * [`classifier`] — [`classifier::XlaClassifier`], the drop-in XLA-backed
//!   implementation of the Bayes classifier interface.

pub mod artifacts;
pub mod classifier;
pub mod client;

pub use artifacts::{Manifest, ShapeConstants};
pub use classifier::XlaClassifier;
pub use client::{ClassifyOut, Runtime, UpdateOut};
