//! PJRT runtime: loads the AOT artifacts produced by `make artifacts` and
//! executes them from the coordinator hot path. Python is never involved at
//! runtime — the HLO text files are self-contained.
//!
//! * [`artifacts`] — manifest parsing + shape validation (always built).
//! * `client` — PJRT CPU client wrapper, one executable per entry point.
//! * `classifier` — `XlaClassifier`, the drop-in XLA-backed implementation
//!   of the Bayes classifier interface.
//!
//! The PJRT pieces need the external `xla` crate, which the offline build
//! image does not ship, so they are gated behind the `xla-runtime` cargo
//! feature (see `rust/Cargo.toml`). Without the feature, [`stub`] provides
//! API-compatible `Runtime` / `XlaClassifier` types whose `load` fails with
//! an actionable message — `repro info` and the `bayes-xla` scheduler
//! degrade gracefully instead of breaking the build.

pub mod artifacts;
#[cfg(feature = "xla-runtime")]
pub mod classifier;
#[cfg(feature = "xla-runtime")]
pub mod client;
#[cfg(not(feature = "xla-runtime"))]
pub mod stub;

pub use artifacts::{Manifest, ShapeConstants};
#[cfg(feature = "xla-runtime")]
pub use classifier::XlaClassifier;
#[cfg(feature = "xla-runtime")]
pub use client::{ClassifyOut, Runtime, UpdateOut};
#[cfg(not(feature = "xla-runtime"))]
pub use stub::{Runtime, XlaClassifier};
