//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! One `Runtime` owns the PJRT CPU client plus one compiled executable per
//! entry point. Loading happens once at startup (`Runtime::load`); the
//! coordinator hot path only calls `classify_raw` / `update_raw`, which
//! never touch python.

use std::path::Path;

use crate::errors::{bail, Context, Result};

use super::artifacts::{Manifest, ShapeConstants};

/// Compiled artifacts, ready to execute.
pub struct Runtime {
    client: xla::PjRtClient,
    classify_exe: xla::PjRtLoadedExecutable,
    update_exe: xla::PjRtLoadedExecutable,
    pub consts: ShapeConstants,
}

/// Outputs of one classify execution over the padded job queue.
#[derive(Debug, Clone)]
pub struct ClassifyOut {
    /// P(good | features) per queue slot.
    pub p_good: Vec<f32>,
    /// Masked expected utility per slot (-1e30 on padding).
    pub score: Vec<f32>,
    /// Argmax slot index.
    pub best: i32,
}

/// Outputs of one update execution (new classifier state).
#[derive(Debug, Clone)]
pub struct UpdateOut {
    pub counts: Vec<f32>,
    pub class_counts: Vec<f32>,
    pub log_prior: Vec<f32>,
    pub log_lik: Vec<f32>,
}

impl Runtime {
    /// Load + compile both entry points from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir).context("loading artifact manifest")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let classify_exe = compile(&client, &manifest.classify.path)?;
        let update_exe = compile(&client, &manifest.update.path)?;
        Ok(Runtime { client, classify_exe, update_exe, consts: manifest.constants })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload the model tables once; reuse the returned device buffers for
    /// many [`Runtime::classify_buffers`] calls (perf: the tables only
    /// change on feedback flush, so re-transferring them per decision was
    /// ~40% of the call cost — see EXPERIMENTS.md §Perf).
    pub fn upload_tables(
        &self,
        log_prior: &[f32],
        log_lik: &[f32],
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let c = self.consts;
        check_len("log_prior", log_prior.len(), c.n_classes)?;
        check_len("log_lik", log_lik.len(), c.n_classes * c.feature_dim)?;
        let prior = self
            .client
            .buffer_from_host_buffer(log_prior, &[c.n_classes], None)?;
        let lik = self.client.buffer_from_host_buffer(
            log_lik,
            &[c.n_classes, c.feature_dim],
            None,
        )?;
        Ok((prior, lik))
    }

    /// Hot-path classify: pre-uploaded table buffers + direct host→device
    /// transfer of the per-call inputs (no Literal intermediates), executed
    /// via `execute_b`.
    pub fn classify_buffers(
        &self,
        tables: &(xla::PjRtBuffer, xla::PjRtBuffer),
        feats: &[i32],
        utility: &[f32],
        mask: &[f32],
    ) -> Result<ClassifyOut> {
        let c = self.consts;
        check_len("feats", feats.len(), c.max_jobs * c.n_features)?;
        check_len("utility", utility.len(), c.max_jobs)?;
        check_len("mask", mask.len(), c.max_jobs)?;
        let feats_b = self.client.buffer_from_host_buffer(
            feats,
            &[c.max_jobs, c.n_features],
            None,
        )?;
        let utility_b = self.client.buffer_from_host_buffer(utility, &[c.max_jobs], None)?;
        let mask_b = self.client.buffer_from_host_buffer(mask, &[c.max_jobs], None)?;
        let args = [&tables.0, &tables.1, &feats_b, &utility_b, &mask_b];
        let result = self.classify_exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let (p_good, score, best) = result.to_tuple3()?;
        Ok(ClassifyOut {
            p_good: p_good.to_vec::<f32>()?,
            score: score.to_vec::<f32>()?,
            best: best.to_vec::<i32>()?[0],
        })
    }

    /// Perf-diagnostic: just the three per-call host→device transfers of
    /// `classify_buffers`, without execution (used by the p1 bench to
    /// attribute hot-path cost).
    pub fn upload_inputs_probe(
        &self,
        feats: &[i32],
        utility: &[f32],
        mask: &[f32],
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)> {
        let c = self.consts;
        Ok((
            self.client
                .buffer_from_host_buffer(feats, &[c.max_jobs, c.n_features], None)?,
            self.client.buffer_from_host_buffer(utility, &[c.max_jobs], None)?,
            self.client.buffer_from_host_buffer(mask, &[c.max_jobs], None)?,
        ))
    }

    /// Execute the classify artifact on raw padded buffers.
    ///
    /// Buffer lengths must match the manifest shapes exactly
    /// (`log_prior`: C, `log_lik`: C*FB, `feats`: N*F row-major,
    /// `utility`/`mask`: N).
    pub fn classify_raw(
        &self,
        log_prior: &[f32],
        log_lik: &[f32],
        feats: &[i32],
        utility: &[f32],
        mask: &[f32],
    ) -> Result<ClassifyOut> {
        let c = self.consts;
        check_len("log_prior", log_prior.len(), c.n_classes)?;
        check_len("log_lik", log_lik.len(), c.n_classes * c.feature_dim)?;
        check_len("feats", feats.len(), c.max_jobs * c.n_features)?;
        check_len("utility", utility.len(), c.max_jobs)?;
        check_len("mask", mask.len(), c.max_jobs)?;

        let args = [
            xla::Literal::vec1(log_prior),
            xla::Literal::vec1(log_lik)
                .reshape(&[c.n_classes as i64, c.feature_dim as i64])?,
            xla::Literal::vec1(feats)
                .reshape(&[c.max_jobs as i64, c.n_features as i64])?,
            xla::Literal::vec1(utility),
            xla::Literal::vec1(mask),
        ];
        let result = self.classify_exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (p_good, score, best) = result.to_tuple3()?;
        Ok(ClassifyOut {
            p_good: p_good.to_vec::<f32>()?,
            score: score.to_vec::<f32>()?,
            best: best.to_vec::<i32>()?[0],
        })
    }

    /// Execute the update artifact on raw padded buffers.
    pub fn update_raw(
        &self,
        counts: &[f32],
        class_counts: &[f32],
        feats: &[i32],
        labels: &[i32],
        mask: &[f32],
        alpha: f32,
    ) -> Result<UpdateOut> {
        let c = self.consts;
        check_len("counts", counts.len(), c.n_classes * c.feature_dim)?;
        check_len("class_counts", class_counts.len(), c.n_classes)?;
        check_len("feats", feats.len(), c.max_batch * c.n_features)?;
        check_len("labels", labels.len(), c.max_batch)?;
        check_len("mask", mask.len(), c.max_batch)?;

        let args = [
            xla::Literal::vec1(counts)
                .reshape(&[c.n_classes as i64, c.feature_dim as i64])?,
            xla::Literal::vec1(class_counts),
            xla::Literal::vec1(feats)
                .reshape(&[c.max_batch as i64, c.n_features as i64])?,
            xla::Literal::vec1(labels),
            xla::Literal::vec1(mask),
            xla::Literal::scalar(alpha),
        ];
        let result = self.update_exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (counts, class_counts, log_prior, log_lik) = result.to_tuple4()?;
        Ok(UpdateOut {
            counts: counts.to_vec::<f32>()?,
            class_counts: class_counts.to_vec::<f32>()?,
            log_prior: log_prior.to_vec::<f32>()?,
            log_lik: log_lik.to_vec::<f32>()?,
        })
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    // HLO *text* interchange: the text parser reassigns instruction ids, so
    // jax>=0.5 modules load on xla_extension 0.5.1 (see DESIGN.md §2).
    let path_str = path
        .to_str()
        .with_context(|| format!("non-utf8 artifact path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {path:?} on PJRT"))
}

fn check_len(name: &str, got: usize, want: usize) -> Result<()> {
    if got != want {
        bail!("buffer '{name}' has length {got}, artifact expects {want}");
    }
    Ok(())
}
