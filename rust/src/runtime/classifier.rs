//! XLA-backed [`Classifier`]: the coordinator hot path executing the
//! AOT-compiled Pallas/JAX artifacts through PJRT.
//!
//! Semantics mirror [`crate::bayes::NaiveBayes`] exactly (same buffering,
//! same Laplace smoothing — the smoothing lives *inside* the update
//! artifact), so the two are interchangeable behind the trait and must
//! agree to f32 tolerance.

use std::path::Path;

use crate::errors::Result;

use crate::bayes::classifier::{
    Classifier, ClassifyResult, Label, FEATURE_DIM, MAX_BATCH, MAX_JOBS,
};
use crate::bayes::features::FeatureVec;

use super::client::Runtime;

/// Classifier state held rust-side between artifact executions.
pub struct XlaClassifier {
    rt: Runtime,
    counts: Vec<f32>,       // [2 * FEATURE_DIM]
    class_counts: Vec<f32>, // [2]
    log_prior: Vec<f32>,    // [2]
    log_lik: Vec<f32>,      // [2 * FEATURE_DIM]
    /// Device-resident copies of (log_prior, log_lik); invalidated on
    /// flush, lazily re-uploaded at the next classify (perf §Perf).
    table_bufs: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    alpha: f32,
    pending: Vec<(FeatureVec, Label)>,
    // preallocated padded buffers (hot path: zero allocation per call)
    feats_buf: Vec<i32>,
    utility_buf: Vec<f32>,
    mask_buf: Vec<f32>,
    batch_feats: Vec<i32>,
    batch_labels: Vec<i32>,
    batch_mask: Vec<f32>,
}

impl XlaClassifier {
    /// Load artifacts from `dir` and initialize an empty model.
    pub fn load(dir: &Path, alpha: f32) -> Result<XlaClassifier> {
        let rt = Runtime::load(dir)?;
        let consts = rt.consts;
        assert_eq!(consts.feature_dim, FEATURE_DIM);
        assert_eq!(consts.max_jobs, MAX_JOBS);
        assert_eq!(consts.max_batch, MAX_BATCH);
        let mut xc = XlaClassifier {
            rt,
            counts: vec![0.0; 2 * FEATURE_DIM],
            class_counts: vec![0.0; 2],
            log_prior: vec![0.0; 2],
            log_lik: vec![0.0; 2 * FEATURE_DIM],
            table_bufs: None,
            alpha,
            pending: Vec::with_capacity(MAX_BATCH),
            feats_buf: vec![0; MAX_JOBS * crate::bayes::N_FEATURES],
            utility_buf: vec![0.0; MAX_JOBS],
            mask_buf: vec![0.0; MAX_JOBS],
            batch_feats: vec![0; MAX_BATCH * crate::bayes::N_FEATURES],
            batch_labels: vec![0; MAX_BATCH],
            batch_mask: vec![0.0; MAX_BATCH],
        };
        // Derive the initial (uniform-prior) tables by pushing an empty
        // batch through the update artifact — keeps ALL smoothing math in
        // one place (the artifact), so rust never re-implements it.
        xc.run_update_batch(0)?;
        Ok(xc)
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    /// Apply `n` samples currently staged in batch_* buffers.
    fn run_update_batch(&mut self, n: usize) -> Result<()> {
        debug_assert!(n <= MAX_BATCH);
        for m in self.batch_mask.iter_mut().take(n) {
            *m = 1.0;
        }
        for m in self.batch_mask.iter_mut().skip(n) {
            *m = 0.0;
        }
        let out = self.rt.update_raw(
            &self.counts,
            &self.class_counts,
            &self.batch_feats,
            &self.batch_labels,
            &self.batch_mask,
            self.alpha,
        )?;
        self.counts = out.counts;
        self.class_counts = out.class_counts;
        self.log_prior = out.log_prior;
        self.log_lik = out.log_lik;
        self.table_bufs = None; // tables changed: device copy is stale
        Ok(())
    }

    fn flush_inner(&mut self) -> Result<()> {
        while !self.pending.is_empty() {
            let take = self.pending.len().min(MAX_BATCH);
            for (i, (fv, label)) in self.pending.drain(..take).enumerate() {
                for (j, &b) in fv.iter().enumerate() {
                    self.batch_feats[i * crate::bayes::N_FEATURES + j] = b as i32;
                }
                self.batch_labels[i] = label as i32;
            }
            self.run_update_batch(take)?;
        }
        Ok(())
    }

    /// Raw model state, same layout as [`crate::bayes::NaiveBayes::state`].
    pub fn state(&self) -> (&[f32], [f32; 2]) {
        (&self.counts, [self.class_counts[0], self.class_counts[1]])
    }
}

impl Classifier for XlaClassifier {
    fn classify(&mut self, feats: &[FeatureVec], utility: &[f32]) -> ClassifyResult {
        assert!(!feats.is_empty() && feats.len() <= MAX_JOBS);
        assert_eq!(feats.len(), utility.len());
        self.flush();
        let n = feats.len();
        for (i, fv) in feats.iter().enumerate() {
            for (j, &b) in fv.iter().enumerate() {
                self.feats_buf[i * crate::bayes::N_FEATURES + j] = b as i32;
            }
        }
        // zero the padding rows (stale bins would still be masked, but keep
        // the buffers deterministic)
        for v in self.feats_buf[n * crate::bayes::N_FEATURES..].iter_mut() {
            *v = 0;
        }
        self.utility_buf[..n].copy_from_slice(utility);
        self.utility_buf[n..].fill(0.0);
        self.mask_buf[..n].fill(1.0);
        self.mask_buf[n..].fill(0.0);
        if self.table_bufs.is_none() {
            self.table_bufs = Some(
                self.rt
                    .upload_tables(&self.log_prior, &self.log_lik)
                    // a PJRT fault mid-run is unrecoverable by design
                    // lint: allow(unwrap-in-lib)
                    .expect("uploading classifier tables failed"),
            );
        }
        let out = self
            .rt
            .classify_buffers(
                // Some by construction above -- lint: allow(unwrap-in-lib)
                self.table_bufs.as_ref().unwrap(),
                &self.feats_buf,
                &self.utility_buf,
                &self.mask_buf,
            )
            // lint: allow(unwrap-in-lib)
            .expect("classify artifact execution failed");
        ClassifyResult {
            p_good: out.p_good[..n].to_vec(),
            score: out.score[..n].to_vec(),
            best: out.best as usize,
        }
    }

    fn observe(&mut self, feats: FeatureVec, label: Label) {
        self.pending.push((feats, label));
        if self.pending.len() >= MAX_BATCH {
            self.flush();
        }
    }

    fn flush(&mut self) {
        // a PJRT fault mid-run is unrecoverable -- lint: allow(unwrap-in-lib)
        self.flush_inner().expect("update artifact execution failed");
    }

    fn class_counts(&self) -> [f32; 2] {
        [self.class_counts[0], self.class_counts[1]]
    }

    fn name(&self) -> &'static str {
        "naive-bayes(xla)"
    }

    fn export_state(&self) -> (Vec<f32>, [f32; 2], f32) {
        (
            self.counts.clone(),
            [self.class_counts[0], self.class_counts[1]],
            self.alpha,
        )
    }
}
