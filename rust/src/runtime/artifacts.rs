//! Artifact manifest: shape constants + entry-point descriptors emitted by
//! `python/compile/aot.py` alongside the HLO text files.
//!
//! The rust side validates the manifest's constants against what it was
//! compiled to expect, so a stale `artifacts/` directory fails loudly at
//! load time instead of producing shape errors (or silent garbage) at
//! execute time.

use std::path::{Path, PathBuf};

use crate::config::json::Json;

/// Shape constants the classifier artifacts were lowered with.
/// Mirror of `python/compile/constants.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeConstants {
    pub max_jobs: usize,
    pub n_features: usize,
    pub n_bins: usize,
    pub n_classes: usize,
    pub max_batch: usize,
    pub feature_dim: usize,
}

/// The constants this build of the rust coordinator expects. `n_features`
/// and `feature_dim` must track `bayes::features::N_FEATURES` (the
/// n-features-sync lint cross-checks this file, `features.rs`, and
/// `python/compile/constants.py`).
pub const EXPECTED: ShapeConstants = ShapeConstants {
    max_jobs: 256,
    n_features: 10,
    n_bins: 10,
    n_classes: 2,
    max_batch: 128,
    feature_dim: 100,
};

/// One AOT entry point (an HLO text file).
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub path: PathBuf,
    pub sha256: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub constants: ShapeConstants,
    pub classify: Entry,
    pub update: Entry,
}

#[derive(Debug)]
pub enum ManifestError {
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    Parse(String),
    ShapeMismatch {
        found: Box<ShapeConstants>,
        expected: Box<ShapeConstants>,
    },
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io { path, source } => {
                write!(f, "cannot read {}: {source}", path.display())
            }
            ManifestError::Parse(msg) => write!(f, "manifest parse error: {msg}"),
            ManifestError::ShapeMismatch { found, expected } => write!(
                f,
                "artifact shape mismatch: artifacts were lowered with {found:?} \
                 but this binary expects {expected:?}; re-run `make artifacts`"
            ),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| ManifestError::Io { path: mpath.clone(), source: e })?;
        let json = Json::parse(&text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let consts = json
            .get("constants")
            .ok_or_else(|| ManifestError::Parse("missing 'constants'".into()))?;
        let get = |k: &str| -> Result<usize, ManifestError> {
            consts
                .get(k)
                .and_then(Json::as_u64)
                .map(|v| v as usize)
                .ok_or_else(|| ManifestError::Parse(format!("missing constant '{k}'")))
        };
        let constants = ShapeConstants {
            max_jobs: get("max_jobs")?,
            n_features: get("n_features")?,
            n_bins: get("n_bins")?,
            n_classes: get("n_classes")?,
            max_batch: get("max_batch")?,
            feature_dim: get("feature_dim")?,
        };
        if constants != EXPECTED {
            return Err(ManifestError::ShapeMismatch {
                found: Box::new(constants),
                expected: Box::new(EXPECTED),
            });
        }
        let entry = |name: &str| -> Result<Entry, ManifestError> {
            let e = json
                .get("entries")
                .and_then(|es| es.get(name))
                .ok_or_else(|| ManifestError::Parse(format!("missing entry '{name}'")))?;
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError::Parse(format!("entry '{name}' missing file")))?;
            Ok(Entry {
                name: name.to_string(),
                path: dir.join(file),
                sha256: e
                    .get("sha256")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
            })
        };
        Ok(Manifest {
            constants,
            classify: entry("classify")?,
            update: entry("update")?,
        })
    }
}

/// Default artifacts directory: `$BAYES_SCHED_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("BAYES_SCHED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // one-line string literals only: the lint scanner's test-region brace
    // counter does not track multi-line raw strings
    fn write_manifest(dir: &Path, max_jobs: usize) {
        let mut text = format!("{{\"constants\": {{\"max_jobs\": {max_jobs},");
        text.push_str(" \"n_features\": 10, \"n_bins\": 10, \"n_classes\": 2,");
        text.push_str(" \"max_batch\": 128, \"feature_dim\": 100},");
        text.push_str(" \"entries\": {\"classify\": {\"file\": \"classify.hlo.txt\",");
        text.push_str(" \"sha256\": \"aa\"}, \"update\": {\"file\": \"update.hlo.txt\",");
        text.push_str(" \"sha256\": \"bb\"}}}");
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("bayes_sched_manifest_ok");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 256);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.constants, EXPECTED);
        assert!(m.classify.path.ends_with("classify.hlo.txt"));
        assert_eq!(m.update.sha256, "bb");
    }

    #[test]
    fn rejects_shape_mismatch() {
        let dir = std::env::temp_dir().join("bayes_sched_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, 512);
        match Manifest::load(&dir) {
            Err(ManifestError::ShapeMismatch { .. }) => {}
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
    }

    #[test]
    fn missing_dir_is_io_error() {
        match Manifest::load(Path::new("/nonexistent/nowhere")) {
            Err(ManifestError::Io { .. }) => {}
            other => panic!("expected Io, got {other:?}"),
        }
    }
}
