//! API-compatible stand-ins for the PJRT runtime when the crate is built
//! without the `xla-runtime` feature (the default in the offline image).
//! Loading fails with an actionable message; the methods that can only be
//! reached through a successfully loaded instance are unreachable.

use std::path::Path;

use crate::bayes::classifier::{Classifier, ClassifyResult, Label};
use crate::bayes::features::FeatureVec;
use crate::errors::{anyhow, Result};

const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the \
    `xla-runtime` feature (add the `xla` dependency in rust/Cargo.toml and \
    build with `--features xla-runtime`)";

/// Stub for `runtime::client::Runtime`.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn load(_dir: &Path) -> Result<Runtime> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }
}

/// Stub for `runtime::classifier::XlaClassifier`.
pub struct XlaClassifier {
    _private: (),
}

impl XlaClassifier {
    pub fn load(_dir: &Path, _alpha: f32) -> Result<XlaClassifier> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    pub fn state(&self) -> (&[f32], [f32; 2]) {
        unreachable!("{UNAVAILABLE}")
    }
}

impl Classifier for XlaClassifier {
    fn classify(&mut self, _feats: &[FeatureVec], _utility: &[f32]) -> ClassifyResult {
        unreachable!("{UNAVAILABLE}")
    }

    fn observe(&mut self, _feats: FeatureVec, _label: Label) {
        unreachable!("{UNAVAILABLE}")
    }

    fn flush(&mut self) {
        unreachable!("{UNAVAILABLE}")
    }

    fn class_counts(&self) -> [f32; 2] {
        unreachable!("{UNAVAILABLE}")
    }

    fn name(&self) -> &'static str {
        "naive-bayes(xla-stub)"
    }

    fn export_state(&self) -> (Vec<f32>, [f32; 2], f32) {
        unreachable!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_fail_with_actionable_message() {
        let dir = Path::new("/nonexistent");
        let e = Runtime::load(dir).unwrap_err().to_string();
        assert!(e.contains("xla-runtime"), "{e}");
        let e = XlaClassifier::load(dir, 1.0).unwrap_err().to_string();
        assert!(e.contains("xla-runtime"), "{e}");
    }
}
