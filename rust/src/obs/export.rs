//! The three exporters: Prometheus text snapshot, chrome://tracing JSON,
//! and a versioned JSONL event stream (same codec conventions as
//! `analysis/trace.rs`: one compact object per line, `"ev"` tag,
//! versioned header). Each format has a parse helper so round-trips are
//! testable without external tooling.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::json::Json;
use crate::errors::{Context, Result};

use super::registry::{bucket_index, bucket_upper, HistSnapshot, Registry, Snapshot, N_BUCKETS};
use super::span::Tracer;
use super::timeseries::{self, WindowRecord};
use super::ObsOptions;

/// Version stamp of the JSONL obs stream (`{"ev":"obs","version":2}`).
/// v2 added per-window `{"ev":"window"}` records and sparse bucket
/// payloads on `hist` lines; the parser still accepts v1 streams.
pub const OBS_VERSION: u64 = 2;

/// Oldest JSONL stream version [`parse_jsonl`] still understands.
pub const OBS_MIN_VERSION: u64 = 1;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(n: impl Into<f64>) -> Json {
    Json::Num(n.into())
}

// u64 has no Into<f64>; counts above 2^53 lose precision in JSON, which
// is acceptable for observability payloads (the .prom snapshot is exact).
fn numu(n: u64) -> Json {
    Json::Num(n as f64)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

// ---------------------------------------------------------------- prom

/// Render a Prometheus text-format snapshot. Histograms emit cumulative
/// `_bucket{le="..."}` samples at power-of-two bounds (empty buckets are
/// skipped; `+Inf` always present) plus exact `_sum` / `_count`.
pub fn to_prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for (name, v) in &snap.gauges {
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for (name, h) in &snap.histograms {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (i, n) in h.buckets.iter().enumerate() {
            if *n == 0 {
                continue;
            }
            cum += n;
            out.push_str(&format!(
                "{name}_bucket{{le=\"{}\"}} {cum}\n",
                bucket_upper(i)
            ));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
        out.push_str(&format!("{name}_sum {}\n", h.sum));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

/// Parse a Prometheus text snapshot back into `sample name -> value`
/// (label suffixes like `{le="3"}` stay part of the key). Every
/// non-comment line must be `name value`.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line
            .rsplit_once(' ')
            .with_context(|| format!("prom line {}: no value", lineno + 1))?;
        let value: f64 = value
            .parse()
            .with_context(|| format!("prom line {}: bad value", lineno + 1))?;
        out.insert(name.to_string(), value);
    }
    Ok(out)
}

// -------------------------------------------------------- chrome trace

/// Render a chrome://tracing (Trace Event Format) document: sampled
/// duration spans become `"ph":"X"` complete events, unsampled instants
/// become `"ph":"i"` events, both with `ts`/`dur` in wall microseconds
/// and the sim-time stamps under `args`.
pub fn to_chrome_trace(tracer: &Tracer) -> String {
    let mut events = Vec::new();
    for sp in tracer.spans() {
        events.push(obj(vec![
            ("name", s(sp.name)),
            ("ph", s("X")),
            ("ts", numu(sp.wall_start_us)),
            ("dur", numu(sp.wall_dur_us)),
            ("pid", num(1u32)),
            ("tid", num(1u32)),
            (
                "args",
                obj(vec![
                    ("sim_start", Json::Num(sp.sim_start)),
                    ("sim_end", Json::Num(sp.sim_end)),
                ]),
            ),
        ]));
    }
    for iv in tracer.instants() {
        events.push(obj(vec![
            ("name", s(iv.name)),
            ("ph", s("i")),
            ("ts", numu(iv.wall_us)),
            ("pid", num(1u32)),
            ("tid", num(1u32)),
            ("s", s("t")),
            ("args", obj(vec![("sim_time", Json::Num(iv.sim_time))])),
        ]));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", s("ms")),
    ])
    .to_string_compact()
}

/// Parse a chrome trace and count events per `(ph, name)`. The keys look
/// like `"i:sched_ev_task_started"` / `"X:heartbeat"` — what the
/// acceptance check compares against `SchedEvent` totals.
pub fn chrome_event_counts(text: &str) -> Result<BTreeMap<String, u64>> {
    let doc = Json::parse(text).context("chrome trace")?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .context("chrome trace: no traceEvents array")?;
    let mut out: BTreeMap<String, u64> = BTreeMap::new();
    for ev in events {
        let name = ev
            .get("name")
            .and_then(|v| v.as_str())
            .context("chrome trace: event without name")?;
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .context("chrome trace: event without ph")?;
        *out.entry(format!("{ph}:{name}")).or_insert(0) += 1;
    }
    Ok(out)
}

// --------------------------------------------------------------- jsonl

fn hist_json(name: &str, h: &HistSnapshot) -> Json {
    // sparse bucket encoding: [index, count] pairs for non-empty buckets
    let buckets: Vec<Json> = h
        .buckets
        .iter()
        .enumerate()
        .filter(|(_, n)| **n > 0)
        .map(|(i, n)| Json::Arr(vec![num(i as f64), numu(*n)]))
        .collect();
    obj(vec![
        ("ev", s("hist")),
        ("name", s(name)),
        ("count", numu(h.count)),
        ("sum", numu(h.sum)),
        ("buckets", Json::Arr(buckets)),
    ])
}

fn window_json(w: &WindowRecord) -> Json {
    let pairs = |v: &[(String, u64)]| {
        Json::Arr(
            v.iter()
                .map(|(n, x)| Json::Arr(vec![s(n), numu(*x)]))
                .collect(),
        )
    };
    let hists = Json::Arr(
        w.hists
            .iter()
            .map(|(n, h)| {
                let buckets: Vec<Json> = h
                    .buckets
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| **c > 0)
                    .map(|(i, c)| Json::Arr(vec![num(i as f64), numu(*c)]))
                    .collect();
                Json::Arr(vec![
                    s(n),
                    numu(h.count),
                    numu(h.sum),
                    Json::Arr(buckets),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("ev", s("window")),
        ("i", numu(w.index)),
        ("sim_start", Json::Num(w.sim_start)),
        ("sim_end", Json::Num(w.sim_end)),
        ("counters", pairs(&w.counters)),
        ("gauges", pairs(&w.gauges)),
        ("hists", hists),
    ])
}

/// Serialize the whole observation of a run — metric snapshot, window
/// series, and span stream — as versioned JSONL.
pub fn to_jsonl(snap: &Snapshot, tracer: &Tracer, windows: &[WindowRecord]) -> String {
    let mut out = String::new();
    let mut push = |j: Json| {
        out.push_str(&j.to_string_compact());
        out.push('\n');
    };
    push(obj(vec![
        ("ev", s("obs")),
        ("version", num(OBS_VERSION as f64)),
        ("dropped", numu(tracer.dropped())),
    ]));
    for (name, v) in &snap.counters {
        push(obj(vec![
            ("ev", s("counter")),
            ("name", s(name)),
            ("value", numu(*v)),
        ]));
    }
    for (name, v) in &snap.gauges {
        push(obj(vec![
            ("ev", s("gauge")),
            ("name", s(name)),
            ("value", numu(*v)),
        ]));
    }
    for (name, h) in &snap.histograms {
        push(hist_json(name, h));
    }
    for w in windows {
        push(window_json(w));
    }
    for sp in tracer.spans() {
        push(obj(vec![
            ("ev", s("span")),
            ("name", s(sp.name)),
            ("sim_start", Json::Num(sp.sim_start)),
            ("sim_end", Json::Num(sp.sim_end)),
            ("wall_start_us", numu(sp.wall_start_us)),
            ("wall_dur_us", numu(sp.wall_dur_us)),
        ]));
    }
    for iv in tracer.instants() {
        push(obj(vec![
            ("ev", s("instant")),
            ("name", s(iv.name)),
            ("sim", Json::Num(iv.sim_time)),
            ("wall_us", numu(iv.wall_us)),
        ]));
    }
    out
}

/// Parsed-back JSONL obs stream, for round-trip tests and offline tools.
#[derive(Clone, Debug, Default)]
pub struct JsonlDoc {
    pub version: u64,
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    /// `name -> (count, sum)` per histogram (kept for v1 consumers).
    pub histograms: BTreeMap<String, (u64, u64)>,
    /// Full bucket payloads per histogram (v2 streams).
    pub hist_buckets: BTreeMap<String, HistSnapshot>,
    /// The per-window delta series, in emit order (v2 streams).
    pub windows: Vec<WindowRecord>,
    pub spans: u64,
    pub instants: u64,
    pub dropped: u64,
}

fn get_name(o: &BTreeMap<String, Json>) -> Result<String> {
    o.get("name")
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .context("obs line has no 'name'")
}

fn get_u64(o: &BTreeMap<String, Json>, key: &str) -> Result<u64> {
    o.get(key)
        .and_then(|v| v.as_u64())
        .with_context(|| format!("bad field '{key}'"))
}

fn get_f64(o: &BTreeMap<String, Json>, key: &str) -> Result<f64> {
    o.get(key)
        .and_then(|v| v.as_f64())
        .with_context(|| format!("bad field '{key}'"))
}

/// Decode a `[[index, count], ...]` sparse bucket array.
fn parse_sparse_buckets(j: &Json) -> Result<[u64; N_BUCKETS]> {
    let mut buckets = [0u64; N_BUCKETS];
    for pair in j.as_arr().context("buckets is not an array")? {
        let pair = pair.as_arr().context("bucket entry is not a pair")?;
        let i = pair
            .first()
            .and_then(|v| v.as_u64())
            .context("bucket index")? as usize;
        let n = pair.get(1).and_then(|v| v.as_u64()).context("bucket count")?;
        if i >= N_BUCKETS {
            crate::bail!("bucket index {i} out of range");
        }
        buckets[i] = n;
    }
    Ok(buckets)
}

/// Decode a `[["name", value], ...]` pair array.
fn parse_pairs(j: &Json) -> Result<Vec<(String, u64)>> {
    let mut out = Vec::new();
    for pair in j.as_arr().context("pairs is not an array")? {
        let pair = pair.as_arr().context("pair entry is not an array")?;
        let name = pair
            .first()
            .and_then(|v| v.as_str())
            .context("pair name")?
            .to_string();
        let v = pair.get(1).and_then(|v| v.as_u64()).context("pair value")?;
        out.push((name, v));
    }
    Ok(out)
}

fn parse_window(o: &BTreeMap<String, Json>) -> Result<WindowRecord> {
    let mut w = WindowRecord {
        index: get_u64(o, "i")?,
        sim_start: get_f64(o, "sim_start")?,
        sim_end: get_f64(o, "sim_end")?,
        counters: parse_pairs(o.get("counters").context("window counters")?)?,
        gauges: parse_pairs(o.get("gauges").context("window gauges")?)?,
        hists: Vec::new(),
    };
    for h in o
        .get("hists")
        .and_then(|v| v.as_arr())
        .context("window hists")?
    {
        let h = h.as_arr().context("window hist entry")?;
        let name = h
            .first()
            .and_then(|v| v.as_str())
            .context("window hist name")?
            .to_string();
        let count = h.get(1).and_then(|v| v.as_u64()).context("hist count")?;
        let sum = h.get(2).and_then(|v| v.as_u64()).context("hist sum")?;
        let buckets = parse_sparse_buckets(h.get(3).context("hist buckets")?)?;
        w.hists.push((name, HistSnapshot { count, sum, buckets }));
    }
    Ok(w)
}

/// Parse a JSONL obs stream. Validates the versioned header line.
pub fn parse_jsonl(text: &str) -> Result<JsonlDoc> {
    let mut doc = JsonlDoc::default();
    let mut saw_header = false;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("obs line {}", lineno + 1))?;
        let o = j
            .as_obj()
            .with_context(|| format!("obs line {} is not an object", lineno + 1))?;
        let tag = o
            .get("ev")
            .and_then(|v| v.as_str())
            .with_context(|| format!("obs line {} has no 'ev' tag", lineno + 1))?;
        if !saw_header {
            if tag != "obs" {
                crate::bail!("obs stream has no header line");
            }
            let version = get_u64(o, "version")?;
            if !(OBS_MIN_VERSION..=OBS_VERSION).contains(&version) {
                crate::bail!(
                    "obs stream version {version}, expected {OBS_MIN_VERSION}..={OBS_VERSION}"
                );
            }
            doc.version = version;
            doc.dropped = get_u64(o, "dropped").unwrap_or(0);
            saw_header = true;
            continue;
        }
        match tag {
            "counter" => {
                doc.counters.insert(get_name(o)?, get_u64(o, "value")?);
            }
            "gauge" => {
                doc.gauges.insert(get_name(o)?, get_u64(o, "value")?);
            }
            "hist" => {
                let name = get_name(o)?;
                let count = get_u64(o, "count")?;
                let sum = get_u64(o, "sum")?;
                if let Some(b) = o.get("buckets") {
                    doc.hist_buckets.insert(
                        name.clone(),
                        HistSnapshot {
                            count,
                            sum,
                            buckets: parse_sparse_buckets(b)?,
                        },
                    );
                }
                doc.histograms.insert(name, (count, sum));
            }
            "window" => doc.windows.push(parse_window(o)?),
            "span" => doc.spans += 1,
            "instant" => doc.instants += 1,
            other => crate::bail!("unknown obs event tag '{other}'"),
        }
    }
    if !saw_header {
        crate::bail!("empty obs stream");
    }
    Ok(doc)
}

// ---------------------------------------------------------------- dump

/// A metric dump loaded back from disk — the common shape `repro obs
/// diff`, `repro obs check`, and the SLO evaluator consume, whichever
/// exporter wrote the file.
#[derive(Clone, Debug, Default)]
pub struct Dump {
    /// Counters and gauges, flattened to `name -> value`.
    pub scalars: BTreeMap<String, f64>,
    /// Full histograms (percentile questions need the buckets).
    pub hists: BTreeMap<String, HistSnapshot>,
    /// The window series, when the dump carried one (JSONL v2 only).
    pub windows: Vec<WindowRecord>,
}

impl Dump {
    /// Look a metric up by name: scalars directly, histograms by their
    /// exact `_count` / `_sum` derived samples.
    pub fn value(&self, name: &str) -> Option<f64> {
        if let Some(v) = self.scalars.get(name) {
            return Some(*v);
        }
        if let Some(stem) = name.strip_suffix("_count") {
            if let Some(h) = self.hists.get(stem) {
                return Some(h.count as f64);
            }
        }
        if let Some(stem) = name.strip_suffix("_sum") {
            if let Some(h) = self.hists.get(stem) {
                return Some(h.sum as f64);
            }
        }
        None
    }
}

/// Rebuild a [`Dump`] from a Prometheus text snapshot: cumulative
/// `_bucket{le="..."}` samples are de-cumulated back into per-bucket
/// counts and the histogram's `_sum`/`_count`/`_bucket` samples leave
/// the scalar table.
pub fn dump_from_prometheus(text: &str) -> Result<Dump> {
    let samples = parse_prometheus(text)?;
    let mut dump = Dump::default();
    // pass 1: find histogram stems and their cumulative bucket samples
    let mut cumulative: BTreeMap<String, Vec<(usize, u64)>> = BTreeMap::new();
    for (key, value) in &samples {
        let Some((stem, label)) = key.split_once("_bucket{le=\"") else {
            continue;
        };
        let le = label.trim_end_matches("\"}");
        if le == "+Inf" {
            cumulative.entry(stem.to_string()).or_default();
            continue;
        }
        let le: u64 = le
            .parse()
            .with_context(|| format!("bad le label in '{key}'"))?;
        cumulative
            .entry(stem.to_string())
            .or_default()
            .push((bucket_index(le), *value as u64));
    }
    for (stem, mut cum) in cumulative {
        cum.sort_unstable();
        let mut h = HistSnapshot {
            count: samples
                .get(&format!("{stem}_count"))
                .copied()
                .unwrap_or(0.0) as u64,
            sum: samples.get(&format!("{stem}_sum")).copied().unwrap_or(0.0) as u64,
            buckets: [0; N_BUCKETS],
        };
        let mut prev = 0u64;
        for (i, c) in cum {
            if i < N_BUCKETS {
                h.buckets[i] = c.saturating_sub(prev);
            }
            prev = c;
        }
        dump.hists.insert(stem, h);
    }
    // pass 2: everything not owned by a histogram is a scalar
    for (key, value) in samples {
        let owned = dump.hists.keys().any(|stem| {
            key.strip_prefix(stem.as_str()).is_some_and(|rest| {
                rest == "_sum" || rest == "_count" || rest.starts_with("_bucket{")
            })
        });
        if !owned {
            dump.scalars.insert(key, value);
        }
    }
    Ok(dump)
}

/// Rebuild a [`Dump`] from a JSONL obs stream (v1 or v2).
pub fn dump_from_jsonl(text: &str) -> Result<Dump> {
    let doc = parse_jsonl(text)?;
    let mut dump = Dump {
        windows: doc.windows,
        ..Dump::default()
    };
    for (name, v) in doc.counters.into_iter().chain(doc.gauges) {
        dump.scalars.insert(name, v as f64);
    }
    for (name, h) in doc.hist_buckets {
        dump.hists.insert(name, h);
    }
    // v1 streams carried only (count, sum); surface them as scalars so
    // value() still answers `_count` / `_sum` questions
    for (name, (count, sum)) in doc.histograms {
        if !dump.hists.contains_key(&name) {
            dump.scalars.insert(format!("{name}_count"), count as f64);
            dump.scalars.insert(format!("{name}_sum"), sum as f64);
        }
    }
    Ok(dump)
}

/// Load a dump from disk, sniffing the format: a JSON object on the
/// first non-empty line means JSONL, anything else Prometheus text.
pub fn load_dump(path: &Path) -> Result<Dump> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let first = text.lines().find(|l| !l.trim().is_empty()).unwrap_or("");
    if first.trim_start().starts_with('{') {
        dump_from_jsonl(&text).with_context(|| format!("{} as obs jsonl", path.display()))
    } else {
        dump_from_prometheus(&text).with_context(|| format!("{} as prometheus", path.display()))
    }
}

// --------------------------------------------------------------- files

/// Write every export the options ask for. Called once, after the run.
pub fn write_all(
    opts: &ObsOptions,
    registry: &Registry,
    tracer: &Tracer,
    windows: &[WindowRecord],
) -> Result<()> {
    let snap = registry.snapshot();
    if let Some(path) = &opts.dump {
        std::fs::write(path, to_prometheus(&snap))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    if let Some(path) = &opts.trace {
        std::fs::write(path, to_chrome_trace(tracer))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    if let Some(path) = &opts.jsonl {
        std::fs::write(path, to_jsonl(&snap, tracer, windows))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    if let Some(path) = &opts.csv {
        std::fs::write(path, timeseries::to_csv(windows))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Registry, Tracer) {
        let r = Registry::new();
        let c = r.counter("sched_ev_task_started");
        c.add(3);
        r.gauge("engine_events_dispatched").set(42);
        let h = r.histogram("driver_assign_nanos");
        h.record(0);
        h.record(2000);
        h.record(4000);
        let mut t = Tracer::new(2);
        t.record_span("heartbeat", 1.0, 1.0, 5_000);
        t.record_span("heartbeat", 2.0, 2.0, 5_000); // sampled out
        t.record_span("assign", 3.0, 3.0, 1_000);
        t.record_instant("sched_ev_task_started", 1.0);
        t.record_instant("sched_ev_task_started", 2.0);
        t.record_instant("sched_ev_task_started", 3.0);
        (r, t)
    }

    #[test]
    fn prometheus_round_trips() {
        let (r, _) = sample();
        let text = to_prometheus(&r.snapshot());
        let samples = parse_prometheus(&text).expect("parse prom");
        assert_eq!(samples["sched_ev_task_started"], 3.0);
        assert_eq!(samples["engine_events_dispatched"], 42.0);
        assert_eq!(samples["obs_collisions"], 0.0);
        assert_eq!(samples["driver_assign_nanos_count"], 3.0);
        assert_eq!(samples["driver_assign_nanos_sum"], 6000.0);
        // cumulative buckets: zero -> le="0", 2000 -> le="2047",
        // 4000 -> le="4095", then +Inf equals _count
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"0\"}"], 1.0);
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"2047\"}"], 2.0);
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"4095\"}"], 3.0);
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"+Inf\"}"], 3.0);
    }

    #[test]
    fn prometheus_rejects_garbage() {
        assert!(parse_prometheus("oops").is_err());
        assert!(parse_prometheus("name notanumber").is_err());
        assert!(parse_prometheus("# comment only\n").unwrap().is_empty());
    }

    #[test]
    fn chrome_trace_round_trips_with_exact_instant_counts() {
        let (_, t) = sample();
        let text = to_chrome_trace(&t);
        let counts = chrome_event_counts(&text).expect("parse chrome trace");
        assert_eq!(counts["X:heartbeat"], 1); // one of two sampled in
        assert_eq!(counts["X:assign"], 1);
        // instants are never sampled: all three survive
        assert_eq!(counts["i:sched_ev_task_started"], 3);
    }

    fn sample_windows() -> Vec<WindowRecord> {
        let r = Registry::new();
        let c = r.counter("sched_ev_task_started");
        let h = r.histogram("driver_assign_nanos");
        let mut ws = crate::obs::timeseries::WindowSnapshotter::new(r.clone(), 10.0);
        c.add(2);
        h.record(1500);
        ws.tick(10.0);
        c.inc();
        ws.flush(14.0)
    }

    #[test]
    fn jsonl_round_trips() {
        let (r, t) = sample();
        let text = to_jsonl(&r.snapshot(), &t, &[]);
        let doc = parse_jsonl(&text).expect("parse obs jsonl");
        assert_eq!(doc.version, OBS_VERSION);
        assert_eq!(doc.counters["sched_ev_task_started"], 3);
        assert_eq!(doc.counters["obs_collisions"], 0);
        assert_eq!(doc.gauges["engine_events_dispatched"], 42);
        assert_eq!(doc.histograms["driver_assign_nanos"], (3, 6000));
        assert_eq!(doc.spans, 2);
        assert_eq!(doc.instants, 3);
        assert_eq!(doc.dropped, 0);
        assert!(doc.windows.is_empty());
        // v2 hist lines carry their buckets exactly
        let h = &doc.hist_buckets["driver_assign_nanos"];
        assert_eq!(h.count, 3);
        assert_eq!(h.buckets.iter().sum::<u64>(), 3);
    }

    #[test]
    fn jsonl_v2_round_trips_the_window_series() {
        let (r, t) = sample();
        let windows = sample_windows();
        let text = to_jsonl(&r.snapshot(), &t, &windows);
        let doc = parse_jsonl(&text).expect("parse obs jsonl");
        assert_eq!(doc.windows, windows, "windows must round-trip exactly");
        assert_eq!(doc.windows[0].counters, vec![("sched_ev_task_started".to_string(), 2)]);
        assert_eq!(doc.windows[1].counters, vec![("sched_ev_task_started".to_string(), 1)]);
        assert_eq!(doc.windows[0].hists[0].1.count, 1);
        assert_eq!(doc.windows[0].hists[0].1.sum, 1500);
    }

    #[test]
    fn jsonl_rejects_missing_or_wrong_header() {
        assert!(parse_jsonl("").is_err());
        assert!(parse_jsonl("{\"ev\":\"counter\",\"name\":\"x\",\"value\":1}").is_err());
        assert!(parse_jsonl("{\"ev\":\"obs\",\"version\":99,\"dropped\":0}").is_err());
        let ok = parse_jsonl("{\"ev\":\"obs\",\"version\":1,\"dropped\":2}").unwrap();
        assert_eq!(ok.dropped, 2);
    }

    #[test]
    fn write_all_honors_each_option() {
        let dir = std::env::temp_dir().join(format!("obs_export_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (r, t) = sample();
        let opts = ObsOptions {
            dump: Some(dir.join("m.prom")),
            trace: Some(dir.join("t.json")),
            jsonl: Some(dir.join("o.jsonl")),
            csv: Some(dir.join("ts.csv")),
            ..ObsOptions::default()
        };
        write_all(&opts, &r, &t, &sample_windows()).expect("write exports");
        let prom = std::fs::read_to_string(dir.join("m.prom")).unwrap();
        assert!(parse_prometheus(&prom).is_ok());
        let trace = std::fs::read_to_string(dir.join("t.json")).unwrap();
        assert!(chrome_event_counts(&trace).is_ok());
        let jsonl = std::fs::read_to_string(dir.join("o.jsonl")).unwrap();
        assert!(parse_jsonl(&jsonl).is_ok());
        let csv = std::fs::read_to_string(dir.join("ts.csv")).unwrap();
        assert!(csv.starts_with("window,sim_start,sim_end,"));
        assert!(csv.lines().count() > 1, "csv carries the window rows");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dump_from_prometheus_rebuilds_histogram_buckets() {
        let (r, _) = sample();
        let dump = dump_from_prometheus(&to_prometheus(&r.snapshot())).expect("load prom");
        assert_eq!(dump.scalars["sched_ev_task_started"], 3.0);
        assert_eq!(dump.scalars["engine_events_dispatched"], 42.0);
        assert!(
            !dump.scalars.keys().any(|k| k.contains("_bucket{")),
            "bucket samples must fold into hists, not stay scalars"
        );
        let h = &dump.hists["driver_assign_nanos"];
        assert_eq!(h.count, 3);
        assert_eq!(h.sum, 6000);
        // de-cumulated: one zero, one in [1024,2047], one in [2048,4095]
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[11], 1);
        assert_eq!(h.buckets[12], 1);
        assert_eq!(h.buckets.iter().sum::<u64>(), 3);
        assert_eq!(dump.value("driver_assign_nanos_count"), Some(3.0));
        assert_eq!(dump.value("driver_assign_nanos_sum"), Some(6000.0));
    }

    #[test]
    fn dump_loaders_agree_across_formats() {
        let dir = std::env::temp_dir().join(format!("obs_dump_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (r, t) = sample();
        let windows = sample_windows();
        let snap = r.snapshot();
        std::fs::write(dir.join("m.prom"), to_prometheus(&snap)).unwrap();
        std::fs::write(dir.join("o.jsonl"), to_jsonl(&snap, &t, &windows)).unwrap();
        let a = load_dump(&dir.join("m.prom")).expect("prom dump");
        let b = load_dump(&dir.join("o.jsonl")).expect("jsonl dump");
        for key in ["sched_ev_task_started", "driver_assign_nanos_count"] {
            assert_eq!(a.value(key), b.value(key), "{key}");
        }
        assert_eq!(
            a.hists["driver_assign_nanos"], b.hists["driver_assign_nanos"],
            "bucket payloads must agree between exporters"
        );
        assert!(a.windows.is_empty(), "prometheus has no time axis");
        assert_eq!(b.windows, windows);
        assert!(load_dump(&dir.join("missing.prom")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
