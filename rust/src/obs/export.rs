//! The three exporters: Prometheus text snapshot, chrome://tracing JSON,
//! and a versioned JSONL event stream (same codec conventions as
//! `analysis/trace.rs`: one compact object per line, `"ev"` tag,
//! versioned header). Each format has a parse helper so round-trips are
//! testable without external tooling.

use std::collections::BTreeMap;

use crate::config::json::Json;
use crate::errors::{Context, Result};

use super::registry::{bucket_upper, HistSnapshot, Registry, Snapshot};
use super::span::Tracer;
use super::ObsOptions;

/// Version stamp of the JSONL obs stream (`{"ev":"obs","version":1}`).
pub const OBS_VERSION: u64 = 1;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(n: impl Into<f64>) -> Json {
    Json::Num(n.into())
}

// u64 has no Into<f64>; counts above 2^53 lose precision in JSON, which
// is acceptable for observability payloads (the .prom snapshot is exact).
fn numu(n: u64) -> Json {
    Json::Num(n as f64)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

// ---------------------------------------------------------------- prom

/// Render a Prometheus text-format snapshot. Histograms emit cumulative
/// `_bucket{le="..."}` samples at power-of-two bounds (empty buckets are
/// skipped; `+Inf` always present) plus exact `_sum` / `_count`.
pub fn to_prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for (name, v) in &snap.gauges {
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for (name, h) in &snap.histograms {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (i, n) in h.buckets.iter().enumerate() {
            if *n == 0 {
                continue;
            }
            cum += n;
            out.push_str(&format!(
                "{name}_bucket{{le=\"{}\"}} {cum}\n",
                bucket_upper(i)
            ));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
        out.push_str(&format!("{name}_sum {}\n", h.sum));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

/// Parse a Prometheus text snapshot back into `sample name -> value`
/// (label suffixes like `{le="3"}` stay part of the key). Every
/// non-comment line must be `name value`.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line
            .rsplit_once(' ')
            .with_context(|| format!("prom line {}: no value", lineno + 1))?;
        let value: f64 = value
            .parse()
            .with_context(|| format!("prom line {}: bad value", lineno + 1))?;
        out.insert(name.to_string(), value);
    }
    Ok(out)
}

// -------------------------------------------------------- chrome trace

/// Render a chrome://tracing (Trace Event Format) document: sampled
/// duration spans become `"ph":"X"` complete events, unsampled instants
/// become `"ph":"i"` events, both with `ts`/`dur` in wall microseconds
/// and the sim-time stamps under `args`.
pub fn to_chrome_trace(tracer: &Tracer) -> String {
    let mut events = Vec::new();
    for sp in tracer.spans() {
        events.push(obj(vec![
            ("name", s(sp.name)),
            ("ph", s("X")),
            ("ts", numu(sp.wall_start_us)),
            ("dur", numu(sp.wall_dur_us)),
            ("pid", num(1u32)),
            ("tid", num(1u32)),
            (
                "args",
                obj(vec![
                    ("sim_start", Json::Num(sp.sim_start)),
                    ("sim_end", Json::Num(sp.sim_end)),
                ]),
            ),
        ]));
    }
    for iv in tracer.instants() {
        events.push(obj(vec![
            ("name", s(iv.name)),
            ("ph", s("i")),
            ("ts", numu(iv.wall_us)),
            ("pid", num(1u32)),
            ("tid", num(1u32)),
            ("s", s("t")),
            ("args", obj(vec![("sim_time", Json::Num(iv.sim_time))])),
        ]));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", s("ms")),
    ])
    .to_string_compact()
}

/// Parse a chrome trace and count events per `(ph, name)`. The keys look
/// like `"i:sched_ev_task_started"` / `"X:heartbeat"` — what the
/// acceptance check compares against `SchedEvent` totals.
pub fn chrome_event_counts(text: &str) -> Result<BTreeMap<String, u64>> {
    let doc = Json::parse(text).context("chrome trace")?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .context("chrome trace: no traceEvents array")?;
    let mut out: BTreeMap<String, u64> = BTreeMap::new();
    for ev in events {
        let name = ev
            .get("name")
            .and_then(|v| v.as_str())
            .context("chrome trace: event without name")?;
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .context("chrome trace: event without ph")?;
        *out.entry(format!("{ph}:{name}")).or_insert(0) += 1;
    }
    Ok(out)
}

// --------------------------------------------------------------- jsonl

fn hist_json(name: &str, h: &HistSnapshot) -> Json {
    // sparse bucket encoding: [index, count] pairs for non-empty buckets
    let buckets: Vec<Json> = h
        .buckets
        .iter()
        .enumerate()
        .filter(|(_, n)| **n > 0)
        .map(|(i, n)| Json::Arr(vec![num(i as f64), numu(*n)]))
        .collect();
    obj(vec![
        ("ev", s("hist")),
        ("name", s(name)),
        ("count", numu(h.count)),
        ("sum", numu(h.sum)),
        ("buckets", Json::Arr(buckets)),
    ])
}

/// Serialize the whole observation of a run — metric snapshot plus span
/// stream — as versioned JSONL.
pub fn to_jsonl(snap: &Snapshot, tracer: &Tracer) -> String {
    let mut out = String::new();
    let mut push = |j: Json| {
        out.push_str(&j.to_string_compact());
        out.push('\n');
    };
    push(obj(vec![
        ("ev", s("obs")),
        ("version", num(OBS_VERSION as f64)),
        ("dropped", numu(tracer.dropped())),
    ]));
    for (name, v) in &snap.counters {
        push(obj(vec![
            ("ev", s("counter")),
            ("name", s(name)),
            ("value", numu(*v)),
        ]));
    }
    for (name, v) in &snap.gauges {
        push(obj(vec![
            ("ev", s("gauge")),
            ("name", s(name)),
            ("value", numu(*v)),
        ]));
    }
    for (name, h) in &snap.histograms {
        push(hist_json(name, h));
    }
    for sp in tracer.spans() {
        push(obj(vec![
            ("ev", s("span")),
            ("name", s(sp.name)),
            ("sim_start", Json::Num(sp.sim_start)),
            ("sim_end", Json::Num(sp.sim_end)),
            ("wall_start_us", numu(sp.wall_start_us)),
            ("wall_dur_us", numu(sp.wall_dur_us)),
        ]));
    }
    for iv in tracer.instants() {
        push(obj(vec![
            ("ev", s("instant")),
            ("name", s(iv.name)),
            ("sim", Json::Num(iv.sim_time)),
            ("wall_us", numu(iv.wall_us)),
        ]));
    }
    out
}

/// Parsed-back JSONL obs stream, for round-trip tests and offline tools.
#[derive(Clone, Debug, Default)]
pub struct JsonlDoc {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    /// `name -> (count, sum)` per histogram.
    pub histograms: BTreeMap<String, (u64, u64)>,
    pub spans: u64,
    pub instants: u64,
    pub dropped: u64,
}

fn get_name(o: &BTreeMap<String, Json>) -> Result<String> {
    o.get("name")
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .context("obs line has no 'name'")
}

fn get_u64(o: &BTreeMap<String, Json>, key: &str) -> Result<u64> {
    o.get(key)
        .and_then(|v| v.as_u64())
        .with_context(|| format!("bad field '{key}'"))
}

/// Parse a JSONL obs stream. Validates the versioned header line.
pub fn parse_jsonl(text: &str) -> Result<JsonlDoc> {
    let mut doc = JsonlDoc::default();
    let mut saw_header = false;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("obs line {}", lineno + 1))?;
        let o = j
            .as_obj()
            .with_context(|| format!("obs line {} is not an object", lineno + 1))?;
        let tag = o
            .get("ev")
            .and_then(|v| v.as_str())
            .with_context(|| format!("obs line {} has no 'ev' tag", lineno + 1))?;
        if !saw_header {
            if tag != "obs" {
                crate::bail!("obs stream has no header line");
            }
            let version = get_u64(o, "version")?;
            if version != OBS_VERSION {
                crate::bail!("obs stream version {version}, expected {OBS_VERSION}");
            }
            doc.dropped = get_u64(o, "dropped").unwrap_or(0);
            saw_header = true;
            continue;
        }
        match tag {
            "counter" => {
                doc.counters.insert(get_name(o)?, get_u64(o, "value")?);
            }
            "gauge" => {
                doc.gauges.insert(get_name(o)?, get_u64(o, "value")?);
            }
            "hist" => {
                doc.histograms
                    .insert(get_name(o)?, (get_u64(o, "count")?, get_u64(o, "sum")?));
            }
            "span" => doc.spans += 1,
            "instant" => doc.instants += 1,
            other => crate::bail!("unknown obs event tag '{other}'"),
        }
    }
    if !saw_header {
        crate::bail!("empty obs stream");
    }
    Ok(doc)
}

// --------------------------------------------------------------- files

/// Write every export the options ask for. Called once, after the run.
pub fn write_all(opts: &ObsOptions, registry: &Registry, tracer: &Tracer) -> Result<()> {
    let snap = registry.snapshot();
    if let Some(path) = &opts.dump {
        std::fs::write(path, to_prometheus(&snap))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    if let Some(path) = &opts.trace {
        std::fs::write(path, to_chrome_trace(tracer))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    if let Some(path) = &opts.jsonl {
        std::fs::write(path, to_jsonl(&snap, tracer))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Registry, Tracer) {
        let r = Registry::new();
        let c = r.counter("sched_ev_task_started");
        c.add(3);
        r.gauge("engine_events_dispatched").set(42);
        let h = r.histogram("driver_assign_nanos");
        h.record(0);
        h.record(2000);
        h.record(4000);
        let mut t = Tracer::new(2);
        t.record_span("heartbeat", 1.0, 1.0, 5_000);
        t.record_span("heartbeat", 2.0, 2.0, 5_000); // sampled out
        t.record_span("assign", 3.0, 3.0, 1_000);
        t.record_instant("sched_ev_task_started", 1.0);
        t.record_instant("sched_ev_task_started", 2.0);
        t.record_instant("sched_ev_task_started", 3.0);
        (r, t)
    }

    #[test]
    fn prometheus_round_trips() {
        let (r, _) = sample();
        let text = to_prometheus(&r.snapshot());
        let samples = parse_prometheus(&text).expect("parse prom");
        assert_eq!(samples["sched_ev_task_started"], 3.0);
        assert_eq!(samples["engine_events_dispatched"], 42.0);
        assert_eq!(samples["obs_collisions"], 0.0);
        assert_eq!(samples["driver_assign_nanos_count"], 3.0);
        assert_eq!(samples["driver_assign_nanos_sum"], 6000.0);
        // cumulative buckets: zero -> le="0", 2000 -> le="2047",
        // 4000 -> le="4095", then +Inf equals _count
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"0\"}"], 1.0);
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"2047\"}"], 2.0);
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"4095\"}"], 3.0);
        assert_eq!(samples["driver_assign_nanos_bucket{le=\"+Inf\"}"], 3.0);
    }

    #[test]
    fn prometheus_rejects_garbage() {
        assert!(parse_prometheus("oops").is_err());
        assert!(parse_prometheus("name notanumber").is_err());
        assert!(parse_prometheus("# comment only\n").unwrap().is_empty());
    }

    #[test]
    fn chrome_trace_round_trips_with_exact_instant_counts() {
        let (_, t) = sample();
        let text = to_chrome_trace(&t);
        let counts = chrome_event_counts(&text).expect("parse chrome trace");
        assert_eq!(counts["X:heartbeat"], 1); // one of two sampled in
        assert_eq!(counts["X:assign"], 1);
        // instants are never sampled: all three survive
        assert_eq!(counts["i:sched_ev_task_started"], 3);
    }

    #[test]
    fn jsonl_round_trips() {
        let (r, t) = sample();
        let text = to_jsonl(&r.snapshot(), &t);
        let doc = parse_jsonl(&text).expect("parse obs jsonl");
        assert_eq!(doc.counters["sched_ev_task_started"], 3);
        assert_eq!(doc.counters["obs_collisions"], 0);
        assert_eq!(doc.gauges["engine_events_dispatched"], 42);
        assert_eq!(doc.histograms["driver_assign_nanos"], (3, 6000));
        assert_eq!(doc.spans, 2);
        assert_eq!(doc.instants, 3);
        assert_eq!(doc.dropped, 0);
    }

    #[test]
    fn jsonl_rejects_missing_or_wrong_header() {
        assert!(parse_jsonl("").is_err());
        assert!(parse_jsonl("{\"ev\":\"counter\",\"name\":\"x\",\"value\":1}").is_err());
        assert!(parse_jsonl("{\"ev\":\"obs\",\"version\":99,\"dropped\":0}").is_err());
        let ok = parse_jsonl("{\"ev\":\"obs\",\"version\":1,\"dropped\":2}").unwrap();
        assert_eq!(ok.dropped, 2);
    }

    #[test]
    fn write_all_honors_each_option() {
        let dir = std::env::temp_dir().join(format!("obs_export_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (r, t) = sample();
        let opts = ObsOptions {
            dump: Some(dir.join("m.prom")),
            trace: Some(dir.join("t.json")),
            jsonl: Some(dir.join("o.jsonl")),
            ..ObsOptions::default()
        };
        write_all(&opts, &r, &t).expect("write exports");
        let prom = std::fs::read_to_string(dir.join("m.prom")).unwrap();
        assert!(parse_prometheus(&prom).is_ok());
        let trace = std::fs::read_to_string(dir.join("t.json")).unwrap();
        assert!(chrome_event_counts(&trace).is_ok());
        let jsonl = std::fs::read_to_string(dir.join("o.jsonl")).unwrap();
        assert!(parse_jsonl(&jsonl).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
