//! The one sanctioned wall-clock site in the library.
//!
//! Everything under `rust/src/` except `obs/` is forbidden from touching
//! `Instant::now` / `SystemTime::now` (the `wallclock-in-sim` lint
//! enforces it): simulation time flows from `Engine::now`, and stray
//! wall-clock reads break determinism. Code that genuinely needs wall
//! time — latency instrumentation, span tracing, bench harnesses — goes
//! through [`Stopwatch`] and [`wall_micros_since_start`] instead, so
//! every wall-clock read in the tree is greppable to this file.

use std::sync::OnceLock;
use std::time::Instant;

/// Wall-clock interval timer.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    started: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch {
            started: Instant::now(),
        }
    }

    /// Nanoseconds since `start()`, saturating at `u64::MAX` (~585 years).
    pub fn elapsed_nanos(&self) -> u64 {
        let nanos = self.started.elapsed().as_nanos();
        nanos.min(u128::from(u64::MAX)) as u64
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

static PROCESS_START: OnceLock<Instant> = OnceLock::new();

/// Microseconds since this function was first called in the process —
/// the shared zero point for every span's `ts` in a chrome trace.
pub fn wall_micros_since_start() -> u64 {
    let t0 = PROCESS_START.get_or_init(Instant::now);
    t0.elapsed().as_micros().min(u128::from(u64::MAX)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_is_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_nanos();
        let b = sw.elapsed_nanos();
        assert!(b >= a);
        assert!(sw.elapsed_secs() >= 0.0);
    }

    #[test]
    fn wall_anchor_is_monotone() {
        let a = wall_micros_since_start();
        let b = wall_micros_since_start();
        assert!(b >= a);
    }
}
