//! Leveled stderr logging for library code.
//!
//! Library code must not write to stderr directly — a million-job run
//! would drown in it, and tests capture nothing. The [`crate::obs_log!`]
//! macro routes through a process-wide level (default: errors only), so
//! diagnostics are silent unless `--verbose` raises the level. The
//! `eprintln!` inside the macro expansion below is the sanctioned sink.

use std::sync::atomic::{AtomicU8, Ordering};

/// Always shown (the default level): unrecoverable or wrong-answer cases.
pub const ERROR: u8 = 1;
/// Suspicious-but-survivable conditions (e.g. hitting `max_sim_time`).
pub const WARN: u8 = 2;
/// Progress chatter, enabled by `--verbose`.
pub const INFO: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(ERROR);

/// Set the process-wide log level (one of [`ERROR`], [`WARN`], [`INFO`]).
pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Would a message at `at` be printed right now?
pub fn enabled(at: u8) -> bool {
    at <= LEVEL.load(Ordering::Relaxed)
}

/// Log to stderr iff the process-wide level admits `$level`.
///
/// ```ignore
/// crate::obs_log!(crate::obs::log::WARN, "hit max_sim_time with {n} jobs");
/// ```
#[macro_export]
macro_rules! obs_log {
    ($level:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($level) {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warn_is_hidden_until_verbose() {
        // the global is process-wide; restore it so test order never matters
        let before = level();
        set_level(ERROR);
        assert!(enabled(ERROR));
        assert!(!enabled(WARN));
        assert!(!enabled(INFO));
        set_level(INFO);
        assert!(enabled(ERROR));
        assert!(enabled(WARN));
        assert!(enabled(INFO));
        set_level(before);
    }
}
