//! Zero-dependency observability: a metrics [`Registry`] (counters,
//! gauges, log-bucketed histograms), dual-clock span tracing
//! ([`Tracer`]), leveled logging ([`log`]), and three exporters
//! ([`export`]: Prometheus text, chrome://tracing JSON, versioned
//! JSONL).
//!
//! Design rules:
//!
//! - **Off by default, zero cost when off.** Drivers carry a
//!   [`DriverObs`] whose inner state is `None` until
//!   `enable_obs` is called; the disabled record path is one `Option`
//!   check. Detached handles keep `Metrics` working standalone.
//! - **Never touches simulation behavior.** Instruments only read the
//!   virtual clock; nothing feeds back. A run with obs enabled is
//!   bit-identical to one without.
//! - **Wall time flows only through [`clock`]** — the one site the
//!   `wallclock-in-sim` lint sanctions outside `rust/benches/`.
//!
//! The full metric-name catalog and exporter formats are documented in
//! `OBSERVABILITY.md` at the repo root.

pub mod clock;
pub mod export;
pub mod log;
pub mod percentile;
pub mod registry;
pub mod slo;
pub mod span;
pub mod timeseries;

use std::path::{Path, PathBuf};

pub use clock::Stopwatch;
pub use export::Dump;
pub use percentile::Percentiles;
pub use registry::{Counter, Gauge, HistSnapshot, Histogram, Registry, Snapshot};
pub use span::{InstantRecord, SpanRecord, Tracer};
pub use timeseries::{WindowRecord, WindowSnapshotter};

/// What the user asked for on the command line (`--obs-dump`,
/// `--obs-trace`, `--obs-jsonl`, `--obs-window W`, `--obs-csv`,
/// `--obs-sample N`, `--verbose`).
#[derive(Clone, Debug)]
pub struct ObsOptions {
    /// Prometheus text snapshot path (`--obs-dump metrics.prom`).
    pub dump: Option<PathBuf>,
    /// chrome://tracing JSON path (`--obs-trace trace.json`).
    pub trace: Option<PathBuf>,
    /// JSONL obs stream path (`--obs-jsonl obs.jsonl`).
    pub jsonl: Option<PathBuf>,
    /// Window cadence in sim seconds (`--obs-window 120`): close a
    /// metric-delta snapshot every W virtual seconds.
    pub window: Option<f64>,
    /// Time-series CSV path (`--obs-csv timeseries.csv`); requires
    /// `window` to produce rows.
    pub csv: Option<PathBuf>,
    /// Keep every Nth duration span (`--obs-sample N`; instants are
    /// always kept).
    pub sample: u64,
    /// Raise the log level to INFO (`--verbose`).
    pub verbose: bool,
}

impl Default for ObsOptions {
    fn default() -> ObsOptions {
        ObsOptions {
            dump: None,
            trace: None,
            jsonl: None,
            window: None,
            csv: None,
            sample: 1,
            verbose: false,
        }
    }
}

/// `dir/name.ext` -> `dir/name.suffix.ext` (no extension: append it).
fn suffix_path(path: &Path, suffix: &str) -> PathBuf {
    let ext = path.extension().and_then(|e| e.to_str());
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("out");
    let file = match ext {
        Some(ext) => format!("{stem}.{suffix}.{ext}"),
        None => format!("{stem}.{suffix}"),
    };
    path.with_file_name(file)
}

impl ObsOptions {
    /// True when any export file was requested — the signal drivers use
    /// to turn instrumentation on at all.
    pub fn any_output(&self) -> bool {
        self.dump.is_some() || self.trace.is_some() || self.jsonl.is_some() || self.csv.is_some()
    }

    /// The options for sweep cell `i`: every output path gains a
    /// `.cell-<i>` suffix (`metrics.prom` -> `metrics.cell-3.prom`) so a
    /// multi-cell experiment no longer clobbers one file per cell.
    pub fn for_cell(&self, i: usize) -> ObsOptions {
        let suffix = format!("cell-{i}");
        let re = |p: &Option<PathBuf>| p.as_ref().map(|p| suffix_path(p, &suffix));
        ObsOptions {
            dump: re(&self.dump),
            trace: re(&self.trace),
            jsonl: re(&self.jsonl),
            csv: re(&self.csv),
            ..self.clone()
        }
    }
}

#[derive(Debug)]
struct DriverObsInner {
    registry: Registry,
    tracer: Tracer,
    /// One counter per `SchedEvent` variant, indexed by `obs_index()`.
    events: Vec<Counter>,
    /// Windowed delta snapshots (`--obs-window`), `None` when unwindowed.
    snapshotter: Option<WindowSnapshotter>,
    heartbeat_nanos: Histogram,
    assign_nanos: Histogram,
    assign_batch_size: Histogram,
    queue_depth: Histogram,
    slot_util_pct: Histogram,
}

/// Per-driver observability state. Defaults to disabled (`inner: None`),
/// so driver constructors stay unchanged and the per-heartbeat cost of a
/// non-observed run is a single `Option` check.
#[derive(Debug, Default)]
pub struct DriverObs {
    inner: Option<Box<DriverObsInner>>,
}

impl DriverObs {
    /// Turn instrumentation on. `event_names[i]` names the counter for
    /// the `SchedEvent` with `obs_index() == i` (the obs layer itself
    /// knows nothing about scheduler types). Returns the registry so the
    /// caller can hand it to `Scheduler::install_obs` /
    /// `Metrics::install_obs`.
    pub fn enable(&mut self, opts: &ObsOptions, event_names: &[&'static str]) -> Registry {
        let registry = Registry::new();
        let events = event_names.iter().map(|n| registry.counter(n)).collect();
        self.inner = Some(Box::new(DriverObsInner {
            tracer: Tracer::new(opts.sample),
            events,
            snapshotter: opts
                .window
                .map(|w| WindowSnapshotter::new(registry.clone(), w)),
            heartbeat_nanos: registry.histogram("driver_heartbeat_nanos"),
            assign_nanos: registry.histogram("driver_assign_nanos"),
            assign_batch_size: registry.histogram("driver_assign_batch_size"),
            queue_depth: registry.histogram("driver_queue_depth"),
            slot_util_pct: registry.histogram("driver_slot_util_pct"),
            registry: registry.clone(),
        }));
        registry
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// A handle to the live registry (None when obs is off) — lets a
    /// driver register extra instrument families (e.g. the `trace_*`
    /// ingest metrics) into the same export surface.
    pub fn registry(&self) -> Option<Registry> {
        self.inner.as_ref().map(|i| i.registry.clone())
    }

    /// Advance the window clock (no-op when obs is off or unwindowed).
    /// Call from the event loop before dispatching the event at
    /// `sim_now`; reads only, never schedules — the sim stays
    /// bit-identical.
    pub fn window_tick(&mut self, sim_now: f64) {
        if let Some(inner) = self.inner.as_deref_mut() {
            if let Some(ws) = inner.snapshotter.as_mut() {
                ws.tick(sim_now);
            }
        }
    }

    /// Count one `SchedEvent` and stamp an unsampled instant for it.
    pub fn on_event(&mut self, index: usize, name: &'static str, sim_now: f64) {
        if let Some(inner) = self.inner.as_deref_mut() {
            if let Some(c) = inner.events.get(index) {
                c.inc();
            }
            inner.tracer.record_instant(name, sim_now);
        }
    }

    /// Record one whole heartbeat: latency histogram + sampled span.
    pub fn record_heartbeat(&mut self, sim_now: f64, wall_nanos: u64) {
        if let Some(inner) = self.inner.as_deref_mut() {
            inner.heartbeat_nanos.record(wall_nanos);
            inner
                .tracer
                .record_span("heartbeat", sim_now, sim_now, wall_nanos);
        }
    }

    /// Record one assign batch: latency, batch size, queue depth, and
    /// slot-utilization histograms + a sampled `assign` span.
    pub fn record_assign(
        &mut self,
        sim_now: f64,
        wall_nanos: u64,
        batch: usize,
        queue_depth: usize,
        util_pct: u64,
    ) {
        if let Some(inner) = self.inner.as_deref_mut() {
            inner.assign_nanos.record(wall_nanos);
            inner.assign_batch_size.record(batch as u64);
            inner.queue_depth.record(queue_depth as u64);
            inner.slot_util_pct.record(util_pct);
            inner
                .tracer
                .record_span("assign", sim_now, sim_now, wall_nanos);
        }
    }

    /// Tear down at sim time `sim_end`, returning the registry, tracer,
    /// and the flushed window series for export (engine gauges are set by
    /// the driver between `finish` and `write_all`).
    pub fn finish(&mut self, sim_end: f64) -> Option<(Registry, Tracer, Vec<WindowRecord>)> {
        self.inner.take().map(|inner| {
            inner
                .registry
                .gauge("obs_spans_dropped")
                .set(inner.tracer.dropped());
            let windows = match inner.snapshotter {
                Some(mut ws) => {
                    let windows = ws.flush(sim_end);
                    inner
                        .registry
                        .gauge("obs_windows_dropped")
                        .set(ws.dropped());
                    windows
                }
                None => Vec::new(),
            };
            (inner.registry, inner.tracer, windows)
        })
    }
}

/// Assign-phase instruments shared by every `by_name` scheduler:
/// `sched_<name>_assign_nanos` + `sched_<name>_assigned_total`.
/// Disabled (and free) until `install` is called; scheduler names are
/// sanitized (`-` -> `_`) to stay valid Prometheus metric names.
#[derive(Debug, Default)]
pub struct SchedObs {
    assign_nanos: Option<Histogram>,
    assigned_total: Option<Counter>,
}

impl SchedObs {
    pub fn install(&mut self, registry: &Registry, sched_name: &str) {
        let base = sched_name.replace('-', "_");
        self.assign_nanos = Some(registry.histogram(&format!("sched_{base}_assign_nanos")));
        self.assigned_total = Some(registry.counter(&format!("sched_{base}_assigned_total")));
    }

    pub fn is_enabled(&self) -> bool {
        self.assign_nanos.is_some()
    }

    /// Start timing an assign call; `None` (no clock read) when disabled.
    pub fn start(&self) -> Option<Stopwatch> {
        self.assign_nanos.is_some().then(Stopwatch::start)
    }

    /// Close out the timing started by [`SchedObs::start`].
    pub fn finish(&mut self, sw: Option<Stopwatch>, assigned: usize) {
        if let Some(sw) = sw {
            if let Some(h) = &self.assign_nanos {
                h.record(sw.elapsed_nanos());
            }
            if let Some(c) = &self.assigned_total {
                c.add(assigned as u64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_obs_is_inert_until_enabled() {
        let mut obs = DriverObs::default();
        assert!(!obs.is_enabled());
        obs.on_event(0, "ev", 1.0);
        obs.record_heartbeat(1.0, 100);
        obs.record_assign(1.0, 100, 2, 5, 50);
        obs.window_tick(5.0);
        assert!(obs.finish(5.0).is_none());
    }

    #[test]
    fn driver_obs_counts_events_and_spans() {
        let mut obs = DriverObs::default();
        let registry = obs.enable(&ObsOptions::default(), &["ev_a", "ev_b"]);
        obs.on_event(0, "ev_a", 1.0);
        obs.on_event(0, "ev_a", 2.0);
        obs.on_event(1, "ev_b", 3.0);
        obs.on_event(99, "out_of_range", 4.0); // counts nothing, still traced
        obs.record_heartbeat(5.0, 1_000);
        obs.record_assign(5.0, 500, 3, 7, 42);
        assert_eq!(registry.counter("ev_a").get(), 2);
        assert_eq!(registry.counter("ev_b").get(), 1);
        assert_eq!(registry.histogram("driver_heartbeat_nanos").count(), 1);
        assert_eq!(registry.histogram("driver_assign_batch_size").sum(), 3);
        assert_eq!(registry.histogram("driver_queue_depth").sum(), 7);
        assert_eq!(registry.histogram("driver_slot_util_pct").sum(), 42);
        let (_, tracer, windows) = obs.finish(5.0).expect("was enabled");
        assert_eq!(tracer.instants().len(), 4);
        assert_eq!(tracer.spans().len(), 2);
        assert!(windows.is_empty(), "no --obs-window, no series");
    }

    #[test]
    fn windowed_driver_obs_produces_the_delta_series() {
        let mut obs = DriverObs::default();
        let opts = ObsOptions {
            window: Some(10.0),
            ..ObsOptions::default()
        };
        obs.enable(&opts, &["ev_a"]);
        obs.on_event(0, "ev_a", 1.0);
        obs.window_tick(12.0); // closes [0,10)
        obs.on_event(0, "ev_a", 12.5);
        obs.on_event(0, "ev_a", 13.0);
        let (registry, _, windows) = obs.finish(15.0).expect("was enabled");
        assert_eq!(windows.len(), 2);
        assert_eq!(windows[0].counters, vec![("ev_a".to_string(), 1)]);
        assert_eq!(windows[1].counters, vec![("ev_a".to_string(), 2)]);
        assert_eq!(windows[1].sim_end, 15.0);
        assert_eq!(registry.gauge("obs_windows_dropped").get(), 0);
    }

    #[test]
    fn for_cell_suffixes_every_output_path() {
        let opts = ObsOptions {
            dump: Some(PathBuf::from("out/metrics.prom")),
            trace: Some(PathBuf::from("trace.json")),
            jsonl: Some(PathBuf::from("obs.jsonl")),
            csv: Some(PathBuf::from("ts")),
            ..ObsOptions::default()
        };
        let cell = opts.for_cell(3);
        assert_eq!(cell.dump.unwrap(), PathBuf::from("out/metrics.cell-3.prom"));
        assert_eq!(cell.trace.unwrap(), PathBuf::from("trace.cell-3.json"));
        assert_eq!(cell.jsonl.unwrap(), PathBuf::from("obs.cell-3.jsonl"));
        assert_eq!(cell.csv.unwrap(), PathBuf::from("ts.cell-3"));
        // disabled outputs stay disabled
        assert!(ObsOptions::default().for_cell(1).dump.is_none());
    }

    #[test]
    fn sched_obs_times_only_when_installed() {
        let mut so = SchedObs::default();
        assert!(so.start().is_none());
        so.finish(None, 5); // no-op
        let registry = Registry::new();
        so.install(&registry, "bayes-blind");
        let sw = so.start();
        assert!(sw.is_some());
        so.finish(sw, 5);
        assert_eq!(
            registry.histogram("sched_bayes_blind_assign_nanos").count(),
            1
        );
        assert_eq!(registry.counter("sched_bayes_blind_assigned_total").get(), 5);
    }
}
