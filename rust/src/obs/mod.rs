//! Zero-dependency observability: a metrics [`Registry`] (counters,
//! gauges, log-bucketed histograms), dual-clock span tracing
//! ([`Tracer`]), leveled logging ([`log`]), and three exporters
//! ([`export`]: Prometheus text, chrome://tracing JSON, versioned
//! JSONL).
//!
//! Design rules:
//!
//! - **Off by default, zero cost when off.** Drivers carry a
//!   [`DriverObs`] whose inner state is `None` until
//!   `enable_obs` is called; the disabled record path is one `Option`
//!   check. Detached handles keep `Metrics` working standalone.
//! - **Never touches simulation behavior.** Instruments only read the
//!   virtual clock; nothing feeds back. A run with obs enabled is
//!   bit-identical to one without.
//! - **Wall time flows only through [`clock`]** — the one site the
//!   `wallclock-in-sim` lint sanctions outside `rust/benches/`.
//!
//! The full metric-name catalog and exporter formats are documented in
//! `OBSERVABILITY.md` at the repo root.

pub mod clock;
pub mod export;
pub mod log;
pub mod registry;
pub mod span;

use std::path::PathBuf;

pub use clock::Stopwatch;
pub use registry::{Counter, Gauge, HistSnapshot, Histogram, Registry, Snapshot};
pub use span::{InstantRecord, SpanRecord, Tracer};

/// What the user asked for on the command line (`--obs-dump`,
/// `--obs-trace`, `--obs-jsonl`, `--obs-sample N`, `--verbose`).
#[derive(Clone, Debug)]
pub struct ObsOptions {
    /// Prometheus text snapshot path (`--obs-dump metrics.prom`).
    pub dump: Option<PathBuf>,
    /// chrome://tracing JSON path (`--obs-trace trace.json`).
    pub trace: Option<PathBuf>,
    /// JSONL obs stream path (`--obs-jsonl obs.jsonl`).
    pub jsonl: Option<PathBuf>,
    /// Keep every Nth duration span (`--obs-sample N`; instants are
    /// always kept).
    pub sample: u64,
    /// Raise the log level to INFO (`--verbose`).
    pub verbose: bool,
}

impl Default for ObsOptions {
    fn default() -> ObsOptions {
        ObsOptions {
            dump: None,
            trace: None,
            jsonl: None,
            sample: 1,
            verbose: false,
        }
    }
}

impl ObsOptions {
    /// True when any export file was requested — the signal drivers use
    /// to turn instrumentation on at all.
    pub fn any_output(&self) -> bool {
        self.dump.is_some() || self.trace.is_some() || self.jsonl.is_some()
    }
}

#[derive(Debug)]
struct DriverObsInner {
    registry: Registry,
    tracer: Tracer,
    /// One counter per `SchedEvent` variant, indexed by `obs_index()`.
    events: Vec<Counter>,
    heartbeat_nanos: Histogram,
    assign_nanos: Histogram,
    assign_batch_size: Histogram,
    queue_depth: Histogram,
    slot_util_pct: Histogram,
}

/// Per-driver observability state. Defaults to disabled (`inner: None`),
/// so driver constructors stay unchanged and the per-heartbeat cost of a
/// non-observed run is a single `Option` check.
#[derive(Debug, Default)]
pub struct DriverObs {
    inner: Option<Box<DriverObsInner>>,
}

impl DriverObs {
    /// Turn instrumentation on. `event_names[i]` names the counter for
    /// the `SchedEvent` with `obs_index() == i` (the obs layer itself
    /// knows nothing about scheduler types). Returns the registry so the
    /// caller can hand it to `Scheduler::install_obs` /
    /// `Metrics::install_obs`.
    pub fn enable(&mut self, opts: &ObsOptions, event_names: &[&'static str]) -> Registry {
        let registry = Registry::new();
        let events = event_names.iter().map(|n| registry.counter(n)).collect();
        self.inner = Some(Box::new(DriverObsInner {
            tracer: Tracer::new(opts.sample),
            events,
            heartbeat_nanos: registry.histogram("driver_heartbeat_nanos"),
            assign_nanos: registry.histogram("driver_assign_nanos"),
            assign_batch_size: registry.histogram("driver_assign_batch_size"),
            queue_depth: registry.histogram("driver_queue_depth"),
            slot_util_pct: registry.histogram("driver_slot_util_pct"),
            registry: registry.clone(),
        }));
        registry
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Count one `SchedEvent` and stamp an unsampled instant for it.
    pub fn on_event(&mut self, index: usize, name: &'static str, sim_now: f64) {
        if let Some(inner) = self.inner.as_deref_mut() {
            if let Some(c) = inner.events.get(index) {
                c.inc();
            }
            inner.tracer.record_instant(name, sim_now);
        }
    }

    /// Record one whole heartbeat: latency histogram + sampled span.
    pub fn record_heartbeat(&mut self, sim_now: f64, wall_nanos: u64) {
        if let Some(inner) = self.inner.as_deref_mut() {
            inner.heartbeat_nanos.record(wall_nanos);
            inner
                .tracer
                .record_span("heartbeat", sim_now, sim_now, wall_nanos);
        }
    }

    /// Record one assign batch: latency, batch size, queue depth, and
    /// slot-utilization histograms + a sampled `assign` span.
    pub fn record_assign(
        &mut self,
        sim_now: f64,
        wall_nanos: u64,
        batch: usize,
        queue_depth: usize,
        util_pct: u64,
    ) {
        if let Some(inner) = self.inner.as_deref_mut() {
            inner.assign_nanos.record(wall_nanos);
            inner.assign_batch_size.record(batch as u64);
            inner.queue_depth.record(queue_depth as u64);
            inner.slot_util_pct.record(util_pct);
            inner
                .tracer
                .record_span("assign", sim_now, sim_now, wall_nanos);
        }
    }

    /// Tear down, returning the registry and tracer for export (engine
    /// gauges are set by the driver between `finish` and `write_all`).
    pub fn finish(&mut self) -> Option<(Registry, Tracer)> {
        self.inner.take().map(|inner| {
            inner
                .registry
                .gauge("obs_spans_dropped")
                .set(inner.tracer.dropped());
            (inner.registry, inner.tracer)
        })
    }
}

/// Assign-phase instruments shared by every `by_name` scheduler:
/// `sched_<name>_assign_nanos` + `sched_<name>_assigned_total`.
/// Disabled (and free) until `install` is called; scheduler names are
/// sanitized (`-` -> `_`) to stay valid Prometheus metric names.
#[derive(Debug, Default)]
pub struct SchedObs {
    assign_nanos: Option<Histogram>,
    assigned_total: Option<Counter>,
}

impl SchedObs {
    pub fn install(&mut self, registry: &Registry, sched_name: &str) {
        let base = sched_name.replace('-', "_");
        self.assign_nanos = Some(registry.histogram(&format!("sched_{base}_assign_nanos")));
        self.assigned_total = Some(registry.counter(&format!("sched_{base}_assigned_total")));
    }

    pub fn is_enabled(&self) -> bool {
        self.assign_nanos.is_some()
    }

    /// Start timing an assign call; `None` (no clock read) when disabled.
    pub fn start(&self) -> Option<Stopwatch> {
        self.assign_nanos.is_some().then(Stopwatch::start)
    }

    /// Close out the timing started by [`SchedObs::start`].
    pub fn finish(&mut self, sw: Option<Stopwatch>, assigned: usize) {
        if let Some(sw) = sw {
            if let Some(h) = &self.assign_nanos {
                h.record(sw.elapsed_nanos());
            }
            if let Some(c) = &self.assigned_total {
                c.add(assigned as u64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_obs_is_inert_until_enabled() {
        let mut obs = DriverObs::default();
        assert!(!obs.is_enabled());
        obs.on_event(0, "ev", 1.0);
        obs.record_heartbeat(1.0, 100);
        obs.record_assign(1.0, 100, 2, 5, 50);
        assert!(obs.finish().is_none());
    }

    #[test]
    fn driver_obs_counts_events_and_spans() {
        let mut obs = DriverObs::default();
        let registry = obs.enable(&ObsOptions::default(), &["ev_a", "ev_b"]);
        obs.on_event(0, "ev_a", 1.0);
        obs.on_event(0, "ev_a", 2.0);
        obs.on_event(1, "ev_b", 3.0);
        obs.on_event(99, "out_of_range", 4.0); // counts nothing, still traced
        obs.record_heartbeat(5.0, 1_000);
        obs.record_assign(5.0, 500, 3, 7, 42);
        assert_eq!(registry.counter("ev_a").get(), 2);
        assert_eq!(registry.counter("ev_b").get(), 1);
        assert_eq!(registry.histogram("driver_heartbeat_nanos").count(), 1);
        assert_eq!(registry.histogram("driver_assign_batch_size").sum(), 3);
        assert_eq!(registry.histogram("driver_queue_depth").sum(), 7);
        assert_eq!(registry.histogram("driver_slot_util_pct").sum(), 42);
        let (_, tracer) = obs.finish().expect("was enabled");
        assert_eq!(tracer.instants().len(), 4);
        assert_eq!(tracer.spans().len(), 2);
    }

    #[test]
    fn sched_obs_times_only_when_installed() {
        let mut so = SchedObs::default();
        assert!(so.start().is_none());
        so.finish(None, 5); // no-op
        let registry = Registry::new();
        so.install(&registry, "bayes-blind");
        let sw = so.start();
        assert!(sw.is_some());
        so.finish(sw, 5);
        assert_eq!(
            registry.histogram("sched_bayes_blind_assign_nanos").count(),
            1
        );
        assert_eq!(registry.counter("sched_bayes_blind_assigned_total").get(), 5);
    }
}
