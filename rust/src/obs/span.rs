//! Structured spans stamped with BOTH clocks.
//!
//! Every record carries the virtual time it describes (`Engine::now`,
//! seconds) and the wall time it cost (microseconds since process
//! start), so one trace answers both "when in the simulation" and "how
//! expensive on this machine".
//!
//! Two record kinds, two policies:
//!
//! - **Duration spans** (heartbeats, assign batches) are SAMPLED: with
//!   `--obs-sample N` every Nth call is kept. Sampling is counter-based,
//!   not random, so a fixed seed reproduces a bit-identical span set.
//! - **Instants** (one per `SchedEvent`) are NEVER sampled: the
//!   chrome-trace exporter promises per-name instant counts equal to the
//!   run's `SchedEvent` totals, which a sampler would break.
//!
//! The buffer is bounded ([`DEFAULT_CAP`]); overflow increments a
//! `dropped` count that the exporters surface rather than silently
//! truncating.

use super::clock;

/// Combined spans+instants buffer bound: ~1M records, plenty for a quick
/// experiment and a hard stop for a million-job run.
pub const DEFAULT_CAP: usize = 1 << 20;

/// One sampled duration span.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRecord {
    pub name: &'static str,
    /// Virtual time (seconds) when the span began.
    pub sim_start: f64,
    /// Virtual time (seconds) when the span ended.
    pub sim_end: f64,
    /// Wall microseconds since process start when the span began.
    pub wall_start_us: u64,
    /// Wall duration in microseconds.
    pub wall_dur_us: u64,
}

/// One unsampled instantaneous event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InstantRecord {
    pub name: &'static str,
    /// Virtual time (seconds) the event fired at.
    pub sim_time: f64,
    /// Wall microseconds since process start when it was recorded.
    pub wall_us: u64,
}

/// Owner of the span/instant buffers; one per driver run.
#[derive(Debug)]
pub struct Tracer {
    sample_every: u64,
    seen: u64,
    cap: usize,
    spans: Vec<SpanRecord>,
    instants: Vec<InstantRecord>,
    dropped: u64,
}

impl Tracer {
    /// `sample_every` = N keeps every Nth duration span (0 acts as 1).
    pub fn new(sample_every: u64) -> Tracer {
        Tracer::with_cap(sample_every, DEFAULT_CAP)
    }

    pub fn with_cap(sample_every: u64, cap: usize) -> Tracer {
        Tracer {
            sample_every: sample_every.max(1),
            seen: 0,
            cap,
            spans: Vec::new(),
            instants: Vec::new(),
            dropped: 0,
        }
    }

    fn full(&self) -> bool {
        self.spans.len() + self.instants.len() >= self.cap
    }

    /// Record a duration span; subject to sampling and the buffer cap.
    /// The wall start is anchored by subtracting `wall_dur_nanos` from
    /// the current [`clock::wall_micros_since_start`].
    pub fn record_span(
        &mut self,
        name: &'static str,
        sim_start: f64,
        sim_end: f64,
        wall_dur_nanos: u64,
    ) {
        self.seen += 1;
        if (self.seen - 1) % self.sample_every != 0 {
            return;
        }
        if self.full() {
            self.dropped += 1;
            return;
        }
        let dur_us = wall_dur_nanos / 1_000;
        let now_us = clock::wall_micros_since_start();
        self.spans.push(SpanRecord {
            name,
            sim_start,
            sim_end,
            wall_start_us: now_us.saturating_sub(dur_us),
            wall_dur_us: dur_us,
        });
    }

    /// Record an instantaneous event; never sampled, only capped.
    pub fn record_instant(&mut self, name: &'static str, sim_time: f64) {
        if self.full() {
            self.dropped += 1;
            return;
        }
        self.instants.push(InstantRecord {
            name,
            sim_time,
            wall_us: clock::wall_micros_since_start(),
        });
    }

    pub fn spans(&self) -> &[SpanRecord] {
        &self.spans
    }

    pub fn instants(&self) -> &[InstantRecord] {
        &self.instants
    }

    /// Records lost to the buffer cap (sampled-out spans are not drops).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Duration-span record calls observed, kept or not.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(t: &mut Tracer, n: u64) {
        for i in 0..n {
            t.record_span("hb", i as f64, i as f64 + 0.5, 1_000 * (i + 1));
        }
    }

    #[test]
    fn sampling_keeps_every_nth_deterministically() {
        let mut a = Tracer::new(3);
        let mut b = Tracer::new(3);
        feed(&mut a, 10);
        feed(&mut b, 10);
        // calls 0, 3, 6, 9 are kept — same set in both tracers
        assert_eq!(a.spans().len(), 4);
        assert_eq!(b.spans().len(), 4);
        let durs_a: Vec<u64> = a.spans().iter().map(|s| s.wall_dur_us).collect();
        let durs_b: Vec<u64> = b.spans().iter().map(|s| s.wall_dur_us).collect();
        assert_eq!(durs_a, durs_b);
        assert_eq!(durs_a, vec![1, 4, 7, 10]);
        assert_eq!(a.seen(), 10);
        assert_eq!(a.dropped(), 0);
    }

    #[test]
    fn sample_every_zero_acts_as_one() {
        let mut t = Tracer::new(0);
        feed(&mut t, 5);
        assert_eq!(t.spans().len(), 5);
        assert_eq!(t.sample_every(), 1);
    }

    #[test]
    fn instants_are_never_sampled() {
        let mut t = Tracer::new(100);
        for i in 0..10 {
            t.record_instant("ev", i as f64);
        }
        assert_eq!(t.instants().len(), 10);
    }

    #[test]
    fn cap_counts_drops_instead_of_growing() {
        let mut t = Tracer::with_cap(1, 3);
        feed(&mut t, 2);
        t.record_instant("ev", 0.0);
        t.record_instant("ev", 1.0); // over cap
        feed(&mut t, 1); // over cap
        assert_eq!(t.spans().len(), 2);
        assert_eq!(t.instants().len(), 1);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn span_carries_both_clocks() {
        let mut t = Tracer::new(1);
        t.record_span("hb", 12.0, 12.5, 2_000_000);
        let s = t.spans()[0];
        assert_eq!(s.name, "hb");
        assert!((s.sim_start - 12.0).abs() < 1e-12);
        assert!((s.sim_end - 12.5).abs() < 1e-12);
        assert_eq!(s.wall_dur_us, 2_000);
    }
}
