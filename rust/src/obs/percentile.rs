//! Percentile estimation over the registry's power-of-two histogram
//! buckets, shared by every consumer (`repro obs diff`, the SLO
//! evaluator, the time-series CSV).
//!
//! A log-bucketed histogram cannot recover exact order statistics, so the
//! estimate walks the cumulative bucket counts to the bucket holding the
//! target rank and interpolates linearly inside it. The error is bounded
//! by the bucket width: the estimate always lands inside
//! `[bucket_lower, bucket_upper]` of the true value's bucket, i.e. within
//! a factor of two. That is plenty for regression gating (a p99 that
//! doubles crosses a bucket boundary by construction).

use super::registry::{bucket_upper, HistSnapshot, N_BUCKETS};

/// Inclusive lower bound of bucket `i` (0 for the zero bucket).
fn bucket_lower(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        bucket_upper(i - 1).saturating_add(1)
    }
}

/// Estimate the `p`-th percentile (0 < p <= 100) from raw bucket counts.
/// Returns 0.0 for an empty histogram. `count` must equal the bucket sum
/// (callers pass `HistSnapshot::count`, which the registry keeps exact).
pub fn percentile_from_buckets(buckets: &[u64; N_BUCKETS], count: u64, p: f64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    // rank of the target observation, 1-based, nearest-rank flavor
    let target = ((p / 100.0) * count as f64).ceil().max(1.0);
    let mut cum = 0u64;
    for (i, n) in buckets.iter().enumerate() {
        if *n == 0 {
            continue;
        }
        let prev = cum;
        cum += n;
        if (cum as f64) >= target {
            let lo = bucket_lower(i) as f64;
            let hi = bucket_upper(i) as f64;
            // fraction of the way through this bucket's observations
            let frac = (target - prev as f64) / *n as f64;
            return lo + (hi - lo) * frac.clamp(0.0, 1.0);
        }
    }
    // counts disagreed with the buckets (sheared snapshot); report the max
    bucket_upper(N_BUCKETS - 1) as f64
}

/// Estimate the `p`-th percentile of one histogram snapshot.
pub fn estimate(h: &HistSnapshot, p: f64) -> f64 {
    percentile_from_buckets(&h.buckets, h.count, p)
}

/// The standard p50/p95/p99 triple every consumer reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    pub fn of(h: &HistSnapshot) -> Percentiles {
        Percentiles {
            p50: estimate(h, 50.0),
            p95: estimate(h, 95.0),
            p99: estimate(h, 99.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{bucket_index, Histogram};

    fn hist_of(values: &[u64]) -> HistSnapshot {
        let h = Histogram::detached();
        for v in values {
            h.record(*v);
        }
        h.snapshot()
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = hist_of(&[]);
        assert_eq!(estimate(&h, 50.0), 0.0);
        assert_eq!(Percentiles::of(&h), Percentiles::default());
    }

    #[test]
    fn single_value_lands_in_its_bucket() {
        let h = hist_of(&[3000]);
        for p in [1.0, 50.0, 99.0, 100.0] {
            let est = estimate(&h, p);
            let i = bucket_index(3000);
            assert!(est >= bucket_lower(i) as f64, "p{p}: {est}");
            assert!(est <= bucket_upper(i) as f64, "p{p}: {est}");
        }
    }

    #[test]
    fn estimates_bracket_the_true_value_by_bucket() {
        // 100 observations 1..=100: true p50 = 50, p95 = 95, p99 = 99
        let values: Vec<u64> = (1..=100).collect();
        let h = hist_of(&values);
        for (p, truth) in [(50.0, 50u64), (95.0, 95), (99.0, 99)] {
            let est = estimate(&h, p);
            let i = bucket_index(truth);
            assert!(
                est >= bucket_lower(i) as f64 && est <= bucket_upper(i) as f64,
                "p{p} estimate {est} escaped bucket {i} of true value {truth}"
            );
        }
    }

    #[test]
    fn percentiles_are_monotone_in_p() {
        let h = hist_of(&[0, 1, 5, 5, 70, 900, 900, 64_000, 1_000_000]);
        let mut last = -1.0;
        for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            let est = estimate(&h, p);
            assert!(est >= last, "p{p}: {est} < {last}");
            last = est;
        }
    }

    #[test]
    fn zero_heavy_histogram_keeps_p50_at_zero() {
        let h = hist_of(&[0, 0, 0, 0, 0, 0, 0, 0, 0, 1_000_000]);
        assert_eq!(estimate(&h, 50.0), 0.0);
        assert!(estimate(&h, 99.0) > 0.0);
    }

    #[test]
    fn interpolation_moves_within_one_bucket() {
        // all mass in bucket [1024, 2047]: higher p -> later in the bucket
        let h = hist_of(&[1100, 1300, 1500, 1700, 1900]);
        let lo = estimate(&h, 10.0);
        let hi = estimate(&h, 90.0);
        assert!(lo < hi, "{lo} !< {hi}");
        assert!(lo >= 1024.0 && hi <= 2047.0);
    }

    #[test]
    fn sheared_snapshot_reports_the_max_bound() {
        // count claims more observations than the buckets hold
        let mut h = hist_of(&[5]);
        h.count = 10;
        assert_eq!(estimate(&h, 100.0), bucket_upper(N_BUCKETS - 1) as f64);
    }
}
