//! Declarative SLO specs over metric dumps: the file format behind
//! `repro obs check --slo slo.json <dump>` and the CI regression gate
//! (`slo/ci.json`), replacing hardcoded thresholds scattered through
//! bench code with one reviewable spec.
//!
//! A spec is a JSON object `{"slo": [rule, ...]}`; each rule has a
//! `"kind"` discriminator:
//!
//! | kind         | fields                         | meaning                          |
//! |--------------|--------------------------------|----------------------------------|
//! | `value`      | `metric`, `max`?, `min`?       | bound a counter/gauge sample     |
//! | `percentile` | `metric`, `p`, `max`?, `min`?  | bound a histogram percentile     |
//! | `ratio`      | `num`, `den`, `max`            | bound `num / den` (0/0 passes)   |
//! | `burn`       | `metric`, `max_per_window`     | bound a per-window counter delta |
//! | `bench`      | `file`, `key`, `max`           | bound a `BENCH_*.json` result    |
//!
//! Missing metrics are violations, not skips — an SLO over a metric the
//! run never registered is a spec bug worth failing loudly on. `burn`
//! rules need a dump with a window series (JSONL v2 from a
//! `--obs-window` run); evaluating one against a windowless dump is
//! likewise a violation.

use std::fmt;
use std::path::Path;

use crate::config::json::Json;
use crate::errors::{Context, Result};

use super::export::Dump;
use super::percentile;
use super::timeseries::max_window_delta;

/// One rule of a spec. Bounds are inclusive: a sample *at* `max` passes.
#[derive(Clone, Debug, PartialEq)]
pub enum SloRule {
    Value {
        metric: String,
        max: Option<f64>,
        min: Option<f64>,
    },
    Percentile {
        metric: String,
        p: f64,
        max: Option<f64>,
        min: Option<f64>,
    },
    Ratio {
        num: String,
        den: String,
        max: f64,
    },
    Burn {
        metric: String,
        max_per_window: f64,
    },
    Bench {
        file: String,
        key: String,
        max: f64,
    },
}

impl fmt::Display for SloRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SloRule::Value { metric, max, min } => {
                write!(f, "value({metric}{})", bounds(max, min))
            }
            SloRule::Percentile { metric, p, max, min } => {
                write!(f, "p{p:.0}({metric}{})", bounds(max, min))
            }
            SloRule::Ratio { num, den, max } => write!(f, "ratio({num}/{den} <= {max})"),
            SloRule::Burn { metric, max_per_window } => {
                write!(f, "burn({metric} <= {max_per_window}/window)")
            }
            SloRule::Bench { file, key, max } => write!(f, "bench({file}:{key} <= {max})"),
        }
    }
}

fn bounds(max: &Option<f64>, min: &Option<f64>) -> String {
    let mut s = String::new();
    if let Some(m) = max {
        s.push_str(&format!(" <= {m}"));
    }
    if let Some(m) = min {
        s.push_str(&format!(" >= {m}"));
    }
    s
}

/// One violated rule, with the observed value spelled out.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: String,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SLO {}: {}", self.rule, self.detail)
    }
}

/// A parsed `{"slo": [...]}` spec.
#[derive(Clone, Debug, Default)]
pub struct SloSpec {
    pub rules: Vec<SloRule>,
}

fn f64_field(o: &Json, key: &str) -> Result<f64> {
    o.get(key)
        .and_then(|v| v.as_f64())
        .with_context(|| format!("slo rule: missing number '{key}'"))
}

fn opt_f64_field(o: &Json, key: &str) -> Option<f64> {
    o.get(key).and_then(|v| v.as_f64())
}

fn str_field(o: &Json, key: &str) -> Result<String> {
    o.get(key)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .with_context(|| format!("slo rule: missing string '{key}'"))
}

impl SloSpec {
    /// Parse a spec document. Empty rule lists are rejected — a vacuous
    /// gate that passes everything is a misconfiguration, not a spec.
    pub fn parse(text: &str) -> Result<SloSpec> {
        let doc = Json::parse(text).context("slo spec")?;
        let rules_json = doc
            .get("slo")
            .and_then(|v| v.as_arr())
            .context("slo spec: no 'slo' rule array")?;
        let mut rules = Vec::new();
        for (i, r) in rules_json.iter().enumerate() {
            let kind = r
                .get("kind")
                .and_then(|v| v.as_str())
                .with_context(|| format!("slo rule {i}: no 'kind'"))?;
            let rule = match kind {
                "value" => SloRule::Value {
                    metric: str_field(r, "metric")?,
                    max: opt_f64_field(r, "max"),
                    min: opt_f64_field(r, "min"),
                },
                "percentile" => SloRule::Percentile {
                    metric: str_field(r, "metric")?,
                    p: f64_field(r, "p")?,
                    max: opt_f64_field(r, "max"),
                    min: opt_f64_field(r, "min"),
                },
                "ratio" => SloRule::Ratio {
                    num: str_field(r, "num")?,
                    den: str_field(r, "den")?,
                    max: f64_field(r, "max")?,
                },
                "burn" => SloRule::Burn {
                    metric: str_field(r, "metric")?,
                    max_per_window: f64_field(r, "max_per_window")?,
                },
                "bench" => SloRule::Bench {
                    file: str_field(r, "file")?,
                    key: str_field(r, "key")?,
                    max: f64_field(r, "max")?,
                },
                other => crate::bail!("slo rule {i}: unknown kind '{other}'"),
            };
            if let SloRule::Value { max: None, min: None, .. }
            | SloRule::Percentile { max: None, min: None, .. } = &rule
            {
                crate::bail!("slo rule {i}: needs at least one of 'max'/'min'");
            }
            rules.push(rule);
        }
        if rules.is_empty() {
            crate::bail!("slo spec: empty rule list gates nothing");
        }
        Ok(SloSpec { rules })
    }

    pub fn load(path: &Path) -> Result<SloSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        SloSpec::parse(&text).with_context(|| path.display().to_string())
    }

    /// Evaluate every rule against `dump`; `bench_root` anchors the
    /// relative `file` of `bench` rules (the repo root in CI). Returns
    /// the violations — empty means the SLO holds.
    pub fn evaluate(&self, dump: &Dump, bench_root: &Path) -> Vec<Violation> {
        let mut out = Vec::new();
        let mut violate = |rule: &SloRule, detail: String| {
            out.push(Violation {
                rule: rule.to_string(),
                detail,
            });
        };
        for rule in &self.rules {
            match rule {
                SloRule::Value { metric, max, min } => match dump.value(metric) {
                    None => violate(rule, format!("metric '{metric}' not in dump")),
                    Some(v) => check_bounds(rule, v, max, min, &mut violate),
                },
                SloRule::Percentile { metric, p, max, min } => match dump.hists.get(metric) {
                    None => violate(rule, format!("histogram '{metric}' not in dump")),
                    Some(h) => {
                        let v = percentile::estimate(h, *p);
                        check_bounds(rule, v, max, min, &mut violate);
                    }
                },
                SloRule::Ratio { num, den, max } => {
                    let (n, d) = (dump.value(num), dump.value(den));
                    match (n, d) {
                        (None, _) => violate(rule, format!("metric '{num}' not in dump")),
                        (_, None) => violate(rule, format!("metric '{den}' not in dump")),
                        (Some(n), Some(d)) => {
                            // exact zero-denominator guard -- lint: allow(float-eq)
                            if d == 0.0 {
                                if n > 0.0 {
                                    violate(rule, format!("{num}={n} with {den}=0"));
                                }
                            } else if n / d > *max {
                                violate(rule, format!("{num}/{den} = {:.4} > {max}", n / d));
                            }
                        }
                    }
                }
                SloRule::Burn { metric, max_per_window } => {
                    if dump.windows.is_empty() {
                        violate(
                            rule,
                            "dump has no window series (need --obs-window + JSONL)".into(),
                        );
                    } else {
                        let worst = max_window_delta(&dump.windows, metric) as f64;
                        if worst > *max_per_window {
                            violate(
                                rule,
                                format!("worst window delta {worst} > {max_per_window}"),
                            );
                        }
                    }
                }
                SloRule::Bench { file, key, max } => {
                    match eval_bench(&bench_root.join(file), key, *max) {
                        Ok(bad) => {
                            for (result, v) in bad {
                                violate(rule, format!("{result}.{key} = {v:.3} > {max}"));
                            }
                        }
                        Err(e) => violate(rule, format!("{e:#}")),
                    }
                }
            }
        }
        out
    }
}

fn check_bounds(
    rule: &SloRule,
    v: f64,
    max: &Option<f64>,
    min: &Option<f64>,
    violate: &mut impl FnMut(&SloRule, String),
) {
    if let Some(m) = max {
        if v > *m {
            violate(rule, format!("observed {v:.3} > {m}"));
        }
    }
    if let Some(m) = min {
        if v < *m {
            violate(rule, format!("observed {v:.3} < {m}"));
        }
    }
}

/// Check `results.*.<key> <= max` in a `BENCH_*.json` document; returns
/// the offending `(result, value)` pairs. A missing file or a results
/// table without the key anywhere is an error (the gate must not pass
/// vacuously because a bench was renamed).
fn eval_bench(path: &Path, key: &str, max: f64) -> Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("bench file {}", path.display()))?;
    let doc = Json::parse(&text).with_context(|| path.display().to_string())?;
    let results = doc
        .get("results")
        .and_then(|v| v.as_obj())
        .with_context(|| format!("{}: no 'results' table", path.display()))?;
    let mut bad = Vec::new();
    let mut seen = false;
    for (result, fields) in results {
        if let Some(v) = fields.get(key).and_then(|v| v.as_f64()) {
            seen = true;
            if v > max {
                bad.push((result.clone(), v));
            }
        }
    }
    if !seen {
        crate::bail!("{}: no result carries '{key}'", path.display());
    }
    Ok(bad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::dump_from_prometheus;
    use crate::obs::registry::Registry;
    use crate::obs::timeseries::WindowSnapshotter;

    fn sample_dump() -> Dump {
        let r = Registry::new();
        r.counter("sched_ev_task_started").add(100);
        r.counter("sched_ev_task_failed").add(4);
        let h = r.histogram("driver_queue_depth");
        for v in [1u64, 2, 3, 10, 200] {
            h.record(v);
        }
        dump_from_prometheus(&super::super::export::to_prometheus(&r.snapshot())).unwrap()
    }

    #[test]
    fn parse_accepts_every_kind_and_rejects_garbage() {
        let spec = SloSpec::parse(
            r#"{"slo":[
                {"kind":"value","metric":"obs_collisions","max":0},
                {"kind":"percentile","metric":"driver_queue_depth","p":99,"max":1000},
                {"kind":"ratio","num":"sched_ev_task_failed","den":"sched_ev_task_started","max":0.25},
                {"kind":"burn","metric":"sched_ev_task_failed","max_per_window":10},
                {"kind":"bench","file":"BENCH_engine.json","key":"obs_overhead_pct","max":5.0}
            ]}"#,
        )
        .expect("parse spec");
        assert_eq!(spec.rules.len(), 5);
        assert!(SloSpec::parse("{}").is_err(), "no slo array");
        assert!(SloSpec::parse(r#"{"slo":[]}"#).is_err(), "vacuous gate");
        assert!(
            SloSpec::parse(r#"{"slo":[{"kind":"nope"}]}"#).is_err(),
            "unknown kind"
        );
        assert!(
            SloSpec::parse(r#"{"slo":[{"kind":"value","metric":"x"}]}"#).is_err(),
            "no bound at all"
        );
    }

    #[test]
    fn value_and_ratio_rules_gate_the_dump() {
        let dump = sample_dump();
        let root = Path::new(".");
        let ok = SloSpec::parse(
            r#"{"slo":[
                {"kind":"value","metric":"obs_collisions","max":0},
                {"kind":"value","metric":"sched_ev_task_started","min":50},
                {"kind":"ratio","num":"sched_ev_task_failed","den":"sched_ev_task_started","max":0.05}
            ]}"#,
        )
        .unwrap();
        assert!(ok.evaluate(&dump, root).is_empty());
        let bad = SloSpec::parse(
            r#"{"slo":[
                {"kind":"value","metric":"sched_ev_task_started","max":10},
                {"kind":"ratio","num":"sched_ev_task_failed","den":"sched_ev_task_started","max":0.01},
                {"kind":"value","metric":"no_such_metric","max":1}
            ]}"#,
        )
        .unwrap();
        let violations = bad.evaluate(&dump, root);
        assert_eq!(violations.len(), 3);
        assert!(violations[2].detail.contains("not in dump"));
    }

    #[test]
    fn ratio_zero_over_zero_passes_but_n_over_zero_fails() {
        let dump = sample_dump();
        let spec = SloSpec::parse(
            r#"{"slo":[{"kind":"ratio","num":"obs_collisions","den":"obs_collisions","max":0.1}]}"#,
        )
        .unwrap();
        assert!(spec.evaluate(&dump, Path::new(".")).is_empty(), "0/0 is fine");
        let spec = SloSpec::parse(
            r#"{"slo":[{"kind":"ratio","num":"sched_ev_task_failed","den":"obs_collisions","max":0.1}]}"#,
        )
        .unwrap();
        assert_eq!(spec.evaluate(&dump, Path::new(".")).len(), 1, "4/0 is not");
    }

    #[test]
    fn percentile_rule_uses_the_bucket_estimate() {
        let dump = sample_dump();
        // p99 of {1,2,3,10,200} sits in 200's bucket [128,255]
        let tight = SloSpec::parse(
            r#"{"slo":[{"kind":"percentile","metric":"driver_queue_depth","p":99,"max":100}]}"#,
        )
        .unwrap();
        assert_eq!(tight.evaluate(&dump, Path::new(".")).len(), 1);
        let loose = SloSpec::parse(
            r#"{"slo":[{"kind":"percentile","metric":"driver_queue_depth","p":99,"max":255}]}"#,
        )
        .unwrap();
        assert!(loose.evaluate(&dump, Path::new(".")).is_empty());
    }

    #[test]
    fn burn_rule_needs_windows_and_bounds_the_worst_one() {
        let mut dump = sample_dump();
        let spec = SloSpec::parse(
            r#"{"slo":[{"kind":"burn","metric":"fails","max_per_window":2}]}"#,
        )
        .unwrap();
        let v = spec.evaluate(&dump, Path::new("."));
        assert_eq!(v.len(), 1, "windowless dump cannot satisfy a burn rule");
        assert!(v[0].detail.contains("no window series"));

        let r = Registry::new();
        let c = r.counter("fails");
        let mut ws = WindowSnapshotter::new(r, 10.0);
        c.inc();
        ws.tick(10.0);
        c.add(5); // burn spike in window 1
        ws.tick(20.0);
        dump.windows = ws.flush(25.0);
        let v = spec.evaluate(&dump, Path::new("."));
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("5"), "{}", v[0].detail);
    }

    #[test]
    fn bench_rule_reads_the_committed_baseline_schema() {
        let dir = std::env::temp_dir().join(format!("slo_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("BENCH_x.json"),
            r#"{"bench":"x","results":{"a":{"pct":3.0},"b":{"pct":6.0}}}"#,
        )
        .unwrap();
        let spec = SloSpec::parse(
            r#"{"slo":[{"kind":"bench","file":"BENCH_x.json","key":"pct","max":5.0}]}"#,
        )
        .unwrap();
        let v = spec.evaluate(&Dump::default(), &dir);
        assert_eq!(v.len(), 1, "only result b breaches");
        assert!(v[0].detail.contains("b.pct"));
        // missing key and missing file are violations, not silent passes
        let spec = SloSpec::parse(
            r#"{"slo":[
                {"kind":"bench","file":"BENCH_x.json","key":"gone","max":5.0},
                {"kind":"bench","file":"BENCH_missing.json","key":"pct","max":5.0}
            ]}"#,
        )
        .unwrap();
        assert_eq!(spec.evaluate(&Dump::default(), &dir).len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
