//! Sim-time-windowed metric snapshots: the time axis of the observatory.
//!
//! `--obs-window W` closes a window every `W` *simulated* seconds and
//! records the per-window **delta** of every registered counter and
//! histogram (gauges record their level). The snapshotter only reads the
//! registry and the virtual clock — it schedules nothing on the engine,
//! so a windowed run stays bit-identical to an unwindowed one — and the
//! series is deterministic for sim-derived metrics: same seed + same
//! window → the same records, bit for bit. (Histograms fed from the wall
//! clock, e.g. `driver_heartbeat_nanos`, carry wall time and are
//! deterministic only in their counts.)
//!
//! Memory is O(windows), bounded: the ring keeps the newest
//! [`DEFAULT_WINDOW_CAP`] windows and counts what it sheds in
//! `obs_windows_dropped`, so a pathological `--obs-window 0.001` on a
//! week-long sim cannot take the process down.

use std::collections::{BTreeMap, VecDeque};

use super::percentile::Percentiles;
use super::registry::{HistSnapshot, Registry, Snapshot, N_BUCKETS};

/// Ring capacity: newest windows win, older ones are shed and counted.
pub const DEFAULT_WINDOW_CAP: usize = 1 << 12;

/// One closed window: per-metric deltas over `[sim_start, sim_end)`.
/// Zero-delta counters and zero-count histogram deltas are skipped (the
/// series stays dense in *windows*, sparse in *metrics*); gauges record
/// their level at window close.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowRecord {
    pub index: u64,
    pub sim_start: f64,
    pub sim_end: f64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    pub hists: Vec<(String, HistSnapshot)>,
}

/// Closes windows off the virtual clock and accumulates the bounded ring.
/// Drive it with [`tick`](WindowSnapshotter::tick) from the event loop
/// and [`flush`](WindowSnapshotter::flush) once at end of run.
#[derive(Debug)]
pub struct WindowSnapshotter {
    registry: Registry,
    window: f64,
    next_boundary: f64,
    index: u64,
    prev: Snapshot,
    ring: VecDeque<WindowRecord>,
    cap: usize,
    dropped: u64,
}

fn hist_delta(cur: &HistSnapshot, prev: Option<&HistSnapshot>) -> HistSnapshot {
    match prev {
        None => cur.clone(),
        Some(p) => HistSnapshot {
            count: cur.count.saturating_sub(p.count),
            sum: cur.sum.wrapping_sub(p.sum),
            buckets: std::array::from_fn(|i| cur.buckets[i].saturating_sub(p.buckets[i])),
        },
    }
}

impl WindowSnapshotter {
    /// A snapshotter over `registry` closing a window every `window` sim
    /// seconds (values `<= 0` are clamped to one second — a zero cadence
    /// would spin the tick loop forever).
    pub fn new(registry: Registry, window: f64) -> WindowSnapshotter {
        WindowSnapshotter::with_cap(registry, window, DEFAULT_WINDOW_CAP)
    }

    pub fn with_cap(registry: Registry, window: f64, cap: usize) -> WindowSnapshotter {
        let window = if window.is_finite() && window > 0.0 {
            window
        } else {
            1.0
        };
        WindowSnapshotter {
            registry,
            window,
            next_boundary: window,
            index: 0,
            prev: Snapshot::default(),
            ring: VecDeque::new(),
            cap: cap.max(1),
            dropped: 0,
        }
    }

    pub fn window_secs(&self) -> f64 {
        self.window
    }

    /// Windows shed by the bounded ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Advance the window clock to `sim_now`, closing every boundary it
    /// crossed (quiet stretches still produce windows, so the series is
    /// dense). Call from the event loop *before* dispatching the event at
    /// `sim_now`; reads only — never schedules.
    pub fn tick(&mut self, sim_now: f64) {
        while sim_now >= self.next_boundary {
            let end = self.next_boundary;
            self.close_window(end);
            self.next_boundary += self.window;
        }
    }

    /// Close the final partial window at end of run and hand the series
    /// over for export.
    pub fn flush(&mut self, sim_end: f64) -> Vec<WindowRecord> {
        self.tick(sim_end);
        let start = self.next_boundary - self.window;
        if sim_end > start {
            self.close_window(sim_end);
        }
        std::mem::take(&mut self.ring).into_iter().collect()
    }

    fn close_window(&mut self, sim_end: f64) {
        let snap = self.registry.snapshot();
        let mut rec = WindowRecord {
            index: self.index,
            sim_start: self.next_boundary - self.window,
            sim_end,
            ..WindowRecord::default()
        };
        let prev_counters: BTreeMap<&str, u64> = self
            .prev
            .counters
            .iter()
            .map(|(n, v)| (n.as_str(), *v))
            .collect();
        for (name, v) in &snap.counters {
            let delta = v.saturating_sub(prev_counters.get(name.as_str()).copied().unwrap_or(0));
            if delta > 0 {
                rec.counters.push((name.clone(), delta));
            }
        }
        for (name, v) in &snap.gauges {
            if *v > 0 {
                rec.gauges.push((name.clone(), *v));
            }
        }
        let prev_hists: BTreeMap<&str, &HistSnapshot> = self
            .prev
            .histograms
            .iter()
            .map(|(n, h)| (n.as_str(), h))
            .collect();
        for (name, h) in &snap.histograms {
            let d = hist_delta(h, prev_hists.get(name.as_str()).copied());
            if d.count > 0 {
                rec.hists.push((name.clone(), d));
            }
        }
        self.prev = snap;
        self.index += 1;
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(rec);
    }
}

/// Render the window series as a long-format CSV
/// (`window,sim_start,sim_end,kind,name,value,sum,p50,p95,p99`):
/// counters/gauges fill `value`, histograms fill count/sum plus the
/// interpolated percentile triple of that window's delta buckets.
pub fn to_csv(windows: &[WindowRecord]) -> String {
    let mut out = String::from("window,sim_start,sim_end,kind,name,value,sum,p50,p95,p99\n");
    for w in windows {
        let head = |kind: &str, name: &str| {
            format!(
                "{},{:.3},{:.3},{kind},{name}",
                w.index, w.sim_start, w.sim_end
            )
        };
        for (name, v) in &w.counters {
            out.push_str(&format!("{},{v},,,,\n", head("counter", name)));
        }
        for (name, v) in &w.gauges {
            out.push_str(&format!("{},{v},,,,\n", head("gauge", name)));
        }
        for (name, h) in &w.hists {
            let p = Percentiles::of(h);
            out.push_str(&format!(
                "{},{},{},{:.1},{:.1},{:.1}\n",
                head("hist", name),
                h.count,
                h.sum,
                p.p50,
                p.p95,
                p.p99
            ));
        }
    }
    out
}

/// Sum one counter's deltas across the whole series (diff/SLO helper).
pub fn counter_total(windows: &[WindowRecord], name: &str) -> u64 {
    windows
        .iter()
        .flat_map(|w| &w.counters)
        .filter(|(n, _)| n == name)
        .map(|(_, v)| v)
        .sum()
}

/// The maximum per-window delta of one counter (burn-rate evaluation).
pub fn max_window_delta(windows: &[WindowRecord], name: &str) -> u64 {
    windows
        .iter()
        .map(|w| {
            w.counters
                .iter()
                .filter(|(n, _)| n == name)
                .map(|(_, v)| *v)
                .sum::<u64>()
        })
        .max()
        .unwrap_or(0)
}

/// Merge a window series back into one cumulative histogram per name —
/// what lets a windowed JSONL dump answer whole-run percentile questions.
pub fn merged_hists(windows: &[WindowRecord]) -> BTreeMap<String, HistSnapshot> {
    let mut out: BTreeMap<String, HistSnapshot> = BTreeMap::new();
    for (name, h) in windows.iter().flat_map(|w| &w.hists) {
        let m = out.entry(name.clone()).or_insert_with(|| HistSnapshot {
            count: 0,
            sum: 0,
            buckets: [0; N_BUCKETS],
        });
        m.count += h.count;
        m.sum = m.sum.wrapping_add(h.sum);
        for i in 0..N_BUCKETS {
            m.buckets[i] += h.buckets[i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_carry_deltas_not_totals() {
        let r = Registry::new();
        let c = r.counter("ev");
        let h = r.histogram("lat");
        let mut ws = WindowSnapshotter::new(r, 10.0);
        c.add(3);
        h.record(100);
        ws.tick(12.0); // closes [0,10)
        c.add(2);
        h.record(200);
        h.record(300);
        let wins = ws.flush(15.0); // closes [10,15)
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[0].counters, vec![("ev".to_string(), 3)]);
        assert_eq!(wins[1].counters, vec![("ev".to_string(), 2)]);
        assert_eq!(wins[0].hists[0].1.count, 1);
        assert_eq!(wins[1].hists[0].1.count, 2);
        assert_eq!(wins[1].hists[0].1.sum, 500);
        assert_eq!(counter_total(&wins, "ev"), 5);
        assert_eq!(max_window_delta(&wins, "ev"), 3);
        let merged = merged_hists(&wins);
        assert_eq!(merged["lat"].count, 3);
        assert_eq!(merged["lat"].sum, 600);
    }

    #[test]
    fn quiet_stretches_still_close_windows() {
        let r = Registry::new();
        let c = r.counter("ev");
        let mut ws = WindowSnapshotter::new(r, 5.0);
        c.inc();
        ws.tick(23.0); // crosses 5, 10, 15, 20
        let wins = ws.flush(23.0);
        assert_eq!(wins.len(), 5, "4 full + 1 partial");
        assert_eq!(wins[0].counters.len(), 1);
        for w in &wins[1..4] {
            assert!(w.counters.is_empty(), "quiet window must be empty");
        }
        assert_eq!(wins[4].sim_start, 20.0);
        assert_eq!(wins[4].sim_end, 23.0);
        let idx: Vec<u64> = wins.iter().map(|w| w.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn flush_without_trailing_activity_adds_no_empty_partial() {
        let r = Registry::new();
        r.counter("ev").inc();
        let mut ws = WindowSnapshotter::new(r, 10.0);
        ws.tick(20.0); // closes [0,10) and [10,20)
        let wins = ws.flush(20.0); // boundary exactly: no partial after it
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[1].sim_end, 20.0);
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let r = Registry::new();
        let c = r.counter("ev");
        let mut ws = WindowSnapshotter::with_cap(r, 1.0, 3);
        for t in 1..=10 {
            c.inc();
            ws.tick(t as f64 + 0.5);
        }
        assert_eq!(ws.dropped(), 7);
        let wins = ws.flush(10.5);
        assert!(wins.len() <= 4, "cap 3 + final partial");
        assert_eq!(wins.last().unwrap().index, 10, "newest windows survive");
    }

    #[test]
    fn bad_window_values_are_clamped() {
        for w in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            let ws = WindowSnapshotter::new(Registry::new(), w);
            assert_eq!(ws.window_secs(), 1.0);
        }
    }

    #[test]
    fn csv_is_long_format_with_percentiles() {
        let r = Registry::new();
        r.counter("ev").add(4);
        r.gauge("depth").set(7);
        let h = r.histogram("lat");
        h.record(1500);
        let mut ws = WindowSnapshotter::new(r, 10.0);
        ws.tick(10.0);
        let wins = ws.flush(10.0);
        let csv = to_csv(&wins);
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "window,sim_start,sim_end,kind,name,value,sum,p50,p95,p99"
        );
        assert!(csv.contains("0,0.000,10.000,counter,ev,4,,,,"));
        assert!(csv.contains("0,0.000,10.000,gauge,depth,7,,,,"));
        let hist_line = csv
            .lines()
            .find(|l| l.contains(",hist,lat,"))
            .expect("hist row");
        let cols: Vec<&str> = hist_line.split(',').collect();
        assert_eq!(cols[5], "1", "count");
        assert_eq!(cols[6], "1500", "sum");
        // percentiles of a single 1500 land in its [1024,2047] bucket
        for c in &cols[7..10] {
            let v: f64 = c.parse().unwrap();
            assert!((1024.0..=2047.0).contains(&v), "{v}");
        }
    }
}
