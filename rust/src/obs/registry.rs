//! Named counters, gauges, and log-bucketed histograms.
//!
//! The registry is the single naming authority: asking for `counter("x")`
//! twice returns two handles onto the SAME atomic cell, so any layer can
//! pick up a metric by name without plumbing handles through every
//! constructor. The record path is one relaxed load (the shared enabled
//! flag) plus one to three relaxed `fetch_add`s — no locks, no allocation
//! — cheap enough for the engine hot loop. Registration (`counter` /
//! `gauge` / `histogram`) takes a mutex and allocates; do it once at
//! setup, never per event.
//!
//! Histograms are HDR-style log-bucketed: value `v` lands in bucket
//! `64 - v.leading_zeros()` (bucket 0 holds zeros), so [`N_BUCKETS`]
//! buckets cover the whole `u64` range with power-of-two boundaries,
//! while an exact `count`/`sum` pair keeps means precise — that is what
//! lets `Metrics::mean_assign_micros` ride on a histogram without
//! changing its reported numbers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Number of histogram buckets: one for zero plus one per bit of `u64`.
pub const N_BUCKETS: usize = 65;

/// Bucket index for a recorded value: 0 for 0, else `64 - leading_zeros`
/// (1 -> 1, 2..=3 -> 2, 4..=7 -> 3, ..., `u64::MAX` -> 64).
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros()) as usize
    }
}

/// Inclusive upper bound of bucket `i` — the exporter's `le` label.
pub fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Monotone counter. Clone shares the underlying cell.
#[derive(Clone, Debug)]
pub struct Counter {
    enabled: Arc<AtomicBool>,
    cell: Arc<AtomicU64>,
}

impl Counter {
    fn with_flag(enabled: Arc<AtomicBool>) -> Counter {
        Counter {
            enabled,
            cell: Arc::new(AtomicU64::new(0)),
        }
    }

    /// A handle not backed by any registry; always enabled, never
    /// exported. Used both as the pre-`install_obs` default inside
    /// `Metrics` and as the sink returned on a name collision.
    pub fn detached() -> Counter {
        Counter::with_flag(Arc::new(AtomicBool::new(true)))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::detached()
    }
}

/// Last-write-wins gauge.
#[derive(Clone, Debug)]
pub struct Gauge {
    enabled: Arc<AtomicBool>,
    cell: Arc<AtomicU64>,
}

impl Gauge {
    fn with_flag(enabled: Arc<AtomicBool>) -> Gauge {
        Gauge {
            enabled,
            cell: Arc::new(AtomicU64::new(0)),
        }
    }

    /// See [`Counter::detached`].
    pub fn detached() -> Gauge {
        Gauge::with_flag(Arc::new(AtomicBool::new(true)))
    }

    pub fn set(&self, v: u64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.store(v, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::detached()
    }
}

#[derive(Debug)]
struct HistCells {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

/// Log-bucketed histogram with an exact count/sum pair. The record path
/// is three relaxed `fetch_add`s; `sum` wraps on overflow (nanosecond
/// latencies would need ~585 years of recorded time to get there).
#[derive(Clone, Debug)]
pub struct Histogram {
    enabled: Arc<AtomicBool>,
    cells: Arc<HistCells>,
}

impl Histogram {
    fn with_flag(enabled: Arc<AtomicBool>) -> Histogram {
        Histogram {
            enabled,
            cells: Arc::new(HistCells {
                count: AtomicU64::new(0),
                sum: AtomicU64::new(0),
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            }),
        }
    }

    /// See [`Counter::detached`].
    pub fn detached() -> Histogram {
        Histogram::with_flag(Arc::new(AtomicBool::new(true)))
    }

    pub fn record(&self, v: u64) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        self.cells.count.fetch_add(1, Ordering::Relaxed);
        self.cells.sum.fetch_add(v, Ordering::Relaxed);
        self.cells.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.cells.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.cells.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count(),
            sum: self.sum(),
            buckets: std::array::from_fn(|i| self.cells.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::detached()
    }
}

/// Point-in-time copy of one histogram, for exporters and tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; N_BUCKETS],
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

#[derive(Debug)]
struct Inner {
    enabled: Arc<AtomicBool>,
    collisions: AtomicU64,
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            enabled: Arc::new(AtomicBool::new(true)),
            collisions: AtomicU64::new(0),
            metrics: Mutex::new(BTreeMap::new()),
        }
    }
}

/// Shared, clonable handle onto one family of named metrics.
///
/// Same name + same kind returns a handle onto the same cell. Same name
/// with a DIFFERENT kind is a collision: the `obs_collisions` counter is
/// bumped and a detached handle is returned, so the caller still works
/// but the conflict is visible in every export.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Flip the shared enabled flag checked (relaxed) by every record
    /// call of every handle this registry has issued.
    pub fn set_enabled(&self, on: bool) {
        self.inner.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.inner.enabled.load(Ordering::Relaxed)
    }

    fn table(&self) -> MutexGuard<'_, BTreeMap<String, Metric>> {
        match self.inner.metrics.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn collide(&self) -> u64 {
        self.inner.collisions.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut table = self.table();
        match table.get(name) {
            Some(Metric::Counter(c)) => c.clone(),
            Some(_) => {
                drop(table);
                self.collide();
                Counter::detached()
            }
            None => {
                let c = Counter::with_flag(self.inner.enabled.clone());
                table.insert(name.to_string(), Metric::Counter(c.clone()));
                c
            }
        }
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut table = self.table();
        match table.get(name) {
            Some(Metric::Gauge(g)) => g.clone(),
            Some(_) => {
                drop(table);
                self.collide();
                Gauge::detached()
            }
            None => {
                let g = Gauge::with_flag(self.inner.enabled.clone());
                table.insert(name.to_string(), Metric::Gauge(g.clone()));
                g
            }
        }
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        let mut table = self.table();
        match table.get(name) {
            Some(Metric::Histogram(h)) => h.clone(),
            Some(_) => {
                drop(table);
                self.collide();
                Histogram::detached()
            }
            None => {
                let h = Histogram::with_flag(self.inner.enabled.clone());
                table.insert(name.to_string(), Metric::Histogram(h.clone()));
                h
            }
        }
    }

    /// Kind-mismatch registrations observed so far.
    pub fn collisions(&self) -> u64 {
        self.inner.collisions.load(Ordering::Relaxed)
    }

    /// Sorted point-in-time copy of every metric, plus the registry's
    /// own `obs_collisions` counter.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        for (name, metric) in self.table().iter() {
            match metric {
                Metric::Counter(c) => snap.counters.push((name.clone(), c.get())),
                Metric::Gauge(g) => snap.gauges.push((name.clone(), g.get())),
                Metric::Histogram(h) => snap.histograms.push((name.clone(), h.snapshot())),
            }
        }
        snap.counters
            .push(("obs_collisions".to_string(), self.collisions()));
        snap.counters.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }
}

/// Everything an exporter needs, sorted by name for deterministic output.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    pub histograms: Vec<(String, HistSnapshot)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        for k in 1..64usize {
            let v = 1u64 << k;
            assert_eq!(bucket_index(v), k + 1, "2^{k} opens bucket {}", k + 1);
            assert_eq!(bucket_index(v - 1), k, "2^{k}-1 closes bucket {k}");
        }
        assert_eq!(bucket_index(u64::MAX), 64);
    }

    #[test]
    fn bucket_upper_brackets_every_value() {
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(63), (1u64 << 63) - 1);
        assert_eq!(bucket_upper(64), u64::MAX);
        for v in [0u64, 1, 2, 3, 4, 5, 1023, 1024, 1025, u64::MAX - 1, u64::MAX] {
            let i = bucket_index(v);
            assert!(bucket_upper(i) >= v, "upper({i}) must cover {v}");
            if i > 0 {
                assert!(bucket_upper(i - 1) < v, "{v} must not fit bucket {}", i - 1);
            }
        }
    }

    #[test]
    fn histogram_is_exact_and_buckets_add_up() {
        let h = Histogram::detached();
        h.record(0);
        h.record(1);
        h.record(2000);
        h.record(4000);
        h.record(u64::MAX);
        let snap = h.snapshot();
        assert_eq!(snap.count, 5);
        // sum wraps on u64::MAX by design; check the exact pair without it
        assert_eq!(snap.buckets.iter().sum::<u64>(), 5);
        assert_eq!(snap.buckets[0], 1);
        assert_eq!(snap.buckets[1], 1);
        assert_eq!(snap.buckets[11], 1, "2000 lands in [1024, 2047]");
        assert_eq!(snap.buckets[12], 1, "4000 lands in [2048, 4095]");
        assert_eq!(snap.buckets[64], 1);
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn histogram_mean_is_exact_for_small_sums() {
        let h = Histogram::detached();
        assert_eq!(h.count(), 0);
        assert!(h.mean().abs() < f64::EPSILON);
        h.record(2000);
        h.record(4000);
        assert_eq!(h.sum(), 6000);
        assert!((h.mean() - 3000.0).abs() < 1e-12);
    }

    #[test]
    fn same_name_same_kind_shares_the_cell() {
        let r = Registry::new();
        let a = r.counter("hits");
        let b = r.counter("hits");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(b.get(), 3);
        assert_eq!(r.collisions(), 0);
    }

    #[test]
    fn kind_collision_returns_detached_and_counts() {
        let r = Registry::new();
        let c = r.counter("x");
        c.inc();
        let h = r.histogram("x");
        let g = r.gauge("x");
        assert_eq!(r.collisions(), 2);
        // the detached handles still work, they just are not exported
        h.record(7);
        g.set(9);
        assert_eq!(h.count(), 1);
        assert_eq!(g.get(), 9);
        // the original registration is untouched
        assert_eq!(c.get(), 1);
        let snap = r.snapshot();
        assert_eq!(snap.histograms.len(), 0);
        assert_eq!(snap.gauges.len(), 0);
        let coll = snap
            .counters
            .iter()
            .find(|(n, _)| n == "obs_collisions")
            .map(|(_, v)| *v);
        assert_eq!(coll, Some(2));
    }

    #[test]
    fn disabling_the_registry_mutes_every_handle() {
        let r = Registry::new();
        let c = r.counter("c");
        let g = r.gauge("g");
        let h = r.histogram("h");
        r.set_enabled(false);
        c.inc();
        g.set(5);
        h.record(5);
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0);
        assert_eq!(h.count(), 0);
        r.set_enabled(true);
        c.inc();
        assert_eq!(c.get(), 1);
        // detached handles have their own always-on flag
        let d = Counter::detached();
        r.set_enabled(false);
        d.inc();
        assert_eq!(d.get(), 1);
    }

    #[test]
    fn snapshot_is_sorted_by_name() {
        let r = Registry::new();
        r.counter("zz");
        r.counter("aa");
        r.gauge("mid");
        let snap = r.snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["aa", "obs_collisions", "zz"]);
        assert_eq!(snap.gauges.len(), 1);
    }
}
