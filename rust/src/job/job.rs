//! A MapReduce job: metadata + its task vectors + progress accounting.

use crate::bayes::features::JobFeatures;
use crate::bayes::utility::Priority;
use crate::cluster::resources::Resources;
use crate::hdfs::BlockId;
use crate::sim::engine::Time;

use super::profile::{demand_from_profile, JobClass};
use super::task::{Task, TaskKind, TaskRef};
use super::JobId;

/// Everything needed to create a job (produced by the workload generator or
/// parsed from a trace file).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub user: String,
    /// Fair-scheduler pool (defaults to the user).
    pub pool: String,
    /// Capacity-scheduler queue.
    pub queue: String,
    pub class: JobClass,
    pub priority: Priority,
    pub profile: JobFeatures,
    /// Work seconds per map task (speed-1 node, local read).
    pub map_works: Vec<f64>,
    /// Work seconds per reduce task.
    pub reduce_works: Vec<f64>,
    /// Arrival time in the simulation.
    pub submit_time: Time,
}

/// Completion summary (metrics input).
#[derive(Debug, Clone, Copy)]
pub struct JobOutcome {
    pub submit_time: Time,
    pub first_launch: Option<Time>,
    pub finish_time: Time,
    /// Total task attempts minus tasks = extra executions beyond one per
    /// task (failure re-runs plus speculative backup copies).
    pub wasted_attempts: u32,
}

/// Live job state inside the JobTracker.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub spec: JobSpec,
    /// Per-map-task resource demand on a node.
    pub demand: Resources,
    pub maps: Vec<Task>,
    pub reduces: Vec<Task>,
    pub maps_done: u32,
    pub reduces_done: u32,
    /// O(1) pending-task counters (maintained by the *_task wrappers; the
    /// scheduler consults these on every decision — perf §Perf).
    pending_map_count: u32,
    pending_reduce_count: u32,
    pub first_launch: Option<Time>,
    pub finish_time: Option<Time>,
    /// True when the job was killed after a task exceeded its attempt
    /// budget (Hadoop's mapreduce.*.maxattempts semantics).
    pub failed: bool,
}

impl Job {
    /// Instantiate a job: map tasks get blocks assigned by the caller (HDFS
    /// placement happens at submit in `JobTable::submit`).
    pub fn new(id: JobId, spec: JobSpec, blocks: Vec<BlockId>) -> Job {
        assert_eq!(spec.map_works.len(), blocks.len());
        let maps = spec
            .map_works
            .iter()
            .zip(&blocks)
            .enumerate()
            .map(|(i, (&w, &b))| Task::map(i as u32, w, b))
            .collect();
        let reduces = spec
            .reduce_works
            .iter()
            .enumerate()
            .map(|(i, &w)| Task::reduce(i as u32, w))
            .collect();
        let demand = demand_from_profile(&spec.profile);
        let pending_map_count = spec.map_works.len() as u32;
        let pending_reduce_count = spec.reduce_works.len() as u32;
        Job {
            id,
            spec,
            demand,
            maps,
            reduces,
            maps_done: 0,
            reduces_done: 0,
            pending_map_count,
            pending_reduce_count,
            first_launch: None,
            finish_time: None,
            failed: false,
        }
    }

    pub fn task(&self, r: &TaskRef) -> &Task {
        debug_assert_eq!(r.job, self.id);
        match r.kind {
            TaskKind::Map => &self.maps[r.index as usize],
            TaskKind::Reduce => &self.reduces[r.index as usize],
        }
    }

    pub fn task_mut(&mut self, r: &TaskRef) -> &mut Task {
        debug_assert_eq!(r.job, self.id);
        match r.kind {
            TaskKind::Map => &mut self.maps[r.index as usize],
            TaskKind::Reduce => &mut self.reduces[r.index as usize],
        }
    }

    /// All maps finished (reduces become eligible — the simulator models
    /// reduce slowstart at 100%, i.e. shuffle starts after the map phase).
    pub fn maps_complete(&self) -> bool {
        self.maps_done as usize == self.maps.len()
    }

    pub fn is_complete(&self) -> bool {
        self.maps_complete() && self.reduces_done as usize == self.reduces.len()
    }

    /// Any task currently schedulable (pending map; pending reduce once the
    /// map phase is done)?
    pub fn has_schedulable_task(&self) -> bool {
        self.pending_maps() > 0 || (self.maps_complete() && self.pending_reduces() > 0)
    }

    pub fn pending_maps(&self) -> usize {
        self.pending_map_count as usize
    }

    pub fn pending_reduces(&self) -> usize {
        self.pending_reduce_count as usize
    }

    /// Transition a task Pending -> Running, maintaining the counters.
    pub fn start_task(&mut self, r: &TaskRef, node: crate::cluster::node::NodeId, now: Time) {
        self.task_mut(r).start(node, now);
        match r.kind {
            TaskKind::Map => self.pending_map_count -= 1,
            TaskKind::Reduce => self.pending_reduce_count -= 1,
        }
        if self.first_launch.is_none() {
            self.first_launch = Some(now);
        }
    }

    /// Transition a task Running -> Done, maintaining done counters.
    pub fn complete_task(&mut self, r: &TaskRef, now: Time) {
        self.task_mut(r).complete(now);
        match r.kind {
            TaskKind::Map => self.maps_done += 1,
            TaskKind::Reduce => self.reduces_done += 1,
        }
    }

    /// Transition a task Running -> Pending (failure), maintaining counters.
    pub fn requeue_task(&mut self, r: &TaskRef) {
        self.task_mut(r).requeue();
        match r.kind {
            TaskKind::Map => self.pending_map_count += 1,
            TaskKind::Reduce => self.pending_reduce_count += 1,
        }
    }

    /// Launch a speculative backup copy of a running task. The pending
    /// counters are untouched (the task is not pending); only the attempt
    /// count grows.
    pub fn start_speculative(&mut self, r: &TaskRef, node: crate::cluster::node::NodeId, now: Time) {
        self.task_mut(r).start_speculative(node, now);
    }

    /// No attempt of this job is left anywhere in the cluster (neither a
    /// primary `Running` state nor a live backup). Drivers gate the final
    /// `JobCompleted` notification on this for killed jobs, so schedulers
    /// can drop per-job state without missing late attempt-end events.
    pub fn fully_drained(&self) -> bool {
        !self
            .maps
            .iter()
            .chain(&self.reduces)
            .any(|t| t.is_running() || t.speculative.is_some())
    }

    pub fn running_tasks(&self) -> usize {
        self.maps.iter().chain(&self.reduces).filter(|t| t.is_running()).count()
    }

    pub fn total_tasks(&self) -> usize {
        self.maps.len() + self.reduces.len()
    }

    /// Sum of attempts over all tasks.
    pub fn total_attempts(&self) -> u32 {
        self.maps.iter().chain(&self.reduces).map(|t| t.attempts).sum()
    }

    pub fn outcome(&self) -> Option<JobOutcome> {
        self.finish_time.map(|finish_time| JobOutcome {
            submit_time: self.spec.submit_time,
            first_launch: self.first_launch,
            finish_time,
            wasted_attempts: self.total_attempts() - self.total_tasks() as u32,
        })
    }
}

#[cfg(test)]
pub fn test_spec(name: &str, n_maps: usize, n_reduces: usize) -> JobSpec {
    JobSpec {
        name: name.to_string(),
        user: "alice".into(),
        pool: "alice".into(),
        queue: "default".into(),
        class: JobClass::Small,
        priority: Priority::Normal,
        profile: JobClass::Small.base_features(),
        map_works: vec![10.0; n_maps],
        reduce_works: vec![20.0; n_reduces],
        submit_time: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::NodeId;

    fn job(n_maps: usize, n_reduces: usize) -> Job {
        let blocks = (0..n_maps as u64).map(BlockId).collect();
        Job::new(JobId::dense(0), test_spec("j", n_maps, n_reduces), blocks)
    }

    #[test]
    fn new_job_counts() {
        let j = job(4, 2);
        assert_eq!(j.pending_maps(), 4);
        assert_eq!(j.pending_reduces(), 2);
        assert_eq!(j.total_tasks(), 6);
        assert!(!j.is_complete());
        assert!(j.has_schedulable_task());
    }

    #[test]
    fn reduces_gated_on_map_phase() {
        let mut j = job(2, 1);
        assert!(j.pending_reduces() > 0 && !j.maps_complete());
        // only maps schedulable now
        j.maps[0].start(NodeId(0), 1.0);
        j.maps[0].complete(5.0);
        j.maps_done += 1;
        assert!(!j.maps_complete());
        j.maps[1].start(NodeId(0), 1.0);
        j.maps[1].complete(6.0);
        j.maps_done += 1;
        assert!(j.maps_complete());
        assert!(j.has_schedulable_task()); // reduce now eligible
    }

    #[test]
    fn completion() {
        let mut j = job(1, 1);
        j.maps[0].start(NodeId(0), 0.0);
        j.maps[0].complete(3.0);
        j.maps_done = 1;
        j.reduces[0].start(NodeId(0), 3.0);
        j.reduces[0].complete(9.0);
        j.reduces_done = 1;
        assert!(j.is_complete());
        j.finish_time = Some(9.0);
        let o = j.outcome().unwrap();
        assert_eq!(o.finish_time, 9.0);
        assert_eq!(o.wasted_attempts, 0);
    }

    #[test]
    fn wasted_attempts_counts_requeues() {
        let mut j = job(1, 0);
        j.maps[0].start(NodeId(0), 0.0);
        j.maps[0].requeue();
        j.maps[0].start(NodeId(1), 2.0);
        j.maps[0].complete(5.0);
        j.maps_done = 1;
        j.finish_time = Some(5.0);
        assert_eq!(j.outcome().unwrap().wasted_attempts, 1);
    }

    #[test]
    #[should_panic]
    fn mismatched_blocks_panic() {
        let _ = Job::new(JobId::dense(0), test_spec("j", 3, 0), vec![BlockId(0)]);
    }
}
