//! Tasks: the schedulable unit. A job is "divided into multiple tasks and
//! job scheduling implements the function that distribute the tasks of a
//! job to a TaskTracker" (paper §4.1).

use crate::cluster::node::NodeId;
use crate::hdfs::BlockId;
use crate::sim::engine::Time;

use super::JobId;

/// Map or reduce (MRv1 slots are typed, paper §2.1 notes the waste this
/// causes — reproduced faithfully).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Map,
    Reduce,
}

/// Globally unique task handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TaskRef {
    pub job: JobId,
    pub kind: TaskKind,
    pub index: u32,
}

impl std::fmt::Display for TaskRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let k = match self.kind {
            TaskKind::Map => "m",
            TaskKind::Reduce => "r",
        };
        write!(f, "{}_{}{:05}", self.job, k, self.index)
    }
}

/// Task lifecycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskState {
    /// Waiting in the job for a slot.
    Pending,
    /// Executing on a node since `start`.
    Running { node: NodeId, start: Time },
    /// Finished at `finish` (wall time includes contention slowdowns).
    Done { finish: Time },
}

/// One map or reduce task.
#[derive(Debug, Clone)]
pub struct Task {
    pub kind: TaskKind,
    pub index: u32,
    /// Seconds of work on a speed-1.0 node with node-local input.
    pub work: f64,
    /// Input block (maps only) — drives the locality decision.
    pub block: Option<BlockId>,
    pub state: TaskState,
    /// Execution attempts (> 1 after failures/OOM re-queues).
    pub attempts: u32,
    /// Bumped whenever the task's completion event is rescheduled; stale
    /// events carry the old generation and are dropped.
    pub generation: u32,
}

impl Task {
    pub fn map(index: u32, work: f64, block: BlockId) -> Task {
        Task {
            kind: TaskKind::Map,
            index,
            work,
            block: Some(block),
            state: TaskState::Pending,
            attempts: 0,
            generation: 0,
        }
    }

    pub fn reduce(index: u32, work: f64) -> Task {
        Task {
            kind: TaskKind::Reduce,
            index,
            work,
            block: None,
            state: TaskState::Pending,
            attempts: 0,
            generation: 0,
        }
    }

    pub fn is_pending(&self) -> bool {
        matches!(self.state, TaskState::Pending)
    }

    pub fn is_running(&self) -> bool {
        matches!(self.state, TaskState::Running { .. })
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, TaskState::Done { .. })
    }

    /// Transition Pending -> Running.
    pub fn start(&mut self, node: NodeId, now: Time) {
        debug_assert!(self.is_pending(), "starting non-pending task");
        self.state = TaskState::Running { node, start: now };
        self.attempts += 1;
        self.generation += 1;
    }

    /// Transition Running -> Done.
    pub fn complete(&mut self, now: Time) {
        debug_assert!(self.is_running(), "completing non-running task");
        self.state = TaskState::Done { finish: now };
    }

    /// Transition Running -> Pending (failure re-queue).
    pub fn requeue(&mut self) {
        debug_assert!(self.is_running(), "requeueing non-running task");
        self.state = TaskState::Pending;
        self.generation += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut t = Task::map(0, 10.0, BlockId(3));
        assert!(t.is_pending());
        t.start(NodeId(1), 5.0);
        assert!(t.is_running());
        assert_eq!(t.attempts, 1);
        t.complete(20.0);
        assert_eq!(t.state, TaskState::Done { finish: 20.0 });
    }

    #[test]
    fn requeue_increments_generation() {
        let mut t = Task::map(0, 10.0, BlockId(0));
        t.start(NodeId(0), 0.0);
        let g = t.generation;
        t.requeue();
        assert!(t.is_pending());
        assert_eq!(t.generation, g + 1);
        t.start(NodeId(2), 1.0);
        assert_eq!(t.attempts, 2);
    }

    #[test]
    fn reduce_has_no_block() {
        let t = Task::reduce(4, 30.0);
        assert_eq!(t.block, None);
        assert_eq!(t.kind, TaskKind::Reduce);
    }

    #[test]
    fn display_formats() {
        let r = TaskRef { job: JobId(7), kind: TaskKind::Map, index: 3 };
        assert_eq!(r.to_string(), "job_0007_m00003");
    }
}
