//! Tasks: the schedulable unit. A job is "divided into multiple tasks and
//! job scheduling implements the function that distribute the tasks of a
//! job to a TaskTracker" (paper §4.1).

use crate::cluster::node::NodeId;
use crate::hdfs::BlockId;
use crate::sim::engine::Time;

use super::JobId;

/// Map or reduce (MRv1 slots are typed, paper §2.1 notes the waste this
/// causes — reproduced faithfully).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TaskKind {
    Map,
    Reduce,
}

/// Globally unique task handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskRef {
    pub job: JobId,
    pub kind: TaskKind,
    pub index: u32,
}

impl std::fmt::Display for TaskRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let k = match self.kind {
            TaskKind::Map => "m",
            TaskKind::Reduce => "r",
        };
        write!(f, "{}_{}{:05}", self.job, k, self.index)
    }
}

/// Task lifecycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskState {
    /// Waiting in the job for a slot.
    Pending,
    /// Executing on a node since `start` (the *primary* attempt; a
    /// concurrent backup copy lives in [`Task::speculative`]).
    Running { node: NodeId, start: Time },
    /// Finished at `finish` (wall time includes contention slowdowns).
    Done { finish: Time },
}

/// A live speculative backup attempt, racing the primary attempt on a
/// different node (first copy to finish wins; the loser is cancelled).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecAttempt {
    pub node: NodeId,
    pub start: Time,
}

/// One map or reduce task.
#[derive(Debug, Clone)]
pub struct Task {
    pub kind: TaskKind,
    pub index: u32,
    /// Seconds of work on a speed-1.0 node with node-local input.
    pub work: f64,
    /// Input block (maps only) — drives the locality decision.
    pub block: Option<BlockId>,
    pub state: TaskState,
    /// Execution attempts (> 1 after failures/OOM re-queues or a
    /// speculative backup launch).
    pub attempts: u32,
    /// Attempts that ended in an OOM failure. This — not `attempts` —
    /// feeds the `max_task_attempts` job-kill check (Hadoop's maxattempts
    /// counts FAILED attempts; node-loss kills and speculative launches
    /// must not erode a job's failure budget).
    pub failed_attempts: u32,
    /// Event stamp of the **primary** attempt: completion/fail events
    /// carry the stamp current at schedule time; stale events mismatch and
    /// are dropped. Stamps for both attempts are allocated from one shared
    /// monotone counter ([`Task::next_stamp`]), so a `(node, stamp)` pair
    /// can never be reused by a different attempt.
    pub generation: u32,
    /// Event stamp of the live (or most recent) backup attempt.
    pub spec_generation: u32,
    /// The live backup attempt, if one is racing the primary.
    pub speculative: Option<SpecAttempt>,
}

impl Task {
    pub fn map(index: u32, work: f64, block: BlockId) -> Task {
        Task {
            kind: TaskKind::Map,
            index,
            work,
            block: Some(block),
            state: TaskState::Pending,
            attempts: 0,
            failed_attempts: 0,
            generation: 0,
            spec_generation: 0,
            speculative: None,
        }
    }

    pub fn reduce(index: u32, work: f64) -> Task {
        Task {
            kind: TaskKind::Reduce,
            index,
            work,
            block: None,
            state: TaskState::Pending,
            attempts: 0,
            failed_attempts: 0,
            generation: 0,
            spec_generation: 0,
            speculative: None,
        }
    }

    /// Allocate the next event stamp (shared monotone counter across both
    /// attempts — see the `generation` field docs).
    pub fn next_stamp(&self) -> u32 {
        self.generation.max(self.spec_generation) + 1
    }

    pub fn is_pending(&self) -> bool {
        matches!(self.state, TaskState::Pending)
    }

    pub fn is_running(&self) -> bool {
        matches!(self.state, TaskState::Running { .. })
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, TaskState::Done { .. })
    }

    /// Transition Pending -> Running.
    pub fn start(&mut self, node: NodeId, now: Time) {
        debug_assert!(self.is_pending(), "starting non-pending task");
        debug_assert!(self.speculative.is_none(), "pending task with backup");
        self.state = TaskState::Running { node, start: now };
        self.attempts += 1;
        self.generation = self.next_stamp();
    }

    /// Transition Running -> Done.
    pub fn complete(&mut self, now: Time) {
        debug_assert!(self.is_running(), "completing non-running task");
        debug_assert!(self.speculative.is_none(), "completing with live backup");
        self.state = TaskState::Done { finish: now };
    }

    /// Transition Running -> Pending (failure re-queue).
    pub fn requeue(&mut self) {
        debug_assert!(self.is_running(), "requeueing non-running task");
        debug_assert!(self.speculative.is_none(), "requeueing with live backup");
        self.state = TaskState::Pending;
        self.generation = self.next_stamp();
    }

    /// Launch a speculative backup copy on `node` while the primary keeps
    /// running elsewhere.
    pub fn start_speculative(&mut self, node: NodeId, now: Time) {
        debug_assert!(self.is_running(), "backup of a non-running task");
        debug_assert!(self.speculative.is_none(), "task already has a backup");
        debug_assert!(
            !matches!(self.state, TaskState::Running { node: n, .. } if n == node),
            "backup on the primary's own node"
        );
        self.attempts += 1;
        self.spec_generation = self.next_stamp();
        self.speculative = Some(SpecAttempt { node, start: now });
    }

    /// Drop the live backup attempt (it lost the race, failed, or its node
    /// died). Its pending events die with `speculative == None`.
    pub fn cancel_speculative(&mut self) {
        debug_assert!(self.speculative.is_some(), "no backup to cancel");
        self.speculative = None;
    }

    /// The primary's node died but the backup lives: the backup becomes
    /// the primary in place, keeping its event stamp valid (the pending
    /// completion event re-validates through the primary path because the
    /// `(node, stamp)` pair is unchanged).
    pub fn promote_speculative(&mut self) {
        // caller checked `speculative` -- lint: allow(unwrap-in-lib)
        let s = self.speculative.take().expect("no backup to promote");
        debug_assert!(self.is_running(), "promoting backup of non-running task");
        self.state = TaskState::Running { node: s.node, start: s.start };
        self.generation = self.spec_generation;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut t = Task::map(0, 10.0, BlockId(3));
        assert!(t.is_pending());
        t.start(NodeId(1), 5.0);
        assert!(t.is_running());
        assert_eq!(t.attempts, 1);
        t.complete(20.0);
        assert_eq!(t.state, TaskState::Done { finish: 20.0 });
    }

    #[test]
    fn requeue_increments_generation() {
        let mut t = Task::map(0, 10.0, BlockId(0));
        t.start(NodeId(0), 0.0);
        let g = t.generation;
        t.requeue();
        assert!(t.is_pending());
        assert_eq!(t.generation, g + 1);
        t.start(NodeId(2), 1.0);
        assert_eq!(t.attempts, 2);
    }

    #[test]
    fn reduce_has_no_block() {
        let t = Task::reduce(4, 30.0);
        assert_eq!(t.block, None);
        assert_eq!(t.kind, TaskKind::Reduce);
    }

    #[test]
    fn display_formats() {
        let r = TaskRef { job: JobId::dense(7), kind: TaskKind::Map, index: 3 };
        assert_eq!(r.to_string(), "job_0007_m00003");
    }

    #[test]
    fn speculative_lifecycle_and_stamps() {
        let mut t = Task::map(0, 10.0, BlockId(0));
        t.start(NodeId(0), 0.0);
        assert_eq!((t.attempts, t.generation), (1, 1));
        t.start_speculative(NodeId(1), 5.0);
        assert_eq!(t.attempts, 2);
        // backup stamp drawn from the shared monotone counter
        assert_eq!(t.spec_generation, 2);
        assert_eq!(t.speculative, Some(SpecAttempt { node: NodeId(1), start: 5.0 }));
        // primary wins: backup cancelled, then completion
        t.cancel_speculative();
        assert!(t.speculative.is_none());
        t.complete(8.0);
        assert!(t.is_done());
    }

    #[test]
    fn promotion_keeps_backup_stamp_valid_as_primary() {
        let mut t = Task::map(0, 10.0, BlockId(0));
        t.start(NodeId(0), 0.0);
        t.start_speculative(NodeId(2), 4.0);
        let backup_stamp = t.spec_generation;
        t.promote_speculative();
        assert_eq!(t.state, TaskState::Running { node: NodeId(2), start: 4.0 });
        assert_eq!(t.generation, backup_stamp);
        assert!(t.speculative.is_none());
        // stamps stay strictly monotone after promotion
        assert!(t.next_stamp() > backup_stamp);
        t.complete(20.0);
        assert!(t.is_done());
    }

    #[test]
    fn stamps_never_repeat_across_requeues_and_backups() {
        let mut t = Task::map(0, 10.0, BlockId(0));
        let mut seen = std::collections::HashSet::new();
        t.start(NodeId(0), 0.0);
        assert!(seen.insert(t.generation));
        t.start_speculative(NodeId(1), 1.0);
        assert!(seen.insert(t.spec_generation));
        t.cancel_speculative();
        t.requeue();
        assert!(seen.insert(t.generation));
        t.start(NodeId(1), 2.0);
        assert!(seen.insert(t.generation));
        t.start_speculative(NodeId(0), 3.0);
        assert!(seen.insert(t.spec_generation));
    }
}
