//! MapReduce job model: jobs, their map/reduce tasks, resource profiles and
//! lifecycle (paper §1: "MapReduce has four parts: the framework of
//! homework submission and initialization, task allocation, task execution
//! and completion").

pub mod job;
pub mod profile;
pub mod queue;
pub mod task;

pub use job::{Job, JobOutcome, JobSpec};
pub use profile::{demand_from_profile, JobClass};
pub use queue::JobTable;
pub use task::{SpecAttempt, Task, TaskKind, TaskRef, TaskState};

/// Job identifier, dense from 0 in submission order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job_{:04}", self.0)
    }
}
