//! MapReduce job model: jobs, their map/reduce tasks, resource profiles and
//! lifecycle (paper §1: "MapReduce has four parts: the framework of
//! homework submission and initialization, task allocation, task execution
//! and completion").

pub mod job;
pub mod profile;
pub mod queue;
pub mod task;

pub use job::{Job, JobOutcome, JobSpec};
pub use profile::{demand_from_profile, JobClass};
pub use queue::JobTable;
pub use task::{SpecAttempt, Task, TaskKind, TaskRef, TaskState};

/// Job identifier: a generational arena handle (see `sim::arena`).
///
/// * `slot` — dense index into the job table's arena. Recycled once the
///   job leaves the system fully drained, so storage stays O(live jobs).
/// * `serial` — globally monotone submission counter, never reused. It is
///   the generation stamp that makes stale handles detectable, the
///   submission-order sort key, and the number shown in displays/traces.
///
/// Two ids are equal only if both fields match; ordering is by `serial`
/// (then `slot`, unreachable for distinct ids in practice), so ordered
/// sets iterate in submission order exactly as before the arena rewrite.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JobId {
    pub slot: u32,
    pub serial: u32,
}

impl JobId {
    /// Id with `slot == serial == n` — exactly what a fresh job table
    /// with no recycling assigns to the n-th submitted job. Test fixture
    /// shorthand.
    pub const fn dense(n: u32) -> JobId {
        JobId { slot: n, serial: n }
    }
}

impl Ord for JobId {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.serial
            .cmp(&other.serial)
            .then_with(|| self.slot.cmp(&other.slot))
    }
}

impl PartialOrd for JobId {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl crate::sim::arena::SlotKey for JobId {
    fn slot_index(self) -> u32 {
        self.slot
    }
    fn serial_stamp(self) -> u32 {
        self.serial
    }
}

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job_{:04}", self.serial)
    }
}
