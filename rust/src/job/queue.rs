//! The JobTracker's job table: all jobs by id, plus the queue view
//! schedulers iterate over (jobs with schedulable tasks, in submission
//! order — the paper's single "job queue").

use std::collections::BTreeSet;

use crate::hdfs::Namespace;
use crate::sim::engine::Time;

use crate::cluster::node::NodeId;
use crate::job::task::TaskRef;

use super::job::{Job, JobSpec};
use super::JobId;

/// Owns every job in the simulation.
///
/// Jobs live in a dense `Vec` indexed by id (ids are sequential), and the
/// schedulable-queue view is maintained **incrementally** by the task
/// transition wrappers — both were coordinator hotspots when recomputed
/// per heartbeat (perf §Perf).
#[derive(Debug, Default)]
pub struct JobTable {
    jobs: Vec<Job>,
    /// Incomplete jobs.
    active: BTreeSet<JobId>,
    /// Incomplete jobs with at least one schedulable task right now.
    ready: BTreeSet<JobId>,
    completed: Vec<JobId>,
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable::default()
    }

    /// Submit a job: allocates its input blocks in HDFS (3-replica,
    /// rack-aware) and instantiates the task vectors.
    pub fn submit(&mut self, spec: JobSpec, hdfs: &mut Namespace) -> JobId {
        let id = JobId(self.jobs.len() as u32);
        let blocks = hdfs.allocate_blocks(spec.map_works.len());
        self.jobs.push(Job::new(id, spec, blocks));
        self.active.insert(id);
        self.sync_ready(id);
        id
    }

    pub fn get(&self, id: JobId) -> &Job {
        &self.jobs[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: JobId) -> &mut Job {
        &mut self.jobs[id.0 as usize]
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// All jobs, submission order.
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.jobs.iter()
    }

    /// Re-derive one job's membership in the ready set.
    fn sync_ready(&mut self, id: JobId) {
        let job = &self.jobs[id.0 as usize];
        if job.finish_time.is_none() && job.has_schedulable_task() {
            self.ready.insert(id);
        } else {
            self.ready.remove(&id);
        }
    }

    // ---- task transition wrappers (keep the ready set consistent) ----

    /// Pending -> Running.
    pub fn start_task(&mut self, r: &TaskRef, node: NodeId, now: Time) {
        self.get_mut(r.job).start_task(r, node, now);
        self.sync_ready(r.job);
    }

    /// Running -> Done. Completing the last map unlocks the reduces.
    pub fn complete_task(&mut self, r: &TaskRef, now: Time) {
        self.get_mut(r.job).complete_task(r, now);
        self.sync_ready(r.job);
    }

    /// Running -> Pending (failure re-queue).
    pub fn requeue_task(&mut self, r: &TaskRef) {
        self.get_mut(r.job).requeue_task(r);
        self.sync_ready(r.job);
    }

    /// Launch a speculative backup copy (pending counters untouched, so
    /// the ready set cannot change).
    pub fn start_speculative(&mut self, r: &TaskRef, node: NodeId, now: Time) {
        self.get_mut(r.job).start_speculative(r, node, now);
    }

    /// The scheduler's queue view: incomplete jobs with schedulable tasks,
    /// submission order (ties elsewhere are broken by scheduler policy).
    pub fn schedulable(&self) -> Vec<JobId> {
        self.ready.iter().copied().collect()
    }

    /// Incomplete job count (queued or running).
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Incomplete jobs (queued or running), submission order. The straggler
    /// scan iterates this — jobs with no pending task (hence absent from
    /// [`JobTable::schedulable`]) are exactly where stragglers live.
    pub fn active_ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.active.iter().copied()
    }

    /// Mark a job finished.
    pub fn mark_complete(&mut self, id: JobId, now: Time) {
        let job = self.get_mut(id);
        debug_assert!(job.is_complete() && job.finish_time.is_none());
        job.finish_time = Some(now);
        self.completed.push(id);
        self.active.remove(&id);
        self.ready.remove(&id);
    }

    /// Kill a job (task attempt budget exhausted). It leaves the queue
    /// view; tasks of it still on nodes are drained by the coordinator.
    pub fn mark_failed(&mut self, id: JobId, now: Time) {
        let job = self.get_mut(id);
        debug_assert!(job.finish_time.is_none());
        job.finish_time = Some(now);
        job.failed = true;
        self.active.remove(&id);
        self.ready.remove(&id);
    }

    pub fn completed_ids(&self) -> &[JobId] {
        &self.completed
    }

    pub fn failed_count(&self) -> usize {
        self.jobs.iter().filter(|j| j.failed).count()
    }

    pub fn all_complete(&self) -> bool {
        self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::job::test_spec;

    fn ns() -> Namespace {
        Namespace::new(4, 2, 42) // 4 nodes, 2 racks
    }

    #[test]
    fn submit_assigns_sequential_ids() {
        let mut t = JobTable::new();
        let mut h = ns();
        let a = t.submit(test_spec("a", 2, 1), &mut h);
        let b = t.submit(test_spec("b", 2, 1), &mut h);
        assert_eq!(a, JobId(0));
        assert_eq!(b, JobId(1));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn schedulable_in_submission_order() {
        let mut t = JobTable::new();
        let mut h = ns();
        for i in 0..5 {
            t.submit(test_spec(&format!("j{i}"), 1, 0), &mut h);
        }
        assert_eq!(
            t.schedulable(),
            (0..5).map(JobId).collect::<Vec<_>>()
        );
    }

    #[test]
    fn completed_jobs_leave_queue_view() {
        let mut t = JobTable::new();
        let mut h = ns();
        let id = t.submit(test_spec("a", 1, 0), &mut h);
        {
            use crate::cluster::node::NodeId;
            let j = t.get_mut(id);
            j.maps[0].start(NodeId(0), 0.0);
            j.maps[0].complete(1.0);
            j.maps_done = 1;
        }
        t.mark_complete(id, 1.0);
        assert!(t.schedulable().is_empty());
        assert!(t.all_complete());
        assert_eq!(t.completed_ids(), &[id]);
        assert_eq!(t.active_count(), 0);
    }

    #[test]
    fn blocks_allocated_per_map() {
        let mut t = JobTable::new();
        let mut h = ns();
        let id = t.submit(test_spec("a", 7, 2), &mut h);
        let j = t.get(id);
        assert_eq!(j.maps.len(), 7);
        assert!(j.maps.iter().all(|m| m.block.is_some()));
    }
}
