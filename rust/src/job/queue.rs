//! The JobTracker's job table: all live jobs in a generational arena,
//! plus the queue view schedulers iterate over (jobs with schedulable
//! tasks, in submission order — the paper's single "job queue").

use std::collections::BTreeSet;

use crate::hdfs::Namespace;
use crate::sim::arena::Arena;
use crate::sim::engine::Time;

use crate::cluster::node::NodeId;
use crate::job::task::TaskRef;

use super::job::{Job, JobSpec};
use super::JobId;

/// Owns every live job in the simulation.
///
/// Jobs live in a dense [`Arena`] indexed by `JobId::slot` and stamped
/// with `JobId::serial` (see `sim::arena` for the aliasing guarantees);
/// the schedulable-queue view is maintained **incrementally** by the task
/// transition wrappers — both were coordinator hotspots when recomputed
/// per heartbeat (perf §Perf).
///
/// With [`JobTable::set_reclaim`] enabled, [`JobTable::release`] frees a
/// drained job's slot for recycling so multi-million-job runs keep the
/// table at O(peak live jobs). Reclamation is off by default because
/// tests and post-run reports inspect completed jobs in place.
#[derive(Debug, Default)]
pub struct JobTable {
    jobs: Arena<Job>,
    /// Monotone submission counter; doubles as the id generation stamp.
    next_serial: u32,
    /// Incomplete jobs.
    active: BTreeSet<JobId>,
    /// Incomplete jobs with at least one schedulable task right now.
    ready: BTreeSet<JobId>,
    completed: u64,
    failed: u64,
    peak_active: usize,
    reclaim: bool,
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable::default()
    }

    /// Enable slot reclamation: [`JobTable::release`] will free drained
    /// jobs' arena slots for reuse (O(active) storage on long runs).
    pub fn set_reclaim(&mut self, on: bool) {
        self.reclaim = on;
    }

    /// Submit a job: allocates its input blocks in HDFS (3-replica,
    /// rack-aware) and instantiates the task vectors.
    pub fn submit(&mut self, spec: JobSpec, hdfs: &mut Namespace) -> JobId {
        let id = JobId { slot: self.jobs.next_slot(), serial: self.next_serial };
        self.next_serial += 1;
        let blocks = hdfs.allocate_blocks(spec.map_works.len());
        let slot = self.jobs.insert(id.serial, Job::new(id, spec, blocks));
        debug_assert_eq!(slot, id.slot);
        self.active.insert(id);
        self.peak_active = self.peak_active.max(self.active.len());
        self.sync_ready(id);
        id
    }

    /// Panicking lookup — stale ids in a driver's main path are a bug.
    /// Event handlers racing a reclaimed job use [`JobTable::try_get`].
    pub fn get(&self, id: JobId) -> &Job {
        match self.jobs.get(id) {
            Some(j) => j,
            None => panic!("stale or unknown {id}"),
        }
    }

    pub fn get_mut(&mut self, id: JobId) -> &mut Job {
        match self.jobs.get_mut(id) {
            Some(j) => j,
            None => panic!("stale or unknown {id}"),
        }
    }

    /// Stale-tolerant lookup: `None` once the job's slot was released
    /// (e.g. a completion event arriving after the job left the system).
    pub fn try_get(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(id)
    }

    /// Total jobs ever submitted.
    pub fn len(&self) -> usize {
        self.next_serial as usize
    }

    pub fn is_empty(&self) -> bool {
        self.next_serial == 0
    }

    /// Jobs currently resident in the arena (= all submitted jobs unless
    /// reclamation is on, then live jobs only).
    pub fn resident(&self) -> usize {
        self.jobs.len()
    }

    /// High-water mark of simultaneously incomplete jobs — the bound that
    /// matters for O(active) memory claims.
    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    /// Resident jobs in slot order (equals submission order while no slot
    /// has been recycled).
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.jobs.iter().map(|(_, _, job)| job)
    }

    /// Re-derive one job's membership in the ready set.
    fn sync_ready(&mut self, id: JobId) {
        let is_ready = match self.jobs.get(id) {
            Some(job) => job.finish_time.is_none() && job.has_schedulable_task(),
            None => false,
        };
        if is_ready {
            self.ready.insert(id);
        } else {
            self.ready.remove(&id);
        }
    }

    // ---- task transition wrappers (keep the ready set consistent) ----

    /// Pending -> Running.
    pub fn start_task(&mut self, r: &TaskRef, node: NodeId, now: Time) {
        self.get_mut(r.job).start_task(r, node, now);
        self.sync_ready(r.job);
    }

    /// Running -> Done. Completing the last map unlocks the reduces.
    pub fn complete_task(&mut self, r: &TaskRef, now: Time) {
        self.get_mut(r.job).complete_task(r, now);
        self.sync_ready(r.job);
    }

    /// Running -> Pending (failure re-queue).
    pub fn requeue_task(&mut self, r: &TaskRef) {
        self.get_mut(r.job).requeue_task(r);
        self.sync_ready(r.job);
    }

    /// Launch a speculative backup copy (pending counters untouched, so
    /// the ready set cannot change).
    pub fn start_speculative(&mut self, r: &TaskRef, node: NodeId, now: Time) {
        self.get_mut(r.job).start_speculative(r, node, now);
    }

    /// The scheduler's queue view: incomplete jobs with schedulable tasks,
    /// submission order (ties elsewhere are broken by scheduler policy).
    pub fn schedulable(&self) -> Vec<JobId> {
        self.ready.iter().copied().collect()
    }

    /// Bounded queue view reusing the caller's buffer: the first `cap`
    /// schedulable jobs in submission order. At million-job scale the
    /// drivers cap the per-heartbeat view (`TrackerConfig::queue_cap`) so
    /// one heartbeat's scoring work is O(cap), not O(backlog).
    pub fn schedulable_prefix(&self, cap: usize, out: &mut Vec<JobId>) {
        out.clear();
        out.extend(self.ready.iter().take(cap).copied());
    }

    /// Incomplete job count (queued or running).
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Schedulable job count (the queue view's length), allocation-free.
    pub fn ready_count(&self) -> usize {
        self.ready.len()
    }

    /// Incomplete jobs (queued or running), submission order. The straggler
    /// scan iterates this — jobs with no pending task (hence absent from
    /// [`JobTable::schedulable`]) are exactly where stragglers live.
    pub fn active_ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.active.iter().copied()
    }

    /// Mark a job finished.
    pub fn mark_complete(&mut self, id: JobId, now: Time) {
        let job = self.get_mut(id);
        debug_assert!(job.is_complete() && job.finish_time.is_none());
        job.finish_time = Some(now);
        self.completed += 1;
        self.active.remove(&id);
        self.ready.remove(&id);
    }

    /// Kill a job (task attempt budget exhausted). It leaves the queue
    /// view; tasks of it still on nodes are drained by the coordinator.
    pub fn mark_failed(&mut self, id: JobId, now: Time) {
        let job = self.get_mut(id);
        debug_assert!(job.finish_time.is_none());
        job.finish_time = Some(now);
        job.failed = true;
        self.failed += 1;
        self.active.remove(&id);
        self.ready.remove(&id);
    }

    /// The job left the system fully drained (drivers call this right
    /// after emitting `JobCompleted`): recycle its slot if reclamation is
    /// on. Stale/double releases are no-ops.
    pub fn release(&mut self, id: JobId) {
        if self.reclaim {
            debug_assert!(!self.active.contains(&id) && !self.ready.contains(&id));
            self.jobs.remove(id);
        }
    }

    pub fn completed_count(&self) -> u64 {
        self.completed
    }

    pub fn failed_count(&self) -> usize {
        self.failed as usize
    }

    pub fn all_complete(&self) -> bool {
        self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::job::test_spec;

    fn ns() -> Namespace {
        Namespace::new(4, 2, 42) // 4 nodes, 2 racks
    }

    #[test]
    fn submit_assigns_sequential_ids() {
        let mut t = JobTable::new();
        let mut h = ns();
        let a = t.submit(test_spec("a", 2, 1), &mut h);
        let b = t.submit(test_spec("b", 2, 1), &mut h);
        assert_eq!(a, JobId::dense(0));
        assert_eq!(b, JobId::dense(1));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn schedulable_in_submission_order() {
        let mut t = JobTable::new();
        let mut h = ns();
        for i in 0..5 {
            t.submit(test_spec(&format!("j{i}"), 1, 0), &mut h);
        }
        assert_eq!(
            t.schedulable(),
            (0..5).map(JobId::dense).collect::<Vec<_>>()
        );
        let mut prefix = Vec::new();
        t.schedulable_prefix(3, &mut prefix);
        assert_eq!(prefix, (0..3).map(JobId::dense).collect::<Vec<_>>());
    }

    #[test]
    fn completed_jobs_leave_queue_view() {
        let mut t = JobTable::new();
        let mut h = ns();
        let id = t.submit(test_spec("a", 1, 0), &mut h);
        {
            use crate::cluster::node::NodeId;
            let j = t.get_mut(id);
            j.maps[0].start(NodeId(0), 0.0);
            j.maps[0].complete(1.0);
            j.maps_done = 1;
        }
        t.mark_complete(id, 1.0);
        assert!(t.schedulable().is_empty());
        assert!(t.all_complete());
        assert_eq!(t.completed_count(), 1);
        assert_eq!(t.active_count(), 0);
    }

    #[test]
    fn blocks_allocated_per_map() {
        let mut t = JobTable::new();
        let mut h = ns();
        let id = t.submit(test_spec("a", 7, 2), &mut h);
        let j = t.get(id);
        assert_eq!(j.maps.len(), 7);
        assert!(j.maps.iter().all(|m| m.block.is_some()));
    }

    #[test]
    fn release_recycles_slots_without_id_reuse() {
        let mut t = JobTable::new();
        let mut h = ns();
        t.set_reclaim(true);
        let a = t.submit(test_spec("a", 1, 0), &mut h);
        {
            use crate::cluster::node::NodeId;
            let j = t.get_mut(a);
            j.maps[0].start(NodeId(0), 0.0);
            j.maps[0].complete(1.0);
            j.maps_done = 1;
        }
        t.mark_complete(a, 1.0);
        t.release(a);
        assert_eq!(t.resident(), 0);
        assert!(t.try_get(a).is_none(), "released id must be stale");
        // next submission recycles the slot under a fresh serial
        let b = t.submit(test_spec("b", 1, 0), &mut h);
        assert_eq!(b.slot, a.slot);
        assert_ne!(b.serial, a.serial);
        assert!(t.try_get(a).is_none(), "old id must not alias new job");
        assert_eq!(t.get(b).spec.name, "b");
        assert_eq!(t.len(), 2, "len counts submissions, not residents");
        // double release is inert
        t.release(a);
        assert_eq!(t.resident(), 1);
    }

    #[test]
    fn peak_active_tracks_high_water_mark() {
        let mut t = JobTable::new();
        let mut h = ns();
        let a = t.submit(test_spec("a", 1, 0), &mut h);
        let _b = t.submit(test_spec("b", 1, 0), &mut h);
        {
            use crate::cluster::node::NodeId;
            let j = t.get_mut(a);
            j.maps[0].start(NodeId(0), 0.0);
            j.maps[0].complete(1.0);
            j.maps_done = 1;
        }
        t.mark_complete(a, 1.0);
        t.submit(test_spec("c", 1, 0), &mut h);
        assert_eq!(t.active_count(), 2);
        assert_eq!(t.peak_active(), 2);
    }
}
