//! Job resource profiles: the paper's job features ("the average usage rate
//! of CPU and average usage rate of memory ... set when the user commits
//! job", §4.2) plus the per-task resource demand they imply in the
//! simulator.

use crate::bayes::features::JobFeatures;
use crate::cluster::resources::Resources;

/// Workload classes used by the generator. Names follow the intro's
/// motivation: clusters run a mix of CPU-, IO-, memory- and shuffle-bound
/// jobs whose resource appetites the administrator cannot hand-tune for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobClass {
    /// Compute-bound (e.g. ML training, compression).
    CpuHeavy,
    /// Disk-scan-bound (e.g. log grep, ETL).
    IoHeavy,
    /// Large in-memory state (e.g. joins, aggregations). OOM-prone.
    MemHeavy,
    /// Shuffle-bound (large intermediate data).
    NetHeavy,
    /// Short interactive jobs, low everything.
    Small,
}

impl JobClass {
    pub const ALL: [JobClass; 5] = [
        JobClass::CpuHeavy,
        JobClass::IoHeavy,
        JobClass::MemHeavy,
        JobClass::NetHeavy,
        JobClass::Small,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            JobClass::CpuHeavy => "cpu_heavy",
            JobClass::IoHeavy => "io_heavy",
            JobClass::MemHeavy => "mem_heavy",
            JobClass::NetHeavy => "net_heavy",
            JobClass::Small => "small",
        }
    }

    pub fn from_name(s: &str) -> Option<JobClass> {
        Self::ALL.iter().copied().find(|c| c.name() == s)
    }

    /// Nominal job features (centres; the generator jitters around these).
    pub fn base_features(&self) -> JobFeatures {
        match self {
            JobClass::CpuHeavy => JobFeatures { cpu: 0.85, mem: 0.35, io: 0.20, net: 0.15 },
            JobClass::IoHeavy => JobFeatures { cpu: 0.25, mem: 0.30, io: 0.85, net: 0.25 },
            JobClass::MemHeavy => JobFeatures { cpu: 0.35, mem: 0.85, io: 0.30, net: 0.20 },
            JobClass::NetHeavy => JobFeatures { cpu: 0.30, mem: 0.35, io: 0.30, net: 0.85 },
            JobClass::Small => JobFeatures { cpu: 0.15, mem: 0.10, io: 0.10, net: 0.10 },
        }
    }

    /// (min, max) map task counts.
    pub fn map_count_range(&self) -> (u32, u32) {
        match self {
            JobClass::Small => (2, 8),
            JobClass::CpuHeavy => (10, 40),
            _ => (10, 60),
        }
    }

    /// (min, max) reduce task counts.
    pub fn reduce_count_range(&self) -> (u32, u32) {
        match self {
            JobClass::Small => (1, 2),
            JobClass::NetHeavy => (4, 16),
            _ => (2, 8),
        }
    }

    /// Log-normal (mu, sigma) of map-task work seconds at speed 1.
    pub fn map_work_lognormal(&self) -> (f64, f64) {
        match self {
            JobClass::Small => (1.6, 0.3),    // ~5s
            JobClass::CpuHeavy => (3.2, 0.4), // ~25s
            JobClass::IoHeavy => (3.0, 0.4),  // ~20s
            JobClass::MemHeavy => (3.1, 0.4),
            JobClass::NetHeavy => (2.8, 0.4),
        }
    }

    /// Log-normal (mu, sigma) of reduce-task work seconds.
    pub fn reduce_work_lognormal(&self) -> (f64, f64) {
        match self {
            JobClass::Small => (1.8, 0.3),
            JobClass::NetHeavy => (3.6, 0.4), // shuffle-heavy reduces
            _ => (3.2, 0.4),
        }
    }
}

/// Per-task resource demand implied by a job's declared features.
///
/// A task of a job with feature fraction f demands f * TASK_DEMAND_SCALE of
/// a standard node in that dimension — so two fully cpu-heavy tasks nearly
/// saturate a standard node's CPU, matching the paper's §2.1 observation
/// that "if two large memory consumption of the task to be scheduled one,
/// it is easy to appear OOM".
pub const TASK_DEMAND_SCALE: f64 = 0.45;

pub fn demand_from_profile(p: &JobFeatures) -> Resources {
    Resources {
        cpu: p.cpu * TASK_DEMAND_SCALE,
        mem: p.mem * TASK_DEMAND_SCALE,
        io: p.io * TASK_DEMAND_SCALE,
        net: p.net * TASK_DEMAND_SCALE,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for c in JobClass::ALL {
            assert_eq!(JobClass::from_name(c.name()), Some(c));
        }
        assert_eq!(JobClass::from_name("bogus"), None);
    }

    #[test]
    fn heavy_classes_dominate_their_dimension() {
        let f = JobClass::CpuHeavy.base_features();
        assert!(f.cpu > f.mem && f.cpu > f.io && f.cpu > f.net);
        let f = JobClass::IoHeavy.base_features();
        assert!(f.io > f.cpu && f.io > f.mem && f.io > f.net);
        let f = JobClass::MemHeavy.base_features();
        assert!(f.mem > f.cpu);
        let f = JobClass::NetHeavy.base_features();
        assert!(f.net > f.cpu);
    }

    #[test]
    fn two_heavy_tasks_nearly_saturate() {
        let d = demand_from_profile(&JobClass::CpuHeavy.base_features());
        assert!(2.0 * d.cpu > 0.7 && 2.0 * d.cpu <= 1.0);
    }

    #[test]
    fn small_jobs_are_small() {
        let d = demand_from_profile(&JobClass::Small.base_features());
        assert!(d.max_component() < 0.1);
        let (lo, hi) = JobClass::Small.map_count_range();
        assert!(hi <= 8 && lo >= 1);
    }
}
