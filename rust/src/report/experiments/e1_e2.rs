//! E1 (efficiency) and E2 (stability): the paper's headline claim —
//! "significant improvement in execution efficiency and stability of job
//! scheduling" — quantified against the §3 baselines.

use crate::coordinator::builder::RunConfig;
use crate::report::table::{fnum, Table};
use crate::workload::generator::WorkloadConfig;

use super::common::{mean_of, run_once, std_of, ExpOpts, RunSummary};

const SCHEDULERS: [&str; 4] = ["fifo", "fair", "capacity", "bayes"];

fn base_cfg(scheduler: &str, seed: u64, opts: &ExpOpts) -> RunConfig {
    RunConfig {
        scheduler: scheduler.into(),
        n_nodes: opts.scaled(40, 8) as u32,
        n_racks: 4,
        workload: WorkloadConfig {
            n_jobs: opts.scaled(200, 30),
            arrival_rate: 0.5,
            seed,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// E1: makespan / throughput / latency per scheduler, multi-seed means.
pub fn e1(opts: &ExpOpts) -> Vec<Table> {
    let seeds = opts.scaled(5, 2) as u64;
    let mut table = Table::new(
        "E1 efficiency: Bayes vs FIFO/Fair/Capacity (mean over seeds)",
        &[
            "scheduler",
            "makespan_s",
            "throughput_jobs_s",
            "mean_latency_s",
            "p95_latency_s",
            "overload_rate",
            "oom_kills",
            "wasted_attempts",
        ],
    );
    for sched in SCHEDULERS {
        let runs: Vec<RunSummary> = (1..=seeds)
            .map(|s| run_once(&base_cfg(sched, s, opts)))
            .collect();
        table.row(vec![
            sched.into(),
            fnum(mean_of(&runs, |r| r.makespan)),
            fnum(mean_of(&runs, |r| r.throughput)),
            fnum(mean_of(&runs, |r| r.mean_latency)),
            fnum(mean_of(&runs, |r| r.p95_latency)),
            fnum(mean_of(&runs, |r| r.overload_rate)),
            fnum(mean_of(&runs, |r| r.oom_kills as f64)),
            fnum(mean_of(&runs, |r| r.wasted_attempts as f64)),
        ]);
    }
    vec![table]
}

/// E2: stability — dispersion of makespan and latency across seeds.
pub fn e2(opts: &ExpOpts) -> Vec<Table> {
    let seeds = opts.scaled(20, 4) as u64;
    let mut table = Table::new(
        "E2 stability: dispersion across seeds (lower = more stable)",
        &[
            "scheduler",
            "makespan_mean",
            "makespan_std",
            "makespan_cv",
            "latency_mean",
            "latency_std",
            "overload_sec_mean",
        ],
    );
    for sched in SCHEDULERS {
        let runs: Vec<RunSummary> = (1..=seeds)
            .map(|s| run_once(&base_cfg(sched, 100 + s, opts)))
            .collect();
        let mk_mean = mean_of(&runs, |r| r.makespan);
        let mk_std = std_of(&runs, |r| r.makespan);
        table.row(vec![
            sched.into(),
            fnum(mk_mean),
            fnum(mk_std),
            fnum(if mk_mean > 0.0 { mk_std / mk_mean } else { 0.0 }),
            fnum(mean_of(&runs, |r| r.mean_latency)),
            fnum(std_of(&runs, |r| r.mean_latency)),
            fnum(mean_of(&runs, |r| r.overload_seconds)),
        ]);
    }
    vec![table]
}
