//! E3 (overload learning curve) and E4 (classifier quality vs feedback
//! volume): the paper's §4.3 claim that the scheduler "adjusts task
//! allocation policy through learning the feedback result ... constantly
//! ... to improve the correct rate of task allocation".

use crate::bayes::classifier::{Classifier, Label, NaiveBayes};
use crate::bayes::features::{feature_vec, FeatureVec};
use crate::bayes::overload::OverloadRule;
use crate::cluster::Cluster;
use crate::coordinator::builder::{build_tracker_with, RunConfig};
use crate::report::table::{fnum, Table};
use crate::sim::rng::Pcg;
use crate::workload::generator::{generate, WorkloadConfig};

use super::common::ExpOpts;

/// E3: overload rate per 100-allocation window over one long bayes run,
/// with fifo as the no-learning control.
pub fn e3(opts: &ExpOpts) -> Vec<Table> {
    let n_jobs = opts.scaled(500, 60);
    let mut table = Table::new(
        "E3 learning curve: overloads per 100 allocations over time",
        &["window", "bayes_overload_rate", "fifo_overload_rate"],
    );
    let mut curves = Vec::new();
    for sched in ["bayes", "fifo"] {
        let cfg = RunConfig {
            scheduler: sched.into(),
            n_nodes: opts.scaled(40, 8) as u32,
            n_racks: 4,
            workload: WorkloadConfig {
                n_jobs,
                arrival_rate: 0.8,
                seed: 3,
                ..Default::default()
            },
            ..Default::default()
        };
        let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
        let specs = generate(&cfg.workload);
        // static experiment config -- lint: allow(unwrap-in-lib)
        let mut jt = build_tracker_with(&cfg, cluster, specs).unwrap();
        jt.run();
        let curve: Vec<f64> = jt
            .metrics
            .windows
            .iter()
            .filter(|w| w.allocations > 0)
            .map(|w| w.overloads as f64 / w.allocations as f64)
            .collect();
        curves.push(curve);
    }
    let n = curves[0].len().min(curves[1].len()).min(opts.scaled(20, 6));
    for i in 0..n {
        table.row(vec![format!("{i}"), fnum(curves[0][i]), fnum(curves[1][i])]);
    }
    vec![table]
}

/// Ground-truth oracle used by E4: the same overload mechanism the
/// simulator applies, evaluated analytically on (job, node) features.
fn oracle_label(fv: &FeatureVec, rule: &OverloadRule) -> Label {
    // feature bins back to approximate fractions (bin midpoints)
    let frac = |b: u8| (b as f64 + 0.5) / 10.0;
    // node utilization after adding this job's task demand
    let demand_scale = crate::job::profile::TASK_DEMAND_SCALE;
    let cpu = frac(fv[4]) + frac(fv[0]) * demand_scale;
    let mem = frac(fv[5]) + frac(fv[1]) * demand_scale;
    let io = frac(fv[6]) + frac(fv[2]) * demand_scale;
    let net = frac(fv[7]) + frac(fv[3]) * demand_scale;
    let slowdown = cpu.max(mem).max(io).max(net).max(1.0);
    let obs = crate::bayes::overload::OverloadObservation {
        cpu_used: cpu,
        mem_used: mem,
        io_load: io,
        net_load: net,
        slowdown,
    };
    rule.label(&obs)
}

/// E4: classifier accuracy / precision / recall vs number of feedback
/// samples, against the analytic oracle (train on synthetic feedback drawn
/// from the same distribution the simulator produces).
pub fn e4(opts: &ExpOpts) -> Vec<Table> {
    let rule = OverloadRule::default();
    let mut rng = Pcg::seeded(4);
    let sample = |rng: &mut Pcg| -> FeatureVec {
        // draw a plausible (job, node) pair: job features from the class
        // mix, node features from a load distribution
        let classes = crate::job::profile::JobClass::ALL;
        let class = classes[rng.index(classes.len())];
        let f = class.base_features();
        let jitter = |rng: &mut Pcg, v: f64| (v + rng.range_f64(-0.1, 0.1)).clamp(0.0, 1.0);
        let job = crate::bayes::features::JobFeatures {
            cpu: jitter(rng, f.cpu),
            mem: jitter(rng, f.mem),
            io: jitter(rng, f.io),
            net: jitter(rng, f.net),
        };
        let node = crate::bayes::features::NodeFeatures {
            cpu_used: rng.f64(),
            mem_used: rng.f64(),
            io_load: rng.f64() * 0.7,
            net_load: rng.f64() * 0.7,
        };
        // synthetic oracle rows: failure-free cluster, bins stay 0
        feature_vec(&job, &node, crate::bayes::features::FailureFeats::default())
    };
    // held-out test set
    let test: Vec<(FeatureVec, Label)> = (0..opts.scaled(2000, 300))
        .map(|_| {
            let fv = sample(&mut rng);
            (fv, oracle_label(&fv, &rule))
        })
        .collect();
    let mut table = Table::new(
        "E4 classifier quality vs feedback volume (analytic oracle)",
        &["train_samples", "accuracy", "precision_bad", "recall_bad"],
    );
    let mut nb = NaiveBayes::new(1.0);
    let mut trained = 0usize;
    let checkpoints = if opts.quick {
        vec![50usize, 200, 500]
    } else {
        vec![50usize, 100, 200, 500, 1000, 2000, 5000]
    };
    for target in checkpoints {
        while trained < target {
            let fv = sample(&mut rng);
            nb.observe(fv, oracle_label(&fv, &rule));
            trained += 1;
        }
        nb.flush();
        let (mut tp, mut fp, mut fneg, mut correct) = (0u32, 0u32, 0u32, 0u32);
        for (fv, truth) in &test {
            let pred = if nb.posterior_good(fv) >= 0.5 { Label::Good } else { Label::Bad };
            if pred == *truth {
                correct += 1;
            }
            match (pred, truth) {
                (Label::Bad, Label::Bad) => tp += 1,
                (Label::Bad, Label::Good) => fp += 1,
                (Label::Good, Label::Bad) => fneg += 1,
                _ => {}
            }
        }
        let prec = if tp + fp > 0 { tp as f64 / (tp + fp) as f64 } else { 0.0 };
        let rec = if tp + fneg > 0 { tp as f64 / (tp + fneg) as f64 } else { 0.0 };
        table.row(vec![
            format!("{target}"),
            fnum(correct as f64 / test.len() as f64),
            fnum(prec),
            fnum(rec),
        ]);
    }
    vec![table]
}
