//! E12: the Bayes policy inside the YARN RM vs YARN-FIFO/Fair, under the
//! declared-vs-actual misdeclaration model (paper §2's architecture with
//! §4's algorithm). (Numbered E10 before the failure sweep took that slot.)

use crate::cluster::Cluster;
use crate::report::table::{fnum, Table};
use crate::workload::generator::{generate, WorkloadConfig};
use crate::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

use super::common::ExpOpts;

pub fn e12(opts: &ExpOpts) -> Vec<Table> {
    let mut table = Table::new(
        "E12 YARN mode: RM policy comparison (misdeclared demands)",
        &[
            "policy",
            "makespan_s",
            "mean_latency_s",
            "overload_rate",
            "oom_kills",
            "overload_seconds",
        ],
    );
    for policy in ["yarn-fifo", "yarn-fair", "yarn-bayes"] {
        let cluster = Cluster::homogeneous(opts.scaled(40, 8) as u32, 4);
        let specs = generate(&WorkloadConfig {
            n_jobs: opts.scaled(200, 25),
            arrival_rate: 0.5,
            seed: 10,
            ..Default::default()
        });
        let mut rm = ResourceManager::new(
            cluster,
            // static experiment config -- lint: allow(unwrap-in-lib)
            yarn_policy_by_name(policy, 1.0).unwrap(),
            specs,
            10,
            YarnConfig::default(),
        );
        rm.run();
        let m = &rm.metrics;
        table.row(vec![
            policy.into(),
            fnum(m.makespan),
            fnum(m.mean_latency()),
            fnum(m.overload_rate()),
            fnum(m.oom_kills as f64),
            fnum(m.overload_seconds),
        ]);
    }
    vec![table]
}
