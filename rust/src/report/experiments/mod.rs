//! Experiment drivers E1–E14 (DESIGN.md §4): each regenerates one derived
//! table from the paper's claims and writes a CSV when an output directory
//! is configured. E10 is the failure sweep (failure-aware vs failure-blind
//! bayes on an MTBF grid); the YARN policy comparison lives in E12; E13 is
//! the million-job scale proof of the arena + calendar-queue core; E14 is
//! the bounded-memory streaming trace replay through both drivers.

pub mod common;
pub mod e1_e2;
pub mod e10;
pub mod e11;
pub mod e12;
pub mod e13;
pub mod e14;
pub mod e3_e4;
pub mod e5_e7;
pub mod e8_e9;

pub use common::ExpOpts;

use crate::report::table::Table;

/// All experiment ids.
pub const ALL: [&str; 14] = [
    "e1", "e2", "e3", "e4", "e5", "e6", "e7", "e8", "e9", "e10", "e11", "e12",
    "e13", "e14",
];

/// Run one experiment by id.
pub fn run(id: &str, opts: &ExpOpts) -> Option<Vec<Table>> {
    let tables = match id {
        "e1" => e1_e2::e1(opts),
        "e2" => e1_e2::e2(opts),
        "e3" => e3_e4::e3(opts),
        "e4" => e3_e4::e4(opts),
        "e5" => e5_e7::e5(opts),
        "e6" => e5_e7::e6(opts),
        "e7" => e5_e7::e7(opts),
        "e8" => e8_e9::e8(opts),
        "e9" => e8_e9::e9(opts),
        "e10" => e10::e10(opts),
        "e11" => e11::e11(opts),
        "e12" => e12::e12(opts),
        "e13" => e13::e13(opts),
        "e14" => e14::e14(opts),
        _ => return None,
    };
    if let Some(dir) = &opts.out_dir {
        for (i, t) in tables.iter().enumerate() {
            let slug = if tables.len() == 1 {
                id.to_string()
            } else {
                format!("{id}_{i}")
            };
            let _ = t.save_csv(dir, &slug);
        }
    }
    Some(tables)
}
