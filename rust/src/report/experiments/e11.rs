//! E11: failure resilience — makespan inflation and lost work under
//! TaskTracker failures (paper §1: the JobTracker must "manage job failed,
//! restart operation"; §2.1 lists the MRv1 single-point-of-failure concern
//! that motivated YARN). Sweeps MTBF for FIFO vs Bayes.

use crate::cluster::Cluster;
use crate::coordinator::builder::RunConfig;
use crate::coordinator::jobtracker::{FailureConfig, JobTracker};
use crate::report::table::{fnum, Table};
use crate::workload::generator::{generate, WorkloadConfig};

use super::common::ExpOpts;

pub fn e11(opts: &ExpOpts) -> Vec<Table> {
    let mtbfs: Vec<Option<f64>> = if opts.quick {
        vec![None, Some(300.0)]
    } else {
        vec![None, Some(1200.0), Some(600.0), Some(300.0)]
    };
    let mut table = Table::new(
        "E11 failure resilience: makespan vs node MTBF (mttr = 90s)",
        &[
            "mtbf_s",
            "scheduler",
            "makespan_s",
            "node_failures",
            "wasted_attempts",
            "failed_jobs",
        ],
    );
    for mtbf in &mtbfs {
        for sched in ["fifo", "bayes"] {
            let cfg = RunConfig {
                scheduler: sched.into(),
                n_nodes: opts.scaled(40, 8) as u32,
                n_racks: 4,
                workload: WorkloadConfig {
                    n_jobs: opts.scaled(200, 25),
                    arrival_rate: 0.5,
                    seed: 11,
                    ..Default::default()
                },
                ..Default::default()
            };
            let mut tracker_cfg = cfg.tracker.clone();
            tracker_cfg.failures = FailureConfig { mtbf: *mtbf, mttr: 90.0 };
            let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
            let sched_box =
                // static experiment config -- lint: allow(unwrap-in-lib)
                crate::coordinator::builder::build_scheduler(&cfg).unwrap();
            let mut jt = JobTracker::new(
                cluster,
                sched_box,
                generate(&cfg.workload),
                cfg.workload.seed,
                tracker_cfg,
            );
            jt.run();
            table.row(vec![
                mtbf.map_or("none".to_string(), |m| format!("{m:.0}")),
                sched.into(),
                fnum(jt.metrics.makespan),
                format!("{}", jt.metrics.node_failures),
                format!("{}", jt.metrics.wasted_attempts()),
                format!("{}", jt.metrics.failed_jobs),
            ]);
        }
    }
    vec![table]
}
