//! E5 (data locality), E6 (scalability), E7 (workload-mix sensitivity).

use crate::coordinator::builder::RunConfig;
use crate::report::table::{fnum, Table};
use crate::workload::generator::{Mix, WorkloadConfig};

use super::common::{run_once, ExpOpts};

/// E5: locality split per scheduler (paper §4.2's locality-first task pick
/// is shared; differences come from *which* jobs win slots when).
pub fn e5(opts: &ExpOpts) -> Vec<Table> {
    let mut table = Table::new(
        "E5 map-task data locality by scheduler",
        &["scheduler", "node_local", "rack_local", "remote"],
    );
    for sched in ["fifo", "fair", "capacity", "bayes", "random", "threshold-fifo"] {
        let cfg = RunConfig {
            scheduler: sched.into(),
            n_nodes: opts.scaled(40, 8) as u32,
            n_racks: 4,
            workload: WorkloadConfig {
                n_jobs: opts.scaled(200, 30),
                seed: 5,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = run_once(&cfg);
        table.row(vec![
            sched.into(),
            fnum(r.locality_node),
            fnum(r.locality_rack),
            fnum(r.locality_remote),
        ]);
    }
    vec![table]
}

/// E6: makespan and scheduler decision latency vs cluster size.
pub fn e6(opts: &ExpOpts) -> Vec<Table> {
    let sizes: Vec<u32> = if opts.quick {
        vec![10, 20]
    } else {
        vec![10, 20, 40, 80, 160]
    };
    let mut table = Table::new(
        "E6 scalability: cluster size sweep (jobs = 5 x nodes)",
        &[
            "nodes",
            "scheduler",
            "makespan_s",
            "mean_decision_us",
            "mean_assign_us",
            "heartbeats",
        ],
    );
    for &n in &sizes {
        for sched in ["fifo", "bayes"] {
            let cfg = RunConfig {
                scheduler: sched.into(),
                n_nodes: n,
                n_racks: (n / 10).max(1),
                workload: WorkloadConfig {
                    n_jobs: (5 * n) as usize,
                    arrival_rate: 0.0125 * n as f64,
                    seed: 6,
                    ..Default::default()
                },
                ..Default::default()
            };
            let r = run_once(&cfg);
            table.row(vec![
                format!("{n}"),
                sched.into(),
                fnum(r.makespan),
                fnum(r.mean_decision_us),
                fnum(r.mean_assign_us),
                format!("{}", r.heartbeats),
            ]);
        }
    }
    vec![table]
}

/// E7: Bayes advantage vs fraction of cpu-heavy jobs — contention-prone
/// mixes are where learned overload avoidance should matter most.
pub fn e7(opts: &ExpOpts) -> Vec<Table> {
    let fracs = if opts.quick {
        vec![0.0, 1.0]
    } else {
        vec![0.0, 0.25, 0.5, 0.75, 1.0]
    };
    let mut table = Table::new(
        "E7 workload-mix sensitivity: makespan vs cpu-heavy fraction",
        &[
            "cpu_fraction",
            "fifo_makespan",
            "bayes_makespan",
            "bayes_speedup",
            "fifo_overloads",
            "bayes_overloads",
        ],
    );
    for frac in fracs {
        let mut mk = [0.0f64; 2];
        let mut ov = [0.0f64; 2];
        for (i, sched) in ["fifo", "bayes"].iter().enumerate() {
            let cfg = RunConfig {
                scheduler: (*sched).into(),
                n_nodes: opts.scaled(40, 8) as u32,
                n_racks: 4,
                workload: WorkloadConfig {
                    n_jobs: opts.scaled(200, 30),
                    arrival_rate: 0.5,
                    mix: Mix::cpu_fraction(frac),
                    seed: 7,
                    ..Default::default()
                },
                ..Default::default()
            };
            let r = run_once(&cfg);
            mk[i] = r.makespan;
            ov[i] = r.overload_rate;
        }
        table.row(vec![
            fnum(frac),
            fnum(mk[0]),
            fnum(mk[1]),
            fnum(if mk[1] > 0.0 { mk[0] / mk[1] } else { 0.0 }),
            fnum(ov[0]),
            fnum(ov[1]),
        ]);
    }
    vec![table]
}
