//! E14: streaming trace replay — write a heavy-tailed (lognormal task
//! works) JSONL trace of 1,000,000 specs (20k in `--quick`), then replay
//! it through BOTH drivers (MRv1 tracker, YARN RM) under fifo and bayes,
//! never materializing the spec vector: the trace streams from disk one
//! record ahead of the virtual clock.
//!
//! The report pairs each cell's makespan with the ingest-side memory
//! proof: `ingest_resident_b` is the peak bytes resident in the decode
//! path (the `trace_ingest_resident` gauge — a fixed parser chunk plus
//! per-record scratch), and `peak_active`/`resident_end` show the arena
//! staying O(active jobs). Together they bound the replay's footprint by
//! the cluster state, not the trace length.

use crate::cluster::Cluster;
use crate::coordinator::jobtracker::{JobTracker, TrackerConfig};
use crate::job::job::JobSpec;
use crate::job::profile::JobClass;
use crate::obs::Stopwatch;
use crate::report::table::{fnum, Table};
use crate::workload::generator::{stream, Mix, WorkloadConfig};
use crate::workload::trace::{self, TraceErrorSlot, TraceFormat, TraceReader, TraceStats};
use crate::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

use super::common::ExpOpts;

/// Open the trace for one replay cell: streaming spec source + its
/// ingest stats + the slot that would catch a malformed record.
fn open_trace(
    path: &std::path::Path,
) -> (Box<dyn Iterator<Item = JobSpec>>, TraceStats, TraceErrorSlot) {
    // the experiment wrote this file moments ago -- lint: allow(unwrap-in-lib)
    let mut reader = TraceReader::open(path).unwrap();
    let stats = TraceStats::default();
    reader.install_stats(stats.clone());
    let (specs, errs) = reader.into_stream();
    (specs, stats, errs)
}

struct CellReport {
    makespan: f64,
    peak_active: usize,
    resident_end: usize,
    wall: f64,
}

fn report_row(
    table: &mut Table,
    driver: &str,
    sched: &str,
    n_jobs: usize,
    cell: &CellReport,
    stats: &TraceStats,
    errs: &TraceErrorSlot,
) {
    if let Some(e) = errs.take() {
        crate::obs_log!(crate::obs::log::ERROR, "e14 trace replay error: {e}");
    }
    table.row(vec![
        driver.into(),
        sched.into(),
        format!("{n_jobs}"),
        fnum(cell.makespan),
        format!("{}", stats.specs_read()),
        fnum(stats.ingest_nanos() as f64 / 1e6),
        format!("{}", stats.resident_peak()),
        format!("{}", cell.peak_active),
        format!("{}", cell.resident_end),
        fnum(cell.wall),
    ]);
}

pub fn e14(opts: &ExpOpts) -> Vec<Table> {
    let n_jobs = opts.scaled(1_000_000, 20_000);
    let n_nodes = opts.scaled(10_000, 500) as u32;
    // same ~60%-of-service calibration as E13 so the backlog stays bounded
    let arrival_rate = if opts.quick { 20.0 } else { 450.0 };
    let workload = WorkloadConfig {
        n_jobs,
        arrival_rate,
        mix: Mix::only(JobClass::Small),
        n_users: 8,
        seed: 14,
    };
    let path = std::env::temp_dir()
        .join(format!("bayes_sched_e14_{}.jsonl", std::process::id()));

    // phase 1: stream generator -> JSONL writer (no spec vector here either)
    let w0 = Stopwatch::start();
    let written = trace::save_stream(stream(&workload), &path, TraceFormat::Jsonl)
        // a temp-dir write failing is fatal -- lint: allow(unwrap-in-lib)
        .unwrap();
    let write_s = w0.elapsed_secs();
    let trace_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);

    let mut info = Table::new(
        "E14 trace",
        &["format", "specs", "bytes", "write_s"],
    );
    info.row(vec![
        "jsonl".into(),
        format!("{written}"),
        format!("{trace_bytes}"),
        fnum(write_s),
    ]);

    let mut table = Table::new(
        "E14 streaming trace replay: bounded-memory ingest through both drivers",
        &[
            "driver",
            "scheduler",
            "jobs",
            "makespan_s",
            "specs_read",
            "ingest_ms",
            "ingest_resident_b",
            "peak_active",
            "resident_end",
            "wall_s",
        ],
    );

    // phase 2: replay the same file through both drivers x {fifo, bayes}
    for sched in ["fifo", "bayes"] {
        // MRv1 tracker
        let (specs, stats, errs) = open_trace(&path);
        let cluster = Cluster::homogeneous(n_nodes, (n_nodes / 40).max(1));
        // by_name covers every registered name -- lint: allow(unwrap-in-lib)
        let scheduler = crate::scheduler::by_name(sched, workload.seed).unwrap();
        let cfg = TrackerConfig {
            queue_cap: 128,
            reclaim_jobs: true,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let mut jt =
            JobTracker::new_streaming(cluster, scheduler, specs, workload.seed, cfg);
        jt.run();
        let cell = CellReport {
            makespan: jt.metrics.makespan,
            peak_active: jt.jobs.peak_active(),
            resident_end: jt.jobs.resident(),
            wall: sw.elapsed_secs(),
        };
        report_row(&mut table, "mrv1", sched, n_jobs, &cell, &stats, &errs);

        // YARN RM
        let (specs, stats, errs) = open_trace(&path);
        let cluster = Cluster::homogeneous(n_nodes, (n_nodes / 40).max(1));
        // the yarn- aliases are registered names -- lint: allow(unwrap-in-lib)
        let policy = yarn_policy_by_name(&format!("yarn-{sched}"), 1.0).unwrap();
        let ycfg = YarnConfig {
            queue_cap: 128,
            reclaim_jobs: true,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let mut rm = ResourceManager::new_streaming(
            cluster,
            policy,
            specs,
            workload.seed,
            ycfg,
        );
        rm.run();
        let cell = CellReport {
            makespan: rm.metrics.makespan,
            peak_active: rm.jobs.peak_active(),
            resident_end: rm.jobs.resident(),
            wall: sw.elapsed_secs(),
        };
        report_row(&mut table, "yarn", sched, n_jobs, &cell, &stats, &errs);
    }

    std::fs::remove_file(&path).ok();
    vec![info, table]
}
