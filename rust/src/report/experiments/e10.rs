//! E10: the failure sweep — an MTBF grid × schedulers on a memory-hungry
//! mix, measuring what failure awareness buys. The headline comparison is
//! `bayes` (failure-history features + speculative execution) against
//! `bayes-blind` (the identical learner with the failure bins masked off):
//! ATLAS (1511.01446) predicts the failure-aware arm loses fewer jobs and
//! finishes sooner once churn sets in. FIFO anchors the no-learning end.

use crate::coordinator::builder::RunConfig;
use crate::coordinator::jobtracker::FailureConfig;
use crate::report::table::{fnum, Table};
use crate::workload::generator::{Mix, WorkloadConfig};

use super::common::{run_once, ExpOpts};

/// The schedulers of the sweep, no-learning anchor first.
pub const SWEEP_SCHEDULERS: [&str; 3] = ["fifo", "bayes-blind", "bayes"];

pub fn e10(opts: &ExpOpts) -> Vec<Table> {
    let mtbfs: Vec<Option<f64>> = if opts.quick {
        vec![None, Some(400.0)]
    } else {
        vec![None, Some(1200.0), Some(600.0), Some(300.0)]
    };
    let mut table = Table::new(
        "E10 failure sweep: failure-aware vs failure-blind bayes (mttr = 90s, mem-heavy mix)",
        &[
            "mtbf_s",
            "scheduler",
            "makespan_s",
            "failed_jobs",
            "task_failures",
            "oom_kills",
            "wasted_attempts",
            "spec_launches",
            "spec_wins",
        ],
    );
    let mut cell = 0usize;
    for mtbf in &mtbfs {
        for sched in SWEEP_SCHEDULERS {
            let mut cfg = RunConfig {
                scheduler: sched.into(),
                n_nodes: opts.scaled(40, 8) as u32,
                n_racks: 4,
                workload: WorkloadConfig {
                    n_jobs: opts.scaled(200, 25),
                    arrival_rate: 0.5,
                    // memory-hungry mix: OOM churn is the failure mode the
                    // failure features must learn around
                    mix: Mix(vec![
                        (crate::job::profile::JobClass::MemHeavy, 0.45),
                        (crate::job::profile::JobClass::CpuHeavy, 0.20),
                        (crate::job::profile::JobClass::IoHeavy, 0.15),
                        (crate::job::profile::JobClass::Small, 0.20),
                    ]),
                    seed: 12,
                    ..Default::default()
                },
                ..Default::default()
            };
            cfg.tracker.failures = FailureConfig { mtbf: *mtbf, mttr: 90.0 };
            // each sweep cell gets its own suffixed exporter outputs
            // (`metrics.prom` -> `metrics.cell-<i>.prom`), mtbf-major
            // order, so no cell clobbers another's files
            cfg.obs = opts.obs.for_cell(cell);
            cell += 1;
            let r = run_once(&cfg);
            table.row(vec![
                mtbf.map_or("none".to_string(), |m| format!("{m:.0}")),
                sched.into(),
                fnum(r.makespan),
                format!("{}", r.failed_jobs),
                format!("{}", r.task_failures),
                format!("{}", r.oom_kills),
                format!("{}", r.wasted_attempts),
                format!("{}", r.speculative_launches),
                format!("{}", r.speculative_wins),
            ]);
        }
    }
    vec![table]
}
