//! Shared experiment plumbing: run one simulation, summarize it.

use std::path::PathBuf;

use crate::coordinator::builder::{build_tracker_with, RunConfig};
use crate::coordinator::jobtracker::JobTracker;
use crate::metrics::stats;
use crate::workload::generator::generate;

/// Options shared by all experiment drivers.
#[derive(Debug, Clone, Default)]
pub struct ExpOpts {
    /// Shrink workloads/seeds for fast smoke runs.
    pub quick: bool,
    /// Where to write CSVs (skipped when None).
    pub out_dir: Option<PathBuf>,
    /// Observability flags, forwarded into each run's `RunConfig`.
    pub obs: crate::obs::ObsOptions,
}

impl ExpOpts {
    /// Scale a count down in quick mode.
    pub fn scaled(&self, full: usize, quick: usize) -> usize {
        if self.quick {
            quick
        } else {
            full
        }
    }
}

/// One simulation run boiled down to report numbers.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub scheduler: String,
    pub seed: u64,
    pub makespan: f64,
    pub throughput: f64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub mean_wait: f64,
    pub overload_rate: f64,
    pub overload_seconds: f64,
    pub oom_kills: u64,
    pub wasted_attempts: u64,
    pub failed_jobs: u64,
    pub task_failures: u64,
    pub node_failures: u64,
    pub speculative_launches: u64,
    pub speculative_wins: u64,
    pub locality_node: f64,
    pub locality_rack: f64,
    pub locality_remote: f64,
    pub mean_decision_us: f64,
    /// Per-heartbeat batch latency (one assign() call fills all free slots).
    pub mean_assign_us: f64,
    pub heartbeats: u64,
}

/// Run a config to completion and summarize.
pub fn run_once(cfg: &RunConfig) -> RunSummary {
    let cluster =
        crate::cluster::Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
    let specs = generate(&cfg.workload);
    // static experiment config -- lint: allow(unwrap-in-lib)
    let mut jt = build_tracker_with(cfg, cluster, specs).expect("build tracker");
    if cfg.obs.any_output() {
        jt.enable_obs(&cfg.obs);
    }
    jt.run();
    if let Err(e) = jt.finish_obs(&cfg.obs) {
        crate::obs_log!(crate::obs::log::ERROR, "obs export failed: {e}");
    }
    summarize(&jt, cfg)
}

/// Summarize a finished tracker.
pub fn summarize(jt: &JobTracker, cfg: &RunConfig) -> RunSummary {
    let m = &jt.metrics;
    // means are exact (streaming sums); the percentile comes from the
    // bounded reservoir sample, which is the full population on runs
    // below metrics::collector::SAMPLE_CAP jobs
    let lat = m.latencies();
    RunSummary {
        scheduler: cfg.scheduler.clone(),
        seed: cfg.workload.seed,
        makespan: m.makespan,
        throughput: m.throughput(),
        mean_latency: m.mean_latency(),
        p95_latency: stats::percentile(&lat, 95.0),
        mean_wait: m.mean_wait(),
        overload_rate: m.overload_rate(),
        overload_seconds: m.overload_seconds,
        oom_kills: m.oom_kills,
        wasted_attempts: m.wasted_attempts(),
        failed_jobs: m.failed_jobs,
        task_failures: m.task_failures,
        node_failures: m.node_failures,
        speculative_launches: m.speculative_launches,
        speculative_wins: m.speculative_wins,
        locality_node: m.locality_fraction("node_local"),
        locality_rack: m.locality_fraction("rack_local"),
        locality_remote: m.locality_fraction("remote"),
        mean_decision_us: m.mean_decision_micros(),
        mean_assign_us: m.mean_assign_micros(),
        heartbeats: m.heartbeats,
    }
}

/// Mean of a field across summaries.
pub fn mean_of(xs: &[RunSummary], f: impl Fn(&RunSummary) -> f64) -> f64 {
    stats::mean(&xs.iter().map(f).collect::<Vec<_>>())
}

/// Std-dev of a field across summaries.
pub fn std_of(xs: &[RunSummary], f: impl Fn(&RunSummary) -> f64) -> f64 {
    stats::std_dev(&xs.iter().map(f).collect::<Vec<_>>())
}
