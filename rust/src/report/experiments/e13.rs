//! E13: the million-job core — drive 1,000,000 jobs through a 10,000-node
//! cluster on the arena-indexed tracker with the calendar-queue engine,
//! streaming specs and reclaiming job slots so memory stays O(active
//! jobs). Reports makespan, event and job counts, the active-job
//! high-water mark, and end-of-run residency (the reclamation proof).
//!
//! The workload is all-Small jobs at ~60% of the cluster's service rate:
//! the point is scale of the *core* (event queue, arena, queue view), not
//! scheduler quality, so FIFO with a capped per-heartbeat queue view is
//! the right baseline.

use crate::cluster::Cluster;
use crate::coordinator::jobtracker::{JobTracker, TrackerConfig};
use crate::job::profile::JobClass;
use crate::report::table::{fnum, Table};
use crate::workload::generator::{stream, Mix, WorkloadConfig};

use super::common::ExpOpts;

pub fn e13(opts: &ExpOpts) -> Vec<Table> {
    let n_jobs = opts.scaled(1_000_000, 20_000);
    let n_nodes = opts.scaled(10_000, 500) as u32;
    // ~60% of the map-slot service rate for the Small class (≈5 maps of
    // ≈5s on 2 map slots per node), so the backlog stays bounded
    let arrival_rate = if opts.quick { 20.0 } else { 450.0 };
    let mut table = Table::new(
        "E13 million-job core: streaming specs, arena reclamation, calendar queue",
        &[
            "scheduler",
            "jobs",
            "nodes",
            "makespan_s",
            "events",
            "clamped",
            "peak_active",
            "resident_end",
            "completed",
            "wall_s",
        ],
    );
    let workload = WorkloadConfig {
        n_jobs,
        arrival_rate,
        mix: Mix::only(JobClass::Small),
        n_users: 8,
        seed: 13,
    };
    let cfg = TrackerConfig {
        // bound per-heartbeat scoring work: O(cap), not O(backlog)
        queue_cap: 128,
        // recycle drained jobs' slots: O(active) memory
        reclaim_jobs: true,
        ..Default::default()
    };
    let cluster = Cluster::homogeneous(n_nodes, (n_nodes / 40).max(1));
    // by_name covers every registered name -- lint: allow(unwrap-in-lib)
    let scheduler = crate::scheduler::by_name("fifo", workload.seed).unwrap();
    let specs = Box::new(stream(&workload));
    let started = crate::obs::Stopwatch::start();
    let mut jt =
        JobTracker::new_streaming(cluster, scheduler, specs, workload.seed, cfg);
    jt.run();
    let wall = started.elapsed_secs();
    table.row(vec![
        "fifo".into(),
        format!("{n_jobs}"),
        format!("{n_nodes}"),
        fnum(jt.metrics.makespan),
        format!("{}", jt.engine.processed()),
        format!("{}", jt.engine.clamped_events()),
        format!("{}", jt.jobs.peak_active()),
        format!("{}", jt.jobs.resident()),
        format!("{}", jt.metrics.completed_jobs()),
        fnum(wall),
    ]);
    vec![table]
}
