//! E8 (design ablations) and E9 (heterogeneous cluster / mis-tuned slots:
//! the paper's §4.1 motivation that administrators cannot hand-tune task
//! limits for every job/node combination).

use crate::bayes::classifier::NaiveBayes;
use crate::bayes::utility::UtilityFn;
use crate::cluster::node::NodeSpec;
use crate::cluster::resources::Resources;
use crate::cluster::Cluster;
use crate::coordinator::builder::{build_tracker_with, RunConfig};
use crate::report::table::{fnum, Table};
use crate::scheduler::{BayesScheduler, Scheduler, StarvationPolicy};
use crate::workload::generator::{generate, Mix, WorkloadConfig};

use super::common::{summarize, ExpOpts};

fn run_with_sched(
    cfg: &RunConfig,
    sched: Box<dyn Scheduler>,
) -> super::common::RunSummary {
    let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
    let specs = generate(&cfg.workload);
    let mut jt = crate::coordinator::jobtracker::JobTracker::new(
        cluster,
        sched,
        specs,
        cfg.workload.seed,
        cfg.tracker.clone(),
    );
    jt.run();
    summarize(&jt, cfg)
}

/// E8: one row per ablated variant of the Bayes scheduler.
pub fn e8(opts: &ExpOpts) -> Vec<Table> {
    let cfg = RunConfig {
        scheduler: "bayes".into(),
        n_nodes: opts.scaled(40, 8) as u32,
        n_racks: 4,
        workload: WorkloadConfig {
            n_jobs: opts.scaled(200, 30),
            arrival_rate: 0.5,
            seed: 8,
            ..Default::default()
        },
        ..Default::default()
    };
    let variants: Vec<(&str, Box<dyn Scheduler>)> = vec![
        ("full", Box::new(BayesScheduler::new(NaiveBayes::new(1.0)))),
        (
            "no_utility",
            Box::new(
                BayesScheduler::new(NaiveBayes::new(1.0))
                    .with_utility(UtilityFn::constant()),
            ),
        ),
        (
            "starvation_wait",
            Box::new(
                BayesScheduler::new(NaiveBayes::new(1.0))
                    .with_policy(StarvationPolicy::Wait),
            ),
        ),
        (
            "starvation_least_bad",
            Box::new(
                BayesScheduler::new(NaiveBayes::new(1.0))
                    .with_policy(StarvationPolicy::LeastBad),
            ),
        ),
        (
            "job_features_only",
            Box::new(
                BayesScheduler::new(NaiveBayes::new(1.0)).with_feature_mask([
                    true, true, true, true, false, false, false, false, false,
                    false,
                ]),
            ),
        ),
        (
            "node_features_only",
            Box::new(
                BayesScheduler::new(NaiveBayes::new(1.0)).with_feature_mask([
                    false, false, false, false, true, true, true, true, false,
                    false,
                ]),
            ),
        ),
        (
            "failure_blind",
            Box::new(
                BayesScheduler::new(NaiveBayes::new(1.0))
                    .with_feature_mask(crate::scheduler::FAILURE_BLIND_MASK),
            ),
        ),
        (
            "no_speculation",
            Box::new(BayesScheduler::new(NaiveBayes::new(1.0)).with_speculation(
                crate::scheduler::SpeculationConfig {
                    enabled: false,
                    ..Default::default()
                },
            )),
        ),
        ("alpha_0.1", Box::new(BayesScheduler::new(NaiveBayes::new(0.1)))),
        ("alpha_10", Box::new(BayesScheduler::new(NaiveBayes::new(10.0)))),
    ];
    let mut table = Table::new(
        "E8 ablations of the Bayes scheduler",
        &[
            "variant",
            "makespan_s",
            "mean_latency_s",
            "overload_rate",
            "oom_kills",
        ],
    );
    for (name, sched) in variants {
        let r = run_with_sched(&cfg, sched);
        table.row(vec![
            name.into(),
            fnum(r.makespan),
            fnum(r.mean_latency),
            fnum(r.overload_rate),
            fnum(r.oom_kills as f64),
        ]);
    }
    vec![table]
}

/// E9: heterogeneous cluster where static slot configs are mis-tuned.
/// `tuned` gives slow nodes fewer slots (admin did their homework);
/// `mistuned` gives every node 4 map slots (the default config the paper
/// says admins fall back to); Bayes runs on the mis-tuned cluster and must
/// learn around it.
pub fn e9(opts: &ExpOpts) -> Vec<Table> {
    let n = opts.scaled(40, 9) as u32;
    let fast = NodeSpec {
        capacity: Resources::splat(2.0),
        speed: 2.0,
        map_slots: 4,
        reduce_slots: 2,
    };
    let std_node = NodeSpec::default();
    let slow = NodeSpec {
        capacity: Resources::splat(0.5),
        speed: 0.5,
        map_slots: 1,
        reduce_slots: 1,
    };
    let slow_mistuned = NodeSpec { map_slots: 4, reduce_slots: 2, ..slow };
    let classes_tuned = [(fast, 0.25), (std_node, 0.5), (slow, 0.25)];
    let classes_mistuned = [(fast, 0.25), (std_node, 0.5), (slow_mistuned, 0.25)];

    let workload = WorkloadConfig {
        n_jobs: opts.scaled(200, 30),
        arrival_rate: 0.5,
        mix: Mix::balanced(),
        seed: 9,
        ..Default::default()
    };
    let mut table = Table::new(
        "E9 heterogeneous cluster: hand-tuned vs mis-tuned slot configs",
        &[
            "config",
            "scheduler",
            "makespan_s",
            "p95_latency_s",
            "overload_rate",
            "oom_kills",
        ],
    );
    let cases: Vec<(&str, &str, &[(NodeSpec, f64)])> = vec![
        ("tuned", "fifo", &classes_tuned),
        ("mistuned", "fifo", &classes_mistuned),
        ("mistuned", "bayes", &classes_mistuned),
        ("tuned", "bayes", &classes_tuned),
    ];
    for (cname, sched, classes) in cases {
        let cfg = RunConfig {
            scheduler: sched.into(),
            n_nodes: n,
            n_racks: 4,
            workload: workload.clone(),
            ..Default::default()
        };
        let cluster = Cluster::heterogeneous(n, 4, classes, 99);
        let specs = generate(&cfg.workload);
        // static experiment config -- lint: allow(unwrap-in-lib)
        let mut jt = build_tracker_with(&cfg, cluster, specs).unwrap();
        jt.run();
        let r = summarize(&jt, &cfg);
        table.row(vec![
            cname.into(),
            sched.into(),
            fnum(r.makespan),
            fnum(r.p95_latency),
            fnum(r.overload_rate),
            fnum(r.oom_kills as f64),
        ]);
    }
    vec![table]
}
