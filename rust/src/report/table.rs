//! Report tables: aligned ASCII rendering for the terminal plus CSV export
//! for plotting — the benches regenerate each derived experiment table in
//! both forms.

use std::fmt::Write as _;
use std::path::Path;

/// A rendered experiment table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len(), "ragged row");
        self.rows.push(cells);
    }

    /// Aligned ASCII rendering.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(cols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate().take(cols) {
                let _ = write!(s, " {:<w$} |", c, w = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// CSV rendering (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV to `<dir>/<slug>.csv`.
    pub fn save_csv(&self, dir: &Path, slug: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{slug}.csv")), self.to_csv())
    }
}

/// Format an f64 with sensible precision for reports.
pub fn fnum(x: f64) -> String {
    // exact-zero prints bare '0' -- lint: allow(float-eq)
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer-name".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        let lines: Vec<&str> = r.lines().skip(1).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{r}");
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1,5".into(), "plain".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"1,5\",plain"));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.6), "1235"); // note: {:.0} rounds half-to-even
        assert_eq!(fnum(12.345), "12.35");
        assert_eq!(fnum(0.1234), "0.1234");
    }
}
