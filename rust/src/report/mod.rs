//! Reporting: ASCII/CSV tables + the E1–E10 experiment drivers.

pub mod bench;
pub mod experiments;
pub mod table;

pub use experiments::ExpOpts;
pub use table::{fnum, Table};
