//! Minimal benchmark harness (criterion substitute — not in the offline
//! crate cache). Plain `harness = false` benches call [`bench`] / [`Bench`]
//! and print a stable, greppable format:
//!
//! `bench <name> ... mean 12.34 ms  (min 11.90, max 13.02, n=20)`

use crate::obs::Stopwatch;

/// One measured result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters: usize,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "bench {:<48} mean {:>12}  (min {}, max {}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.max_ns),
            self.iters
        );
    }
}

/// Human-scale duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Measure `f` `iters` times (after `warmup` unmeasured runs), print and
/// return the result. `f` gets the iteration index; use `std::hint::black_box`
/// on inputs/outputs inside.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut(usize)) -> Measurement {
    assert!(iters > 0);
    for i in 0..warmup {
        f(i);
    }
    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Stopwatch::start();
        f(i);
        samples.push(t0.elapsed_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0, f64::max);
    let m = Measurement {
        name: name.to_string(),
        mean_ns: mean,
        min_ns: min,
        max_ns: max,
        iters,
    };
    m.print();
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let m = bench("noop-ish", 1, 5, |_| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.min_ns <= m.mean_ns && m.mean_ns <= m.max_ns);
    }

    #[test]
    fn formats_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.20 s");
    }
}
