//! `repro` — the launcher binary. See `repro help` or README.md.

fn main() {
    let code = match bayes_sched::cli::dispatch(std::env::args().skip(1)) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
