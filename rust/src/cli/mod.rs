//! CLI: the `repro` launcher's argument parsing and subcommand dispatch.

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::dispatch;
