//! Tiny argument parser (clap substitute): positionals + `--key value`
//! options + `--flag` booleans, with typed accessors and unknown-flag
//! rejection.

use std::collections::BTreeMap;

use crate::errors::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `flag_names` lists valueless
    /// switches; everything else starting with `--` takes a value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err(anyhow!("bare '--' not supported"));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} needs a value"))?;
                    out.options.insert(name.to_string(), v);
                }
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["quick", "verbose"])
            .unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("run --scheduler bayes --nodes 40 trace.json");
        assert_eq!(a.positionals, vec!["run", "trace.json"]);
        assert_eq!(a.opt("scheduler"), Some("bayes"));
        assert_eq!(a.opt_u64("nodes", 0).unwrap(), 40);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("x --seed=7 --rate=0.5");
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.opt_f64("rate", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn flags() {
        let a = parse("exp e1 --quick");
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(vec!["--nodes".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --seed abc");
        assert!(a.opt_u64("seed", 0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.opt_or("scheduler", "bayes"), "bayes");
        assert_eq!(a.opt_f64("rate", 0.5).unwrap(), 0.5);
    }
}
